"""CI smoke for the scheduling front door (DESIGN.md §7).

One tiny Scenario on BOTH engines (normalized Results must agree within
the 1% engine-equivalence contract), plus a 3-step `SaathSession`
(submit / advance / poll) whose incremental CCTs must match the offline
replay. Fast by construction (~seconds + one small XLA compile).

    PYTHONPATH=src python -m benchmarks.api_smoke
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.api import Scenario, SaathSession, run
from repro.core.params import SchedulerParams
from repro.traces import tiny_trace


def main():
    t0 = time.time()
    p = SchedulerParams()
    trace = tiny_trace(24, 12, seed=3, load=0.8)

    results = {}
    for engine in ("numpy", "jax"):
        res = run(Scenario(policy="saath", engine=engine, trace=trace,
                           params=p, label="api-smoke"))
        results[engine] = res
        print(f"# {engine}: avg_cct={res.avg_cct[0]:.3f}s "
              f"makespan={res.makespan[0]:.3f}s steps={res.steps} "
              f"wall={res.wall_seconds:.2f}s", file=sys.stderr)
    rn, rj = results["numpy"], results["jax"]
    np.testing.assert_allclose(rj.row_cct(), rn.row_cct(), rtol=1e-2,
                               atol=2 * p.delta)
    ratio = float(rj.avg_cct[0] / rn.avg_cct[0])
    assert abs(ratio - 1.0) < 1e-2, ratio

    # 3-step online session: submit the trace incrementally
    sess = SaathSession(p, num_ports=12, backend="jax")
    ordered = sorted(trace.coflows, key=lambda c: c.arrival)
    cut1, cut2 = len(ordered) // 3, 2 * len(ordered) // 3
    ccts = {}
    for step, group in enumerate((ordered[:cut1], ordered[cut1:cut2],
                                  ordered[cut2:])):
        last = max(c.arrival for c in group)
        for c in group:
            sess.advance(max(c.arrival - sess.now, 0.0))
            sess.submit([c])
        sess.advance(max(last - sess.now, 0.0))
        done = sess.poll()
        print(f"# session step {step}: t={sess.now:.3f}s "
              f"live={sess.num_live} completed={len(done)}",
              file=sys.stderr)
        ccts.update({d.handle: d.cct for d in done})
    ccts.update({d.handle: d.cct for d in sess.drain(step=5.0)})
    got = np.array([ccts[h] for h in sorted(ccts)])
    want = rn.row_cct()[[c.cid for c in ordered]]
    np.testing.assert_allclose(got, want, rtol=1e-2, atol=2 * p.delta)
    print(f"# api smoke OK in {time.time() - t0:.1f}s "
          f"(session reproduced offline CCTs, max rel err "
          f"{np.nanmax(np.abs(got - want) / want):.2e})", file=sys.stderr)


if __name__ == "__main__":
    main()
