"""Fig. 9: CCT speedup of Saath over Aalo / Varys-SEBF / UC-TCP.

Paper (FB trace): Saath vs Aalo p50 = 1.53x, p90 = 4.5x; ~Varys-SEBF
parity; >>100x vs UC-TCP.
"""
from __future__ import annotations

from benchmarks.common import Bench, emit
from repro.fabric.metrics import percentile_speedup


def run(bench: Bench):
    saath = bench.sim("saath").table.cct
    rows = []
    for pol in ("aalo", "varys-sebf", "uc-tcp", "fifo", "saath-jax"):
        other = bench.sim(pol).table.cct
        s = percentile_speedup(other, saath)  # CCT_other / CCT_saath
        rows.append({"vs": pol, **s})
    emit("fig9_speedup", rows)
    aalo = next(r for r in rows if r["vs"] == "aalo")
    assert aalo["p50"] > 1.1, f"Saath should beat Aalo at p50: {aalo}"
    assert aalo["p90"] > 2.0, f"...and strongly at p90: {aalo}"
    return rows


if __name__ == "__main__":
    run(Bench())
