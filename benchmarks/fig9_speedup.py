"""Fig. 9: CCT speedup of Saath over Aalo / Varys-SEBF / UC-TCP.

Paper (FB trace): Saath vs Aalo p50 = 1.53x, p90 = 4.5x; ~Varys-SEBF
parity; >>100x vs UC-TCP.

--engine=jax additionally runs the batched-fleet demonstration: 16
traces replayed as ONE vmapped XLA computation vs 16 sequential
`Simulator.run` calls (the claim this PR's engine exists for — a >= 5x
wall-clock win once compiled).
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import Bench, cli_bench, emit
from repro.fabric.metrics import percentile_speedup

FLEET = 16  # traces in the batched sweep


def run(bench: Bench, engine: str = "numpy"):
    saath = bench.sim("saath").table.cct
    rows = []
    for pol in ("aalo", "varys-sebf", "uc-tcp", "fifo", "saath-jax"):
        other = bench.sim(pol).table.cct
        s = percentile_speedup(other, saath)  # CCT_other / CCT_saath
        rows.append({"vs": pol, **s})
    emit("fig9_speedup", rows)
    aalo = next(r for r in rows if r["vs"] == "aalo")
    assert aalo["p50"] > 1.1, f"Saath should beat Aalo at p50: {aalo}"
    assert aalo["p90"] > 2.0, f"...and strongly at p90: {aalo}"
    if engine == "jax":
        rows += run_fleet(bench)
    return rows


def run_fleet(bench: Bench):
    """16-trace fleet: sequential event-driven numpy replays vs one
    batched `jax_engine.simulate_batch` call (cold = incl. XLA compile,
    warm = the steady-state sweep cost a parameter study pays).

    Two batched rows: full FIDELITY (per-flow work conservation + §4.3
    re-queue — must match the numpy references' CCTs, the PR-2 claim)
    and the coflow-granular THROUGHPUT mode (the parameter-sweep
    configuration the >= 5x wall-clock gate applies to)."""
    from repro.core.params import SchedulerParams
    from repro.core.policies import make_policy
    from repro.fabric import jax_engine
    from repro.fabric.engine import Simulator
    from repro.fabric.state import FlowTable
    from repro.traces import tiny_trace

    p = SchedulerParams()
    n, ports = 40, 20
    fleet = FLEET if bench.quick else 2 * FLEET
    traces = [tiny_trace(n, ports, seed=s, load=0.8) for s in range(fleet)]

    t0 = time.perf_counter()
    seq_cct = []
    for tr in traces:
        table = FlowTable.from_trace(tr, p.port_bw)
        Simulator(p).run(table, make_policy("saath", p))
        seq_cct.append(float(np.nanmean(table.cct)))
    t_seq = time.perf_counter() - t0

    t0 = time.perf_counter()
    res = jax_engine.simulate_batch(traces, p)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = jax_engine.simulate_batch(traces, p)
    t_fid = time.perf_counter() - t0
    ratio = float(np.mean(res.avg_cct) / np.mean(seq_cct))

    fast_kw = dict(fidelity="coflow", dynamics_requeue=False)
    res_fast = jax_engine.simulate_batch(traces, p, **fast_kw)
    t0 = time.perf_counter()
    res_fast = jax_engine.simulate_batch(traces, p, **fast_kw)
    t_warm = time.perf_counter() - t0
    ratio_fast = float(np.mean(res_fast.avg_cct) / np.mean(seq_cct))

    rows = [
        {"vs": "fleet-seq-numpy", "wall_s": t_seq, "speedup": 1.0,
         "note": f"{fleet}x Simulator.run {n}x{ports}"},
        {"vs": "fleet-jax-cold", "wall_s": t_cold,
         "speedup": t_seq / t_cold, "note": "incl. XLA compile"},
        {"vs": "fleet-jax-fidelity", "wall_s": t_fid,
         "speedup": t_seq / t_fid,
         "note": f"events={res.events} avg-cct-ratio={ratio:.3f}"},
        {"vs": "fleet-jax-warm", "wall_s": t_warm,
         "speedup": t_seq / t_warm,
         "note": f"events={res_fast.events} "
                 f"avg-cct-ratio={ratio_fast:.3f}"},
    ]
    emit("fig9_fleet", rows)
    warm = t_seq / t_warm
    # >= 5x on a quiet machine; SAATH_FLEET_MIN_SPEEDUP relaxes the gate
    # on loaded/shared CI runners where wall-clock ratios wander
    floor = float(os.environ.get("SAATH_FLEET_MIN_SPEEDUP", "5.0"))
    assert warm >= floor, f"batched fleet should be >={floor}x: {warm:.1f}x"
    # full fidelity must MATCH the per-flow reference, not approximate it
    assert 0.97 < ratio < 1.03, ratio
    # the coflow-granular throughput mode keeps the documented envelope
    assert 0.5 < ratio_fast < 2.0, ratio_fast
    return rows


if __name__ == "__main__":
    run(*cli_bench())
