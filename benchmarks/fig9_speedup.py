"""Fig. 9: CCT speedup of Saath over Aalo / Varys-SEBF / UC-TCP.

Paper (FB trace): Saath vs Aalo p50 = 1.53x, p90 = 4.5x; ~Varys-SEBF
parity; >>100x vs UC-TCP.

The Saath side runs on whichever engine the Scenario names (--engine is
scenario data, not a code path); the baselines are host-only policies.
The fleet section is inherently cross-engine: 16 traces replayed as ONE
vmapped XLA computation vs 16 sequential `Simulator.run` replays — the
>= 5x wall-clock claim the batched engine exists for.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import Bench, cli_bench, emit, record
from repro.api import Scenario
from repro.api import run as api_run
from repro.fabric.metrics import percentile_speedup

FLEET = 16  # traces in the batched sweep


def run(bench: Bench, engine: str = "numpy"):
    saath = bench.run("saath", engine=engine,
                      record_as="fig9_saath").row_cct()
    rows = []
    for pol in ("aalo", "varys-sebf", "uc-tcp", "fifo", "saath-jax"):
        other = bench.run(pol).row_cct()
        s = percentile_speedup(other, saath)  # CCT_other / CCT_saath
        rows.append({"vs": pol, **s})
    emit(f"fig9_speedup[{engine}]", rows)
    aalo = next(r for r in rows if r["vs"] == "aalo")
    assert aalo["p50"] > 1.1, f"Saath should beat Aalo at p50: {aalo}"
    assert aalo["p90"] > 2.0, f"...and strongly at p90: {aalo}"
    rows += run_fleet(bench)
    return rows


def run_fleet(bench: Bench):
    """16-trace fleet: sequential event-driven numpy replays vs one
    batched engine call, all through `repro.api.run` (cold/warm split
    via Scenario.warm_timing).

    Two batched rows: full FIDELITY (per-flow work conservation + §4.3
    re-queue — must match the numpy references' CCTs, the PR-2 claim)
    and the coflow-granular THROUGHPUT mode (the parameter-sweep
    configuration the >= 5x wall-clock gate applies to)."""
    from repro.core.params import SchedulerParams
    from repro.traces import tiny_trace

    p = SchedulerParams()
    n, ports = 40, 20
    fleet = FLEET if bench.quick else 2 * FLEET
    traces = tuple(tiny_trace(n, ports, seed=s, load=0.8)
                   for s in range(fleet))

    seq = api_run(Scenario(policy="saath", engine="numpy", params=p,
                           traces=traces, label="fleet-seq"))
    t_seq = seq.wall_seconds

    fid = api_run(Scenario(policy="saath", engine="jax", params=p,
                           traces=traces, warm_timing=True,
                           label="fleet-fidelity"))
    t_cold = fid.wall_seconds + fid.compile_seconds
    t_fid = fid.wall_seconds
    ratio = float(np.mean(fid.avg_cct) / np.mean(seq.avg_cct))

    fast = api_run(Scenario(policy="saath", engine="jax", params=p,
                            traces=traces, fidelity="coflow",
                            mechanisms={"dynamics_requeue": False},
                            warm_timing=True, label="fleet-throughput"))
    t_warm = fast.wall_seconds
    ratio_fast = float(np.mean(fast.avg_cct) / np.mean(seq.avg_cct))

    record("fig9_fleet_seq", seq)
    record("fig9_fleet_fidelity", fid)
    record("fig9_fleet_throughput", fast)
    rows = [
        {"vs": "fleet-seq-numpy", "wall_s": t_seq, "speedup": 1.0,
         "note": f"{fleet}x Simulator.run {n}x{ports}"},
        {"vs": "fleet-jax-cold", "wall_s": t_cold,
         "speedup": t_seq / t_cold, "note": "incl. XLA compile"},
        {"vs": "fleet-jax-fidelity", "wall_s": t_fid,
         "speedup": t_seq / t_fid,
         "note": f"events={fid.steps} avg-cct-ratio={ratio:.3f}"},
        {"vs": "fleet-jax-warm", "wall_s": t_warm,
         "speedup": t_seq / t_warm,
         "note": f"events={fast.steps} "
                 f"avg-cct-ratio={ratio_fast:.3f}"},
    ]
    emit("fig9_fleet", rows)
    warm = t_seq / t_warm
    # >= 5x on a quiet machine; SAATH_FLEET_MIN_SPEEDUP relaxes the gate
    # on loaded/shared CI runners where wall-clock ratios wander
    floor = float(os.environ.get("SAATH_FLEET_MIN_SPEEDUP", "5.0"))
    assert warm >= floor, f"batched fleet should be >={floor}x: {warm:.1f}x"
    # full fidelity must MATCH the per-flow reference, not approximate it
    assert 0.97 < ratio < 1.03, ratio
    # the coflow-granular throughput mode keeps the documented envelope
    assert 0.5 < ratio_fast < 2.0, ratio_fast
    return rows


if __name__ == "__main__":
    run(*cli_bench())
