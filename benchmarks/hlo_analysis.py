"""Trip-count-aware HLO cost extraction for the roofline analysis.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE —
useless for scan-over-layers programs (a 94-layer model reports ~1
layer of FLOPs). This module re-derives per-device costs from the
optimized HLO text:

* FLOPs: every ``dot`` op costs 2 * prod(result_dims) * prod(lhs
  contracting dims); multiplied by the product of enclosing while-loop
  ``known_trip_count``s (scan lowers to while with that attribute).
* HBM bytes: per top-level op, result + operand bytes (fusion internals
  excluded — fused intermediates never touch HBM), same multipliers.
* Collective link bytes (per device), ring estimates:
    all-gather / all-to-all : result * (g-1)/g
    all-reduce              : 2 * result * (g-1)/g
    reduce-scatter          : result * (g-1)   [operand = g * result]
    collective-permute      : result
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List

_TYPE_RE = re.compile(
    r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred|f8e4m3fn|"
    r"f8e5m2|c64|c128)\[([0-9,]*)\]")
_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s8": 1, "u8": 1,
          "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
          "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*)$")
_OPC_RE = re.compile(r"^((?:\([^)]*\)|[\w\[\],\{\} ]+?)?)\s*([\w\-]+)\(")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n["\s:]+"?(\d+)')
_CALLS_RE = re.compile(r"(?:calls|body)=(%[\w\.\-]+)")
_OPER_RE = re.compile(r"\((%[\w\.\-]+(?:,\s*%[\w\.\-]+)*)?\)")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[m.group(1)]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _TYPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str


_HDR_RE = re.compile(r"^(ENTRY\s+)?(%[\w\.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")


def parse_computations(hlo: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        if not line.startswith(" "):
            m = _HDR_RE.match(s)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):  # ENTRY
                    comps["__entry__"] = comps[cur]
            elif s == "}":
                cur = None
            continue
        if cur is None:
            continue
        dm = _DEF_RE.match(s)
        if not dm:
            continue
        name, rhs = dm.group(1), dm.group(2)
        om = _OPC_RE.match(rhs)
        opcode = om.group(2) if om else rhs.split("(")[0].split()[-1]
        type_str = om.group(1) if om else rhs
        comps[cur].append(Op(name, type_str, opcode, s))
    return comps


def _operands(line: str) -> List[str]:
    # operand list = first (...) after the opcode
    m = re.search(r"[\w\-]+\((.*?)\)(?:,|$)", line)
    if not m:
        return []
    return re.findall(r"%[\w\.\-]+", m.group(1))


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    out_elems = 1
    for d in _shape_dims(op.type_str):
        out_elems *= d
    ops_ = _operands(op.line)
    if not ops_:
        return 0.0
    lhs_t = symtab.get(ops_[0], "")
    lhs_dims = _shape_dims(lhs_t)
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    k = 1
    if cm and lhs_dims:
        for d in cm.group(1).split(","):
            if d:
                k *= lhs_dims[int(d)]
    return 2.0 * out_elems * k


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,\s]+?)\}[,}]", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"source_target_pairs=\{(.+?)\}\}", line)
    if m:
        return default
    return default


def _collective_bytes(op: Op, kind: str, ndev: int) -> float:
    rb = _shape_bytes(op.type_str)
    # XLA:CPU promotes bf16 dot outputs to f32, so row-parallel partial
    # sums get all-reduced in f32 (reduction computation is named
    # '*_promoted'). On TPU the payload stays bf16 — halve it.
    if "promoted" in op.line and "f32[" in op.type_str:
        rb *= 0.5
    g = _group_size(op.line, ndev)
    if kind == "all-gather":
        return rb * (g - 1) / max(g, 1)
    if kind == "all-reduce":
        return 2.0 * rb * (g - 1) / max(g, 1)
    if kind == "reduce-scatter":
        return rb * (g - 1)
    if kind == "all-to-all":
        return rb * (g - 1) / max(g, 1)
    return float(rb)  # collective-permute


# einsum signatures that identify the flash-attention / SSD chunk scan
# loops: under the Pallas kernels (kernels/flash_attention.py,
# kernels/ssd_scan.py) everything inside those loops lives in VMEM, so
# their HBM traffic exists only on the pure-jnp fallback path. The
# 'kernelized' byte count zeroes those loop bodies (FLOPs and
# collectives are still charged). NOTE: this assumes flash/SSD *backward*
# kernels too (FlashAttention-2-style) — see DESIGN.md §6.
KERNEL_INTERNAL_RE = re.compile(
    r"(->bhgst|bhgst,|->btuh|btuh,|bshgd,bthd|bthn,bhdn)")


def _op_meta(line: str) -> str:
    m = re.search(r'op_name="([^"]*)"', line)
    return m.group(1) if m else ""


def _kernel_bodies(comps) -> set:
    """Computations holding the flash/SSD einsum DOTs (the scan bodies).

    Only dots qualify: einsum-lowered transposes outside the scan carry
    the same op_name path and must not tag their (layer-level) caller.
    """
    out = set()
    for cname, ops in comps.items():
        for o in ops:
            if o.opcode == "dot" and KERNEL_INTERNAL_RE.search(
                    _op_meta(o.line)):
                out.add(cname)
                break
    return out


def analyze(hlo: str, num_devices: int) -> dict:
    comps = parse_computations(hlo)
    kbodies = _kernel_bodies(comps)

    import functools

    @functools.lru_cache(maxsize=None)
    def comp_cost(cname: str) -> tuple:
        """(flops, hbm_bytes, coll_bytes_by_kind tuple) for one execution
        of computation `cname`, including nested loops."""
        ops = comps.get(cname, [])
        symtab = {o.name: o.type_str for o in ops}
        flops = 0.0
        bytes_ = 0.0
        kbytes = 0.0   # bytes attributable to kernel-internal traffic
        coll = {k: 0.0 for k in COLLECTIVE_KINDS}
        for o in ops:
            if o.opcode == "parameter":
                continue
            kind = next((k for k in COLLECTIVE_KINDS
                         if o.opcode.startswith(k)), None)
            if kind and not o.opcode.endswith("-done"):
                coll[kind] += _collective_bytes(o, kind, num_devices)
            if o.opcode in ("dot", "convolution"):
                flops += _dot_flops(o, symtab)
            # HBM bytes: result + operands of every top-level op.
            # Control ops are containers (their traffic is the ops inside);
            # slice-like ops only touch the sliced region (mirrors XLA's
            # HloCostAnalysis), incl. fusions XLA names after them.
            if o.opcode not in ("tuple", "get-tuple-element", "parameter",
                                "constant", "iota", "bitcast", "while",
                                "conditional", "call", "opt-barrier",
                                "after-all", "partition-id", "replica-id"):
                rb = _shape_bytes(o.type_str)
                slicey = (o.opcode in ("dynamic-slice", "gather", "slice")
                          or "dynamic-slice" in o.name)
                updatey = (o.opcode in ("dynamic-update-slice", "scatter")
                           or "dynamic-update-slice" in o.name)
                b = 0.0
                if slicey:
                    b = 2.0 * rb
                elif updatey:
                    ods = _operands(o.line)
                    cands = [_shape_bytes(symtab.get(od, ""))
                             for od in ods]
                    cands = [c2 for c2 in cands if 0 < c2 < rb]
                    ub = max(cands) if cands else rb
                    b = 2.0 * ub
                else:
                    b = rb
                    for od in _operands(o.line):
                        b += _shape_bytes(symtab.get(od, ""))
                bytes_ += b
                if cname in kbodies:
                    kbytes += b
            # descend
            if o.opcode == "while":
                bm = re.search(r"body=(%[\w\.\-]+)", o.line)
                tm = _TRIP_RE.search(o.line)
                trip = int(tm.group(1)) if tm else 1
                if bm:
                    f2, b2, k2, c2 = comp_cost(bm.group(1))
                    flops += trip * f2
                    bytes_ += trip * b2
                    kbytes += trip * k2
                    for k in COLLECTIVE_KINDS:
                        coll[k] += trip * c2[COLLECTIVE_KINDS.index(k)]
            elif o.opcode == "fusion":
                cm = re.search(r"calls=(%[\w\.\-]+)", o.line)
                if cm:
                    f2, _, _, c2 = comp_cost(cm.group(1))
                    flops += f2   # dots inside fusions still compute
                    for k in COLLECTIVE_KINDS:
                        coll[k] += c2[COLLECTIVE_KINDS.index(k)]
            elif o.opcode in ("call", "async-start", "custom-call",
                              "conditional"):
                cm = _CALLS_RE.search(o.line)
                if cm and cm.group(1) in comps:
                    f2, b2, k2, c2 = comp_cost(cm.group(1))
                    flops += f2
                    bytes_ += b2
                    kbytes += k2
                    for k in COLLECTIVE_KINDS:
                        coll[k] += c2[COLLECTIVE_KINDS.index(k)]
        return flops, bytes_, kbytes, tuple(
            coll[k] for k in COLLECTIVE_KINDS)

    f, b, kb, c = comp_cost("__entry__")
    coll = dict(zip(COLLECTIVE_KINDS, c))
    coll["total"] = sum(c)
    return {"flops": f, "hbm_bytes": b,
            # memory traffic with Pallas-kernel-internal tensors kept in
            # VMEM (flash scores / SSD chunk matrices) — the TPU path
            "hbm_bytes_kernelized": b - kb,
            "collective_bytes": coll}


# ------------------------------------------------------------- roofline
HW = {
    "peak_flops": 197e12,     # bf16 / chip (TPU v5e)
    "hbm_bw": 819e9,          # B/s / chip
    "ici_bw": 50e9,           # B/s / link (per-chip injection, ~3 links)
}


def roofline_terms(per_device: dict, hw=HW, kernelized: bool = True) -> dict:
    t_c = per_device["flops"] / hw["peak_flops"]
    mem = per_device.get("hbm_bytes_kernelized"
                         if kernelized else "hbm_bytes",
                         per_device["hbm_bytes"])
    t_m = mem / hw["hbm_bw"]
    t_n = per_device["collective_bytes"]["total"] / hw["ici_bw"]
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_n),
              key=lambda kv: kv[1])[0]
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
            "bottleneck": dom}


def model_flops(cfg, shape, src_len: int = 4096) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D (+ attention quadratic term).
    Train counts fwd+bwd (6ND); prefill 2ND; decode 2N per token."""
    n = cfg.active_param_count() - cfg.vocab_size * cfg.d_model * (
        1 if cfg.tie_embeddings else 2)
    n_head = cfg.vocab_size * cfg.d_model  # output head matmul
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        base = 6.0 * (n + n_head) * tokens
        attn = 6.0 * _attn_matmul_flops(cfg, S, causal=True) * B
    elif shape.kind == "prefill":
        tokens = B * S
        base = 2.0 * (n + n_head) * tokens
        attn = 2.0 * _attn_matmul_flops(cfg, S, causal=True) * B
    else:  # decode: one token, full-context attention reads
        tokens = B * 1
        base = 2.0 * (n + n_head) * tokens
        attn = 2.0 * B * _attn_layers(cfg) * 2 * 2 * \
            cfg.num_heads * cfg.head_dim * S  # qK^T + pV per layer
    if cfg.enc_dec:
        base *= 1.0  # encoder counted via params already (rough)
    return base + attn


def model_min_bytes(cfg, shape) -> float:
    """Information-theoretic floor on per-step HBM reads (global):
    decode must read the active weights (bf16) plus the whole KV/state
    cache once; train/prefill read weights + write/read activations
    (weights term only — a loose floor). Used for the decode
    bandwidth-utilization metric."""
    w = cfg.active_param_count() * 2.0
    if shape.kind != "decode":
        return w
    B, S = shape.global_batch, shape.seq_len
    L_attn = _attn_layers(cfg)
    if cfg.mla:
        cache = L_attn * B * S * (cfg.kv_lora_rank + cfg.rope_head_dim) * 2
    else:
        cache = L_attn * B * S * 2 * cfg.num_kv_heads * cfg.head_dim * 2
    if cfg.ssm_inner:
        n_mamba = cfg.num_layers - L_attn
        cache += n_mamba * B * cfg.ssm_heads * cfg.ssm_head_dim * \
            cfg.ssm_state * 4
    if cfg.enc_dec:
        cache += cfg.num_layers * B * 4096 * 2 * cfg.num_kv_heads * \
            cfg.head_dim * 2  # cross-attention KV at src_len=4096
    return w + cache


def _attn_layers(cfg) -> int:
    if cfg.ssm_inner and cfg.attn_period == 0:
        return 0
    if cfg.attn_period:
        return cfg.num_layers // cfg.attn_period
    return cfg.num_layers


def _attn_matmul_flops(cfg, S: int, causal: bool) -> float:
    """Per-sequence qK^T + pV flops (causal halves it)."""
    L = _attn_layers(cfg)
    if L == 0:
        return 0.0
    per = 2.0 * 2.0 * cfg.num_heads * cfg.head_dim * S * S
    if causal:
        per *= 0.5
    return per * L
