"""Fig. 14: sensitivity to S (start threshold), E (growth), delta
(sync interval), A (arrival speedup), d (deadline factor), plus the
work-conservation / §4.3-re-queue mechanism switches.

One methodology on both engines, through `repro.api.run`:

* the (S, E, delta, d, mech) grid is ONE sweep Scenario over one trace
  — vmapped into a single XLA computation on the jax engine, looped on
  numpy;
* the arrival-speedup (A) axis changes the TRACE, so it is one Scenario
  per A with an Aalo host baseline (speedup = contention claim).

Key paper claims checked: Saath insensitive to S (LCoF fixes FIFO's
HoL); Saath's edge grows with contention (A); mechanisms don't hurt.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import Bench, cli_bench, emit, record
from repro.api import Scenario
from repro.api import run as api_run
from repro.core.params import MB, SchedulerParams
from repro.fabric.metrics import percentile_speedup


def _grid(base: SchedulerParams):
    grid = []
    for S in (1 * MB, 10 * MB, 100 * MB):
        grid.append(("S", S / MB,
                     dataclasses.replace(base, start_threshold=S)))
    for E in (2.0, 10.0, 32.0):
        grid.append(("E", E, dataclasses.replace(base, growth=E)))
    for delta in (8e-3, 64e-3, 256e-3):
        grid.append(("delta_ms", delta * 1e3,
                     dataclasses.replace(base, delta=delta)))
    for d in (1.0, 2.0, 8.0):
        grid.append(("d", d, dataclasses.replace(base, deadline_factor=d)))
    # mechanism switches (wc = work conservation, rq = §4.3 re-queue),
    # value encodes the pair as 2*wc + rq
    for wc in (True, False):
        for rq in (True, False):
            grid.append(("mech", 2 * wc + rq, dataclasses.replace(
                base, work_conservation=wc, dynamics_requeue=rq)))
    return grid


def run(bench: Bench, engine: str = "numpy"):
    from repro.traces import tiny_trace

    n, ports = (60, 24) if bench.quick else (100, 48)
    trace = tiny_trace(n, ports, seed=0, load=0.8)
    base = SchedulerParams()
    grid = _grid(base)

    t0 = time.perf_counter()
    res = api_run(Scenario(policy="saath", engine=engine, trace=trace,
                           sweep=tuple(p for _, _, p in grid),
                           label="fig14/grid"))
    wall = time.perf_counter() - t0
    record("fig14_grid", res)
    rows = []
    for i, (knob, value, _) in enumerate(grid):
        cct = res.row_cct(i)
        rows.append({"knob": knob, "value": value,
                     "avg_cct": float(np.nanmean(cct)),
                     "p50_cct": float(np.nanpercentile(cct, 50)),
                     "p90_cct": float(np.nanpercentile(cct, 90))})

    # contention axis: A scales the TRACE's arrival rate; Saath side on
    # the Scenario's engine, Aalo host baseline
    for A in (0.5, 1.0, 2.0):
        tr = tiny_trace(n, ports, seed=0, load=0.8, arrival_speedup=A)
        a = api_run(Scenario(policy="aalo", engine="numpy", trace=tr,
                             params=base))
        s = api_run(Scenario(policy="saath", engine=engine, trace=tr,
                             params=base, label=f"fig14/A={A}"))
        sp = percentile_speedup(a.row_cct(), s.row_cct())
        rows.append({"knob": "A", "value": A, "avg_cct": sp["p50"],
                     "p50_cct": sp["p50"], "p90_cct": sp["p90"]})

    emit(f"fig14_sensitivity[{engine}]",
         rows + [{"knob": "wall_s", "value": wall, "avg_cct": len(grid),
                  "p50_cct": float("nan"), "p90_cct": float("nan")}])

    # S-insensitivity: avg CCT varies < 2x across the S grid
    s_rows = [r["avg_cct"] for r in rows if r["knob"] == "S"]
    assert max(s_rows) <= 2.0 * min(s_rows), s_rows
    # mechanisms should not hurt: full SAATH (wc+rq) avg CCT stays
    # within 10% of (and typically beats) the no-mechanism ablation
    mech = {r["value"]: r["avg_cct"] for r in rows if r["knob"] == "mech"}
    assert mech[3] <= 1.1 * mech[0], mech
    # contention claim: speedup at A=2 >= speedup at A=0.5 (more
    # contention -> LCoF pays off more)
    a_lo = next(r for r in rows if r["knob"] == "A" and r["value"] == 0.5)
    a_hi = next(r for r in rows if r["knob"] == "A" and r["value"] == 2.0)
    assert a_hi["p50_cct"] >= a_lo["p50_cct"] * 0.8, (a_lo, a_hi)
    return rows


if __name__ == "__main__":
    run(*cli_bench())
