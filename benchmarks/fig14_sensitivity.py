"""Fig. 14: sensitivity to S (start threshold), E (growth), delta
(sync interval), A (arrival speedup), d (deadline factor).

Key paper claims: Saath insensitive to S (LCoF fixes FIFO's HoL);
both degrade as delta grows; Saath's edge grows with contention (A).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import Bench, emit
from repro.core.params import MB, SchedulerParams
from repro.fabric.metrics import percentile_speedup


def _speedup(bench, params, **trace_kw):
    a = bench.sim("aalo", params, **trace_kw).table.cct
    s = bench.sim("saath", params, **trace_kw).table.cct
    return percentile_speedup(a, s)


def run(bench: Bench):
    rows = []
    base = SchedulerParams()

    for S in (1 * MB, 10 * MB, 100 * MB):
        p = dataclasses.replace(base, start_threshold=S)
        rows.append({"knob": "S", "value": S / MB,
                     **_speedup(bench, p)})
    for E in (2.0, 10.0, 32.0):
        p = dataclasses.replace(base, growth=E)
        rows.append({"knob": "E", "value": E, **_speedup(bench, p)})
    for delta in (8e-3, 64e-3, 256e-3):
        p = dataclasses.replace(base, delta=delta)
        rows.append({"knob": "delta_ms", "value": delta * 1e3,
                     **_speedup(bench, p)})
    for A in (0.5, 1.0, 2.0):
        rows.append({"knob": "A", "value": A,
                     **_speedup(bench, base, arrival_speedup=A)})
    for d in (1.0, 2.0, 8.0):
        p = dataclasses.replace(base, deadline_factor=d)
        a = bench.sim("aalo", base).table.cct
        s = bench.sim("saath", p).table.cct
        rows.append({"knob": "d", "value": d,
                     **percentile_speedup(a, s)})
    emit("fig14_sensitivity", rows)

    # contention claim: speedup at A=2 >= speedup at A=0.5 (more
    # contention -> LCoF pays off more)
    a_lo = next(r for r in rows if r["knob"] == "A" and r["value"] == 0.5)
    a_hi = next(r for r in rows if r["knob"] == "A" and r["value"] == 2.0)
    assert a_hi["p50"] >= a_lo["p50"] * 0.8
    return rows


if __name__ == "__main__":
    run(Bench())
