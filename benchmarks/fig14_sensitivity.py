"""Fig. 14: sensitivity to S (start threshold), E (growth), delta
(sync interval), A (arrival speedup), d (deadline factor).

Key paper claims: Saath insensitive to S (LCoF fixes FIFO's HoL);
both degrade as delta grows; Saath's edge grows with contention (A).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import Bench, cli_bench, emit
from repro.core.params import MB, SchedulerParams
from repro.fabric.metrics import percentile_speedup


def _speedup(bench, params, **trace_kw):
    a = bench.sim("aalo", params, **trace_kw).table.cct
    s = bench.sim("saath", params, **trace_kw).table.cct
    return percentile_speedup(a, s)


def run(bench: Bench, engine: str = "numpy"):
    if engine == "jax":
        return run_jax_sweep(bench)
    rows = []
    base = SchedulerParams()

    for S in (1 * MB, 10 * MB, 100 * MB):
        p = dataclasses.replace(base, start_threshold=S)
        rows.append({"knob": "S", "value": S / MB,
                     **_speedup(bench, p)})
    for E in (2.0, 10.0, 32.0):
        p = dataclasses.replace(base, growth=E)
        rows.append({"knob": "E", "value": E, **_speedup(bench, p)})
    for delta in (8e-3, 64e-3, 256e-3):
        p = dataclasses.replace(base, delta=delta)
        rows.append({"knob": "delta_ms", "value": delta * 1e3,
                     **_speedup(bench, p)})
    for A in (0.5, 1.0, 2.0):
        rows.append({"knob": "A", "value": A,
                     **_speedup(bench, base, arrival_speedup=A)})
    for d in (1.0, 2.0, 8.0):
        p = dataclasses.replace(base, deadline_factor=d)
        a = bench.sim("aalo", base).table.cct
        s = bench.sim("saath", p).table.cct
        rows.append({"knob": "d", "value": d,
                     **percentile_speedup(a, s)})
    emit("fig14_sensitivity", rows)

    # contention claim: speedup at A=2 >= speedup at A=0.5 (more
    # contention -> LCoF pays off more)
    a_lo = next(r for r in rows if r["knob"] == "A" and r["value"] == 0.5)
    a_hi = next(r for r in rows if r["knob"] == "A" and r["value"] == 2.0)
    assert a_hi["p50"] >= a_lo["p50"] * 0.8
    return rows


def run_jax_sweep(bench: Bench):
    """The whole (S, E, delta, d, mechanism-switch) grid on one trace as
    ONE vmapped XLA computation (fabric.jax_engine.simulate_sweep) — the
    paper's Fig. 14 methodology at sweep-in-one-shot cost. The work-
    conservation and §4.3 re-queue switches are traced DynCoordParams
    leaves, so the mechanism ablations ride the same executable as the
    threshold knobs. Reports Saath CCT stats per setting; the
    S-insensitivity claim (LCoF fixes FIFO's HoL blocking) is checked
    directly on the batched results."""
    from repro.fabric import jax_engine
    from repro.traces import tiny_trace

    n, ports = (60, 24) if bench.quick else (100, 48)
    trace = tiny_trace(n, ports, seed=0, load=0.8)
    base = SchedulerParams()
    grid = []
    for S in (1 * MB, 10 * MB, 100 * MB):
        grid.append(("S", S / MB,
                     dataclasses.replace(base, start_threshold=S)))
    for E in (2.0, 10.0, 32.0):
        grid.append(("E", E, dataclasses.replace(base, growth=E)))
    for delta in (8e-3, 64e-3, 256e-3):
        grid.append(("delta_ms", delta * 1e3,
                     dataclasses.replace(base, delta=delta)))
    for d in (1.0, 2.0, 8.0):
        grid.append(("d", d, dataclasses.replace(base, deadline_factor=d)))
    # mechanism switches (wc = work conservation, rq = §4.3 re-queue),
    # value encodes the pair as 2*wc + rq
    for wc in (True, False):
        for rq in (True, False):
            grid.append(("mech", 2 * wc + rq, dataclasses.replace(
                base, work_conservation=wc, dynamics_requeue=rq)))

    t0 = time.perf_counter()
    res = jax_engine.simulate_sweep(trace, [p for _, _, p in grid])
    wall = time.perf_counter() - t0
    C = len(trace.coflows)
    rows = []
    for i, (knob, value, _) in enumerate(grid):
        cct = res.cct[i, :C]
        rows.append({"knob": knob, "value": value,
                     "avg_cct": float(np.nanmean(cct)),
                     "p50_cct": float(np.nanpercentile(cct, 50)),
                     "p90_cct": float(np.nanpercentile(cct, 90))})
    emit("fig14_sensitivity[jax]",
         rows + [{"knob": "wall_s", "value": wall, "avg_cct": len(grid),
                  "p50_cct": float("nan"), "p90_cct": float("nan")}])
    # S-insensitivity: avg CCT varies < 2x across the S grid
    s_rows = [r["avg_cct"] for r in rows if r["knob"] == "S"]
    assert max(s_rows) <= 2.0 * min(s_rows), s_rows
    # mechanisms should not hurt: full SAATH (wc+rq) avg CCT stays
    # within 10% of (and typically beats) the no-mechanism ablation
    mech = {r["value"]: r["avg_cct"] for r in rows if r["knob"] == "mech"}
    assert mech[3] <= 1.1 * mech[0], mech
    return rows


if __name__ == "__main__":
    run(*cli_bench())
