"""Fig. 2: prevalence of the out-of-sync problem under Aalo.

(a) width distribution; (b) flow-length skew; (c) normalized std-dev of
per-flow FCTs under Aalo, split equal/unequal flow lengths.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Bench, cli_bench, emit, pctl
from repro.fabric.metrics import fct_normalized_std, width_size_bins


def run(bench: Bench, engine: str = "numpy"):
    t = bench.run("aalo", record_as="fig2").table()
    widths = t.width
    rows = [{
        "metric": "width",
        "p50": pctl(widths, 50), "p90": pctl(widths, 90),
        "frac_single": float((widths == 1).mean()),
    }]
    dev = fct_normalized_std(t)
    for kind in ("equal", "unequal"):
        d = dev[kind]
        if d.size == 0:
            continue
        rows.append({
            "metric": f"fct_norm_std_{kind}",
            "p50": pctl(d, 50), "p90": pctl(d, 80),
            "frac_single": float((d > 0.39).mean()),
        })
    emit("fig2_out_of_sync", rows)
    # paper: 20% of equal-length coflows see >39% deviation under Aalo
    d = dev["equal"]
    assert d.size and pctl(d, 80) > 0.1, "out-of-sync should be visible"
    return rows


if __name__ == "__main__":
    run(*cli_bench())
