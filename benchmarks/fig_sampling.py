"""Non-clairvoyant Saath: known vs pilot-learned coflow sizes vs Aalo.

The paper assumes the coordinator knows every coflow's flow sizes up
front (clairvoyance); the ISSUE-10 sampling layer drops that
assumption Philae-style (arxiv 2108.11255): a few pilot flows per
coflow finish first and their mean size becomes the coflow's estimate
for the §4.3 re-queue, with plain bytes-sent Eq. 1 placement as the
fallback before the first pilot completes. This driver measures what
the learning costs on the FB-like bench fabric, three lanes per plane:

* known   — clairvoyant Saath (the paper's setting);
* learned — `Scenario(clairvoyance=False)`, sizes from pilot flows;
* aalo    — the non-clairvoyant baseline Saath must beat: the true
  `aalo` host policy on the numpy plane, the coordinated-FIFO ablation
  (lcof/per-flow thresholds off) on the jax plane.

Every cell is recorded to BENCH_api.json via `benchmarks.common.record`
(the clairvoyance flag is part of the scenario hash). The acceptance
gate: learned-size Saath still beats Aalo on average CCT on BOTH
planes — sampling trades a little of the known-size win, not the win.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Bench, cli_bench, emit, record
from repro.api import run as api_run
from repro.core.params import SchedulerParams

AALO_MECH = dict(lcof=False, per_flow_threshold=False)


def run(bench: Bench, engine: str = "jax"):
    # §4.3 re-queueing is where clairvoyance enters the schedule; the
    # sampling estimator feeds exactly that path, so it must be on
    p = SchedulerParams(dynamics_requeue=True)
    rows = []
    avg = {}

    jax_lanes = {"known": dict(clairvoyance=True),
                 "learned": dict(clairvoyance=False),
                 "aalo-like": dict(mechanisms=AALO_MECH)}
    np_lanes = {"known": ("saath", dict(clairvoyance=True)),
                "learned": ("saath", dict(clairvoyance=False)),
                "aalo": ("aalo", dict())}

    for lane, kw in jax_lanes.items():
        sc = dataclasses.replace(
            bench.scenario("saath", engine="jax", params=p,
                           label=f"sampling-{lane}"), **kw)
        res = api_run(sc)
        record("fig_sampling_jax", res, lane=lane)
        avg[("jax", lane)] = float(np.nanmean(res.avg_cct))
        rows.append({"engine": "jax", "lane": lane,
                     "avg_cct": avg[("jax", lane)],
                     "wall_seconds": res.wall_seconds})

    for lane, (policy, kw) in np_lanes.items():
        sc = dataclasses.replace(
            bench.scenario(policy, engine="numpy", params=p,
                           label=f"sampling-{lane}"), **kw)
        res = api_run(sc)
        record("fig_sampling_numpy", res, lane=lane)
        avg[("numpy", lane)] = float(np.nanmean(res.avg_cct))
        rows.append({"engine": "numpy", "lane": lane,
                     "avg_cct": avg[("numpy", lane)],
                     "wall_seconds": res.wall_seconds})

    emit("fig_sampling", rows)

    # the acceptance gate: losing clairvoyance must not lose the win —
    # pilot-learned Saath still beats the Aalo lane on avg CCT
    for eng, aalo in (("jax", "aalo-like"), ("numpy", "aalo")):
        assert avg[(eng, "learned")] < avg[(eng, aalo)], \
            f"{eng}: learned Saath should beat Aalo: " \
            f"learned={avg[(eng, 'learned')]:.4g} " \
            f"aalo={avg[(eng, aalo)]:.4g}"
    return rows


if __name__ == "__main__":
    bench, engine = cli_bench()
    run(bench, engine)
