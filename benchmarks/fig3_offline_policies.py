"""Fig. 3: offline SCF vs SRTF vs LWTF speedups over Aalo (sizes known).

LWTF (t*k: duration x contention) should beat SCF/SRTF — the paper's
evidence that contention matters.
"""
from __future__ import annotations

from benchmarks.common import Bench, cli_bench, emit
from repro.fabric.metrics import percentile_speedup


def run(bench: Bench, engine: str = "numpy"):
    base = bench.run("aalo").row_cct()
    rows = []
    for pol in ("scf", "srtf", "lwtf"):
        s = percentile_speedup(base, bench.run(pol).row_cct())
        rows.append({"policy": pol, **{k: v for k, v in s.items()}})
    emit("fig3_offline", rows)
    lwtf = next(r for r in rows if r["policy"] == "lwtf")
    scf = next(r for r in rows if r["policy"] == "scf")
    assert lwtf["overall"] >= scf["overall"] * 0.95, (
        "LWTF should be competitive with SCF overall")
    return rows


if __name__ == "__main__":
    run(*cli_bench())
