"""Table 2: coordinator scheduling cost.

Times (a) the host-reference Saath replay on the bench fabric (paper's
150-port scale), (b) the jitted JAX coordinator at production scale
(512 ports x up to 4096 coflows) with the LCoF/contention sub-step
broken out, and (c) the amortized per-trace-step cost of a whole fleet
replay through `repro.api.run` on the Scenario's engine. The paper's
C++ coordinator: 0.57 ms avg / 2.85 ms P90 at ~150 ports.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Bench, cli_bench, emit, record
from repro.api import Scenario
from repro.api import run as api_run
from repro.core import jax_coordinator as jc
from repro.core.params import SchedulerParams
from repro.kernels import ops


def _time(fn, n=20, warmup=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def run(bench: Bench, engine: str = "numpy"):
    import jax
    import jax.numpy as jnp

    rows = []

    # (a) host reference on the replay fabric
    res = bench.run("saath")
    rows.append({
        "impl": "numpy-replay", "C": int(res.num_coflows[0]),
        "P": res.table(0).num_ports,
        "avg_ms": 1e3 * res.sched_seconds / max(res.steps, 1),
        "note": "full Fig.7 step incl. WC",
    })

    # (b) jitted coordinator at production scales
    rng = np.random.default_rng(0)
    for C, P in ((512, 150), (2048, 512), (4096, 512)):
        cp = jc.CoordParams.from_params(SchedulerParams())
        state = jc.init_state(C)
        batch = jc.CoflowBatch(
            active=jnp.asarray(rng.uniform(size=C) < 0.7),
            arrival=jnp.arange(C, dtype=jnp.int32),
            m=jnp.asarray(rng.uniform(0, 1e8, C), jnp.float32),
            width=jnp.asarray(rng.integers(1, 64, C), jnp.int32),
            cnt_s=jnp.asarray((rng.uniform(size=(C, P)) < 0.05) *
                              rng.integers(1, 4, (C, P)), jnp.float32),
            cnt_r=jnp.asarray((rng.uniform(size=(C, P)) < 0.05) *
                              rng.integers(1, 4, (C, P)), jnp.float32),
            bw_s=jnp.full((P,), 1e9, jnp.float32),
            bw_r=jnp.full((P,), 1e9, jnp.float32),
        )

        def tick():
            s, out = jc.schedule_tick(state, batch, jnp.float32(1.0),
                                      cp=cp)
            jax.block_until_ready(out["rate"])

        dt = _time(tick)
        # LCoF contention sub-step alone (the Pallas kernel's job).
        # Inputs passed as args (a closure would constant-fold the jit).
        a_s = (batch.cnt_s > 0).astype(jnp.float32)
        a_r = (batch.cnt_r > 0).astype(jnp.float32)
        contention_only = jax.jit(
            lambda s_, r_, a_: ops.contention(s_, r_, a_, force="ref"))
        dt_k = _time(lambda: jax.block_until_ready(
            contention_only(a_s, a_r, batch.active)))
        rows.append({"impl": "jax-jit", "C": C, "P": P,
                     "avg_ms": dt * 1e3,
                     "note": f"contention={dt_k * 1e3:.3f}ms"})
    rows += run_engine_throughput(bench, engine)
    emit(f"table2_coordinator[{engine}]", rows)
    big = next(r for r in rows if r.get("C") == 4096)
    assert big["avg_ms"] < 1e3, "coordinator tick should be sub-second"
    return rows


def run_engine_throughput(bench: Bench, engine: str):
    """Amortized per-trace coordinator-step cost of a whole-fleet replay
    through the front door on the Scenario's engine (warm timing splits
    compile cost out on jax)."""
    from repro.traces import tiny_trace

    p = SchedulerParams()
    n, ports, fleet = (60, 24, 16) if bench.quick else (120, 48, 32)
    traces = tuple(tiny_trace(n, ports, seed=s, load=0.8)
                   for s in range(fleet))
    res = api_run(Scenario(policy="saath", engine=engine, params=p,
                           traces=traces, warm_timing=True,
                           label="table2/fleet"))
    record("table2_fleet", res)
    return [{"impl": f"{engine}-batched-engine", "C": n, "P": ports,
             "avg_ms": 1e3 * res.wall_seconds / max(res.steps, 1),
             "note": f"fleet={fleet} steps={res.steps} "
                     f"wall={res.wall_seconds:.2f}s "
                     f"(amortized per trace-step)"}]


if __name__ == "__main__":
    run(*cli_bench())
