"""Fig. 10: design-component breakdown — A/N, A/N+P/F, full Saath
(LCoF), each vs Aalo. Paper (FB): 1.13x -> 1.3x -> 1.53x median.

--engine=jax replays the Saath side of every ablation through the
batched XLA fleet engine: the lcof / per_flow_threshold switches are
traced `DynCoordParams` leaves, so the two ablated variants share one
compiled executable (full SAATH compiles a second, smaller one — its
step omits the Aalo-queue event horizon entirely). The ablation
ordering assertion guards the jitted ablation paths end to end.
"""
from __future__ import annotations

from benchmarks.common import Bench, cli_bench, emit
from repro.fabric.metrics import percentile_speedup

VARIANTS = [
    ("A/N", dict(lcof=False, per_flow_threshold=False)),
    ("A/N+PF", dict(lcof=False, per_flow_threshold=True)),
    ("SAATH", dict(lcof=True, per_flow_threshold=True)),
]


def run(bench: Bench, engine: str = "numpy"):
    base = bench.sim("aalo").table.cct
    rows = []
    if engine == "jax":
        import numpy as np

        from repro.core.params import SchedulerParams
        from repro.fabric import jax_engine

        params = SchedulerParams()
        trace = bench.trace()
        C = len(trace.coflows)
        for name, kw in VARIANTS:
            res = jax_engine.simulate_batch([trace], params, **kw)
            cct = np.full(base.shape, np.nan)
            cct[:C] = res.cct[0, :C]
            rows.append({"variant": name, **percentile_speedup(base, cct)})
    else:
        for name, kw in VARIANTS:
            cct = bench.sim("saath", policy_kwargs=kw).table.cct
            rows.append({"variant": name, **percentile_speedup(base, cct)})
    emit(f"fig10_breakdown[{engine}]", rows)
    # the paper's Fig. 10 claim: each design component helps at p50
    # (5% slack absorbs replay noise on the quick fabric)
    an, anpf, saath = (r["p50"] for r in rows)
    assert anpf >= an * 0.95, ("A/N+PF should not lose to A/N", rows)
    assert saath >= anpf * 0.95, ("SAATH should not lose to A/N+PF", rows)
    assert saath >= an * 0.95, (
        "full SAATH should not lose to A/N-only at p50", rows)
    return rows


if __name__ == "__main__":
    run(*cli_bench())
