"""Fig. 10: design-component breakdown — A/N, A/N+P/F, full Saath
(LCoF), each vs Aalo. Paper (FB): 1.13x -> 1.3x -> 1.53x median.

The ablation switches are the shared `repro.api` mechanism names: on
the numpy engine they become Saath ctor kwargs, on the jax engine they
are traced/structure switches of the batched fleet engine — one
Scenario field either way, no per-driver engine branching. The ablation
ordering assertion guards both planes end to end.
"""
from __future__ import annotations

from benchmarks.common import Bench, cli_bench, emit
from repro.fabric.metrics import percentile_speedup

VARIANTS = [
    ("A/N", dict(lcof=False, per_flow_threshold=False)),
    ("A/N+PF", dict(lcof=False, per_flow_threshold=True)),
    ("SAATH", dict(lcof=True, per_flow_threshold=True)),
]


def run(bench: Bench, engine: str = "numpy"):
    base = bench.run("aalo").row_cct()
    rows = []
    for name, mech in VARIANTS:
        cct = bench.run("saath", engine=engine, mechanisms=mech,
                        label=f"fig10/{name}").row_cct()
        rows.append({"variant": name, **percentile_speedup(base, cct)})
    emit(f"fig10_breakdown[{engine}]", rows)
    # the paper's Fig. 10 claim: each design component helps at p50
    # (5% slack absorbs replay noise on the quick fabric)
    an, anpf, saath = (r["p50"] for r in rows)
    assert anpf >= an * 0.95, ("A/N+PF should not lose to A/N", rows)
    assert saath >= anpf * 0.95, ("SAATH should not lose to A/N+PF", rows)
    assert saath >= an * 0.95, (
        "full SAATH should not lose to A/N-only at p50", rows)
    return rows


if __name__ == "__main__":
    run(*cli_bench())
