"""Fig. 10: design-component breakdown — A/N, A/N+P/F, full Saath
(LCoF), each vs Aalo. Paper (FB): 1.13x -> 1.3x -> 1.53x median."""
from __future__ import annotations

from benchmarks.common import Bench, emit
from repro.fabric.metrics import percentile_speedup

VARIANTS = [
    ("A/N", dict(lcof=False, per_flow_threshold=False)),
    ("A/N+PF", dict(lcof=False, per_flow_threshold=True)),
    ("SAATH", dict(lcof=True, per_flow_threshold=True)),
]


def run(bench: Bench):
    base = bench.sim("aalo").table.cct
    rows = []
    for name, kw in VARIANTS:
        cct = bench.sim("saath", policy_kwargs=kw).table.cct
        s = percentile_speedup(base, cct)
        rows.append({"variant": name, **s})
    emit("fig10_breakdown", rows)
    assert rows[-1]["p50"] >= rows[0]["p50"] * 0.95, (
        "full SAATH should not lose to A/N-only at p50")
    return rows


if __name__ == "__main__":
    run(Bench())
