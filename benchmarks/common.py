"""Shared benchmark machinery: Scenario cache, CSV + BENCH_api.json emit.

Every driver goes through `Bench.run`, which builds a `repro.api.Scenario`
from the bench fabric spec and caches the normalized `Result` by scenario
hash — the engine is plain scenario data, so drivers never branch on it.
Uncached runs are appended to BENCH_api.json (scenario hash, engine,
wall-clock, compile time, CCT stats) so the perf trajectory is recorded
across PRs.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import sys
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.api import Result, Scenario
from repro.api import run as api_run
from repro.core.params import SchedulerParams

# default benchmark fabric: FB-like (paper: 526 coflows / 150 ports);
# --quick shrinks it so the full suite stays minutes on one CPU core.
FULL = dict(num_coflows=526, num_ports=150, seed=0)
QUICK = dict(num_coflows=240, num_ports=100, seed=0)

BENCH_JSON = os.environ.get("SAATH_BENCH_JSON", "BENCH_api.json")


def record(name: str, result: Result, row: int = 0, **extra) -> dict:
    """Append one machine-readable perf record to BENCH_api.json
    (idempotent per (bench, scenario, engine, row) key)."""
    rec = {"bench": name, **result.summary(row), **extra}
    rec = {k: (None if isinstance(v, float) and not math.isfinite(v)
               else v) for k, v in rec.items()}
    key = (rec["bench"], rec["scenario"], rec["engine"], rec["row"])
    existing = []
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as fh:
                existing = json.load(fh)
        except (json.JSONDecodeError, OSError):
            existing = []
    existing = [r for r in existing
                if (r.get("bench"), r.get("scenario"), r.get("engine"),
                    r.get("row")) != key]
    existing.append(rec)
    with open(BENCH_JSON, "w") as fh:
        json.dump(existing, fh, indent=1)
    return rec


@dataclasses.dataclass
class Bench:
    quick: bool = True
    _cache: Dict[str, Result] = dataclasses.field(default_factory=dict)
    _trace_kw: dict = None

    def __post_init__(self):
        self._trace_kw = QUICK if self.quick else FULL

    def scenario(self, policy: str = "saath", *, engine: str = "numpy",
                 params: SchedulerParams | None = None,
                 mechanisms: dict | None = None,
                 policy_kwargs: dict | None = None,
                 label: str = "", **trace_overrides) -> Scenario:
        """A Scenario over the bench fabric (QUICK/FULL synth spec plus
        per-driver overrides)."""
        synth = dict(self._trace_kw)
        synth.update(trace_overrides)
        return Scenario(policy=policy, engine=engine,
                        params=params or SchedulerParams(), synth=synth,
                        mechanisms=mechanisms, policy_kwargs=policy_kwargs,
                        label=label)

    def run(self, policy: str = "saath", *,
            scenario: Optional[Scenario] = None, record_as: str = "",
            **kw) -> Result:
        """Run (or fetch the cached) Result for a scenario. `record_as`
        names the BENCH_api.json record for uncached headline runs."""
        sc = scenario if scenario is not None else \
            self.scenario(policy, **kw)
        key = sc.hash()
        if key not in self._cache:
            t0 = time.perf_counter()
            self._cache[key] = api_run(sc)
            print(f"#   ran {sc.policy}[{sc.engine}]"
                  f"{'/' + sc.label if sc.label else ''} in "
                  f"{time.perf_counter() - t0:.1f}s", file=sys.stderr)
            if record_as:
                record(record_as, self._cache[key])
        return self._cache[key]

    def trace(self, **overrides):
        """The bench fabric trace itself (for drivers that inspect it)."""
        from repro.traces import fb_like_trace

        kw = dict(self._trace_kw)
        kw.update(overrides)
        return fb_like_trace(**kw)

def cli_bench(argv=None) -> "Tuple[Bench, str]":
    """Common driver CLI: --full fabric scale, --engine numpy|jax.

    The engine is scenario DATA, not a code path: drivers put it in the
    Saath-side Scenario and the repro.api dispatcher routes it.
    """
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="FB-scale fabric (526 coflows x 150 ports)")
    ap.add_argument("--engine", choices=("numpy", "jax"), default="numpy",
                    help="replay engine for the Saath side")
    args = ap.parse_args(argv)
    return Bench(quick=not args.full), args.engine


def emit(name: str, rows):
    """CSV rows: list of dicts with consistent keys."""
    if not rows:
        print(f"{name},EMPTY")
        return
    keys = list(rows[0])
    print(f"# {name}")
    print(",".join(["bench"] + keys))
    for r in rows:
        print(",".join([name] + [_fmt(r[k]) for k in keys]))


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def pctl(x, q):
    return float(np.nanpercentile(np.asarray(x, float), q))
