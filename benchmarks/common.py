"""Shared benchmark machinery: trace + simulation cache, CSV emit."""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Dict, Tuple

import numpy as np

from repro.core.params import SchedulerParams
from repro.fabric.engine import SimResult, simulate
from repro.traces import fb_like_trace

# default benchmark fabric: FB-like (paper: 526 coflows / 150 ports);
# --quick shrinks it so the full suite stays minutes on one CPU core.
FULL = dict(num_coflows=526, num_ports=150, seed=0)
QUICK = dict(num_coflows=240, num_ports=100, seed=0)


@dataclasses.dataclass
class Bench:
    quick: bool = True
    _sims: Dict[Tuple, SimResult] = dataclasses.field(default_factory=dict)
    _trace_kw: dict = None

    def __post_init__(self):
        self._trace_kw = QUICK if self.quick else FULL

    def trace(self, **overrides):
        kw = dict(self._trace_kw)
        kw.update(overrides)
        return fb_like_trace(**kw)

    def sim(self, policy: str, params: SchedulerParams | None = None,
            policy_kwargs: dict | None = None, **trace_overrides
            ) -> SimResult:
        params = params or SchedulerParams()
        key = (policy, params, tuple(sorted((policy_kwargs or {}).items())),
               tuple(sorted(trace_overrides.items())))
        if key not in self._sims:
            t0 = time.perf_counter()
            self._sims[key] = simulate(self.trace(**trace_overrides),
                                       policy, params,
                                       policy_kwargs=policy_kwargs)
            print(f"#   simulated {policy} "
                  f"{dict(policy_kwargs or {})} in "
                  f"{time.perf_counter() - t0:.1f}s", file=sys.stderr)
        return self._sims[key]


def cli_bench(argv=None) -> "Tuple[Bench, str]":
    """Common driver CLI: --full fabric scale, --engine numpy|jax.

    `numpy` is the event-driven reference replay; `jax` adds the batched
    XLA fleet-engine path (fabric.jax_engine) where the driver supports
    it.
    """
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="FB-scale fabric (526 coflows x 150 ports)")
    ap.add_argument("--engine", choices=("numpy", "jax"), default="numpy",
                    help="replay engine for the Saath side")
    args = ap.parse_args(argv)
    return Bench(quick=not args.full), args.engine


def emit(name: str, rows):
    """CSV rows: list of dicts with consistent keys."""
    if not rows:
        print(f"{name},EMPTY")
        return
    keys = list(rows[0])
    print(f"# {name}")
    print(",".join(["bench"] + keys))
    for r in rows:
        print(",".join([name] + [_fmt(r[k]) for k in keys]))


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def pctl(x, q):
    return float(np.nanpercentile(np.asarray(x, float), q))
