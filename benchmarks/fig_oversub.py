"""Oversubscription sweep: CCT degradation under a leaf-spine fabric.

Beyond-paper driver for the ISSUE-9 FabricModel layer: the paper's
big-switch assumption (§3) is exact at 1:1 oversubscription — the
uplink residual always dominates the sum of its subtended port
residuals — but real leaf-spine fabrics run 2:1..4:1, where the shared
uplinks/downlinks bind and every policy's CCTs stretch. This driver
sweeps oversub x policy lane through BOTH planes:

* jax lane: a fleet of traces replayed through the vmapped engine, one
  `Scenario(topology=LeafSpine(...))` per (oversub, policy) cell —
  "aalo" here is the coordinated-FIFO ablation of the jitted Saath
  coordinator (lcof=0, per-flow thresholds off), the jax plane's
  closest Aalo analogue;
* numpy lane: the event-driven reference on one trace per cell (the
  true `aalo` host policy), gating that the degradation is a property
  of the fabric model, not of one engine.

Every cell is recorded to BENCH_api.json via `benchmarks.common.record`
(keyed by scenario hash — the topology is part of the hash).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Bench, cli_bench, emit, record
from repro.api import Scenario
from repro.api import run as api_run
from repro.core.params import SchedulerParams
from repro.fabric.topology import LeafSpine
from repro.traces.synth import tiny_trace

OVERSUBS = (1.0, 2.0, 4.0)
HOSTS_PER_LEAF = 4


def _fleet(quick: bool):
    n = 4 if quick else 16
    return tuple(tiny_trace(30, 16, seed=s, load=0.8) for s in range(n))


def run(bench: Bench, engine: str = "jax"):
    p = SchedulerParams()
    traces = _fleet(bench.quick)
    rows = []

    # jax lane: fleet x (saath, coordinated-FIFO ablation) x oversub
    lanes = {"saath": None,
             "aalo-like": dict(lcof=False, per_flow_threshold=False)}
    jax_avg = {}
    for lane, mech in lanes.items():
        for ov in OVERSUBS:
            sc = Scenario(policy="saath", engine="jax", params=p,
                          traces=traces, mechanisms=mech,
                          topology=LeafSpine(
                              hosts_per_leaf=HOSTS_PER_LEAF, oversub=ov),
                          label=f"oversub-{lane}-{ov:g}")
            res = api_run(sc)
            record("fig_oversub_jax", res, lane=lane, oversub=ov)
            avg = float(np.nanmean(res.avg_cct))
            jax_avg[(lane, ov)] = avg
            rows.append({"engine": "jax", "lane": lane, "oversub": ov,
                         "avg_cct": avg,
                         "wall_seconds": res.wall_seconds})

    # numpy lane: one trace, the true host policies
    for lane in ("saath", "aalo"):
        for ov in OVERSUBS:
            sc = Scenario(policy=lane, engine="numpy", params=p,
                          trace=traces[0],
                          topology=LeafSpine(
                              hosts_per_leaf=HOSTS_PER_LEAF, oversub=ov),
                          label=f"oversub-{lane}-{ov:g}")
            res = api_run(sc)
            record("fig_oversub_numpy", res, lane=lane, oversub=ov)
            rows.append({"engine": "numpy", "lane": lane, "oversub": ov,
                         "avg_cct": float(np.nanmean(res.avg_cct)),
                         "wall_seconds": res.wall_seconds})

    emit("fig_oversub", rows)

    # the fabric model must BITE: 4:1 visibly worse than 1:1, per lane,
    # per plane (this is the ISSUE-9 acceptance gate)
    for eng in ("jax", "numpy"):
        for lane in ({"jax": ("saath", "aalo-like"),
                      "numpy": ("saath", "aalo")}[eng]):
            r = {row["oversub"]: row["avg_cct"] for row in rows
                 if row["engine"] == eng and row["lane"] == lane}
            assert r[4.0] > 1.1 * r[1.0], \
                f"{eng}/{lane}: 4:1 should degrade CCTs: {r}"
    return rows


if __name__ == "__main__":
    bench, engine = cli_bench()
    run(bench, engine)
