import repro.launch.dryrun as dr  # noqa: F401  (sets XLA_FLAGS first)

import argparse     # noqa: E402
import json         # noqa: E402
import os           # noqa: E402
import time         # noqa: E402

from benchmarks import hlo_analysis as ha          # noqa: E402
from repro.configs import (ARCH_IDS, SHAPES,       # noqa: E402
                           cell_is_runnable, get_config)

"""§Roofline driver: per (arch x shape) on the single-pod mesh, lower +
compile the cell, then derive the three roofline terms from the HLO with
trip-count-aware counting (hlo_analysis.py):

    compute    = HLO_FLOPs / peak ;  memory = HLO_bytes / HBM_bw ;
    collective = link_bytes / ICI_bw      (all per device, seconds)

plus MODEL_FLOPS = 6·N_active·D and the useful-compute ratio.
Results: experiments/roofline/<cell>.json + a markdown table on stdout.

    PYTHONPATH=src:. python -m benchmarks.roofline --all
"""

NDEV = 256  # single-pod


def roofline_cell(arch: str, shape_name: str) -> dict:
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name}
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        rec["skipped"] = why
        return rec
    lowered, info = dr.lower_cell(arch, shape_name, multi_pod=False)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    res = ha.analyze(hlo, NDEV)
    rec.update({k: res[k] for k in ("flops", "hbm_bytes",
                                    "hbm_bytes_kernelized")})
    rec["collective_bytes"] = res["collective_bytes"]
    rec["terms"] = ha.roofline_terms(res)
    rec["terms_raw_mem"] = ha.roofline_terms(res, kernelized=False)
    mf = ha.model_flops(cfg, shape)
    rec["model_flops_global"] = mf
    rec["model_flops_per_dev"] = mf / NDEV
    rec["useful_ratio"] = (mf / NDEV) / max(res["flops"], 1.0)
    # roofline fraction. Train/prefill (compute-shaped work): useful-
    # flops time over the achievable step time (the dominant term sets
    # the clock). Decode (bandwidth-shaped): required bytes (weights +
    # cache, read once) over the bytes actually moved.
    if shape.kind == "decode":
        need = ha.model_min_bytes(cfg, shape) / NDEV
        rec["min_bytes_per_dev"] = need
        rec["roofline_fraction"] = need / max(
            res["hbm_bytes_kernelized"], 1.0)
    else:
        t_use = (mf / NDEV) / ha.HW["peak_flops"]
        t_step = max(rec["terms"]["compute_s"], rec["terms"]["memory_s"],
                     rec["terms"]["collective_s"])
        rec["roofline_fraction"] = t_use / max(t_step, 1e-12)
    mem = compiled.memory_analysis()
    if mem is not None:
        rec["temp_bytes"] = int(getattr(mem, "temp_size_in_bytes", -1))
        rec["arg_bytes"] = int(getattr(mem, "argument_size_in_bytes", -1))
    rec["seconds"] = round(time.time() - t0, 1)
    return rec


def fmt_row(rec) -> str:
    if "skipped" in rec:
        return (f"| {rec['arch']} | {rec['shape']} | — | — | — | — | — | "
                f"skip |")
    t = rec["terms"]
    return ("| {arch} | {shape} | {c:.3f} | {m:.3f} | {n:.3f} | {b} | "
            "{u:.2f} | {rf:.1%} |".format(
                arch=rec["arch"], shape=rec["shape"], c=t["compute_s"],
                m=t["memory_s"], n=t["collective_s"], b=t["bottleneck"],
                u=rec["useful_ratio"], rf=rec["roofline_fraction"]))


HEADER = ("| arch | shape | compute_s | memory_s | collective_s | "
          "bottleneck | useful | roofline |\n"
          "|---|---|---|---|---|---|---|---|")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/roofline")
    args = ap.parse_args()
    cells = ([(a, s) for a in ARCH_IDS for s in SHAPES] if args.all
             else [(args.arch, args.shape)])
    os.makedirs(args.out, exist_ok=True)
    print(HEADER)
    for arch, shape in cells:
        try:
            rec = roofline_cell(arch, shape)
        except Exception as e:  # noqa: BLE001
            rec = {"arch": arch, "shape": shape, "error": str(e)[:500]}
        tag = f"{ARCH_IDS.get(arch, arch)}.{shape}"
        with open(os.path.join(args.out, tag + ".json"), "w") as fh:
            json.dump(rec, fh, indent=1)
        print(fmt_row(rec) if "error" not in rec else
              f"| {arch} | {shape} | ERROR {rec['error'][:60]} |",
              flush=True)


if __name__ == "__main__":
    main()
