"""Fig. 13: FCT deviation (out-of-sync) collapses under Saath vs Aalo.

--engine=jax replays the Saath side through the batched XLA fleet
engine (`jax_engine.run_to_table`) — the per-flow FCTs the deviation
metric consumes are recorded algebraically by the traced tick, so the
jitted path reproduces the out-of-sync collapse, not just mean CCTs.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Bench, cli_bench, emit, pctl
from repro.fabric.metrics import fct_normalized_std


def _saath_table(bench: Bench, engine: str):
    if engine == "jax":
        from repro.core.params import SchedulerParams
        from repro.fabric import jax_engine

        table, _ = jax_engine.run_to_table(bench.trace(), SchedulerParams())
        return table
    return bench.sim("saath").table


def run(bench: Bench, engine: str = "numpy"):
    rows = []
    devs = {}
    for pol in ("aalo", "saath"):
        table = _saath_table(bench, engine) if pol == "saath" \
            else bench.sim(pol).table
        dev = fct_normalized_std(table)
        devs[pol] = dev
        for kind in ("equal", "unequal"):
            d = dev[kind]
            if d.size == 0:
                continue
            rows.append({
                "policy": pol, "kind": kind,
                "frac_zero": float((d < 1e-6).mean()),
                "frac_under_10pct": float((d < 0.10).mean()),
                "p50": pctl(d, 50),
            })
    emit(f"fig13_fct_deviation[{engine}]", rows)
    a = devs["aalo"]["equal"]
    s = devs["saath"]["equal"]
    if a.size and s.size:
        assert (s < 0.10).mean() >= (a < 0.10).mean(), (
            "Saath should reduce FCT deviation for equal-length coflows")
    return rows


if __name__ == "__main__":
    run(*cli_bench())
