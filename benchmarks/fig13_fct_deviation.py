"""Fig. 13: FCT deviation (out-of-sync) collapses under Saath vs Aalo.

The per-flow FCTs the deviation metric consumes are part of the
normalized `Result` on both engines (the jax tick records them
algebraically), so the Saath side just takes the Scenario's engine.
"""
from __future__ import annotations

from benchmarks.common import Bench, cli_bench, emit, pctl
from repro.fabric.metrics import fct_normalized_std


def run(bench: Bench, engine: str = "numpy"):
    rows = []
    devs = {}
    for pol in ("aalo", "saath"):
        table = bench.run(pol, engine=engine if pol == "saath"
                          else "numpy").table()
        dev = fct_normalized_std(table)
        devs[pol] = dev
        for kind in ("equal", "unequal"):
            d = dev[kind]
            if d.size == 0:
                continue
            rows.append({
                "policy": pol, "kind": kind,
                "frac_zero": float((d < 1e-6).mean()),
                "frac_under_10pct": float((d < 0.10).mean()),
                "p50": pctl(d, 50),
            })
    emit(f"fig13_fct_deviation[{engine}]", rows)
    a = devs["aalo"]["equal"]
    s = devs["saath"]["equal"]
    if a.size and s.size:
        assert (s < 0.10).mean() >= (a < 0.10).mean(), (
            "Saath should reduce FCT deviation for equal-length coflows")
    return rows


if __name__ == "__main__":
    run(*cli_bench())
