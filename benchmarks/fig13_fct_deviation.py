"""Fig. 13: FCT deviation (out-of-sync) collapses under Saath vs Aalo."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Bench, emit, pctl
from repro.fabric.metrics import fct_normalized_std


def run(bench: Bench):
    rows = []
    devs = {}
    for pol in ("aalo", "saath"):
        dev = fct_normalized_std(bench.sim(pol).table)
        devs[pol] = dev
        for kind in ("equal", "unequal"):
            d = dev[kind]
            if d.size == 0:
                continue
            rows.append({
                "policy": pol, "kind": kind,
                "frac_zero": float((d < 1e-6).mean()),
                "frac_under_10pct": float((d < 0.10).mean()),
                "p50": pctl(d, 50),
            })
    emit("fig13_fct_deviation", rows)
    a = devs["aalo"]["equal"]
    s = devs["saath"]["equal"]
    if a.size and s.size:
        assert (s < 0.10).mean() >= (a < 0.10).mean(), (
            "Saath should reduce FCT deviation for equal-length coflows")
    return rows


if __name__ == "__main__":
    run(Bench())
