"""Pool throughput: N concurrent SaathSessions on one slab vs N
sequential standalone sessions (the ISSUE-4 acceptance gate, in the
spirit of Table 2's coordinator-cost-under-load measurement).

Every session replays the same-shape (different-seed) online workload:
all coflows submitted up front, then fixed `--step` advances until the
session drains. The SEQUENTIAL baseline drives N standalone sessions
one after another (each its own single-row slab, N dispatch chains per
step); the POOL drives one `SessionPool` whose `advance` moves all N
rows with one vmapped dispatch chain per step. Per-session CCTs must
be bitwise identical between the two — batching changes the dispatch
count, never the arithmetic — and the pooled fleet must be at least
``SAATH_POOL_MIN_SPEEDUP`` (default 4.0) times faster end-to-end.
The amortization scales with fleet width — the 4x gate is calibrated
for the default 16 sessions; lower the env var for narrower runs (CI
runs 8 sessions at 2x on shared runners).

The device-resident slab contract (ISSUE 5) is gated here too: the
whole pooled drive performs exactly ONE full slab upload (the initial
build) — every later advance either moves nothing (clean rows) or
dirty-row scatters — and `pool.io`'s transfer accounting is printed
and recorded so the host-traffic trajectory is tracked across PRs.

Records (benchmarks.common.record -> BENCH_api.json): wall clocks for
both drives, compile/warmup split, sessions/sec, the speedup, and the
shard/async-dispatch configuration.

    PYTHONPATH=src python -m benchmarks.pool_throughput [--sessions 16]
    PYTHONPATH=src python -m benchmarks.pool_throughput --shards 4

`--shards N` drives the pooled fleet on an N-device sharded slab (the
ISSUE-6 pmap dispatch path); on CPU the forced host devices are set up
automatically when XLA_FLAGS isn't already pinned by the caller.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __name__ == "__main__" and "--shards" in sys.argv \
        and "XLA_FLAGS" not in os.environ:
    # jax locks the device count at first initialization (triggered by
    # the repro.api import below) — a sharded run must force the host
    # devices BEFORE that
    _n = int(sys.argv[sys.argv.index("--shards") + 1])
    if _n > 1:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={_n}"

import numpy as np

from benchmarks.common import record
from repro.api import SaathSession, SessionPool, result_from_completions
from repro.core.coflow import Coflow, Flow
from repro.core.params import SchedulerParams

# a serving-style fabric: narrow coflows (collective-sized widths) on
# a small slab, many advances — the regime where per-dispatch fixed
# cost dominates per-lane compute, i.e. exactly what batching tenants
# on one slab amortizes (DESIGN.md §3's op-overhead argument, applied
# to whole sessions)
PARAMS = SchedulerParams(port_bw=1.0, delta=1e-2, start_threshold=4.0,
                         growth=4.0, num_queues=5)
PORTS = 12


def _workload(seed: int, n_coflows: int):
    rng = np.random.default_rng(seed)
    cfs, fid = [], 0
    for c in range(n_coflows):
        w = int(rng.integers(1, 4))
        flows = [Flow(fid + i, int(rng.integers(0, PORTS)),
                      int(rng.integers(0, PORTS)),
                      float(rng.uniform(1.0, 12.0))) for i in range(w)]
        fid += w
        cfs.append(Coflow(c, float(rng.uniform(0.0, 5.0)), flows))
    return cfs


def _workloads(n_sessions: int, n_coflows: int, seed: int):
    """One arrival stream per session: same shape, different seeds, so
    every row does comparable work but takes its own trajectory."""
    return [_workload(seed + i, n_coflows) for i in range(n_sessions)]


def _drive(sessions, advance_all, step: float, max_steps: int = 4000):
    """Advance until every session drains; returns per-session
    {handle: (cct, fct-tuple)} dicts plus session 0's raw
    `CompletedCoflow`s (the representative stream the BENCH record
    normalizes — no extra replay needed)."""
    out = [dict() for _ in sessions]
    raw0 = []
    for _ in range(max_steps):
        advance_all(step)
        live = 0
        for i, s in enumerate(sessions):
            done = s.poll()
            if i == 0:
                raw0 += done
            out[i].update({d.handle: (d.cct, tuple(d.fct))
                           for d in done})
            live += s.num_live
        if not live:
            return out, raw0
    raise RuntimeError(f"workload failed to drain in {max_steps} steps")


def run_sequential(traces, step: float):
    sessions = [SaathSession(PARAMS, num_ports=PORTS, backend="jax")
                for _ in traces]
    for s, tr in zip(sessions, traces):
        s.submit(sorted(tr, key=lambda c: (c.arrival, c.cid)))
    t0 = time.perf_counter()

    def advance_all(dt):
        for s in sessions:
            s.advance(dt)

    ccts, raw0 = _drive(sessions, advance_all, step)
    return ccts, raw0, time.perf_counter() - t0


def run_pool(traces, step: float, shards: int = 1,
             async_dispatch: bool = True):
    pool = SessionPool(PARAMS, num_ports=PORTS,
                       max_sessions=len(traces), shards=shards,
                       async_dispatch=async_dispatch)
    sessions = [pool.session() for _ in traces]
    for s, tr in zip(sessions, traces):
        s.submit(sorted(tr, key=lambda c: (c.arrival, c.cid)))
    t0 = time.perf_counter()
    ccts, raw0 = _drive(sessions, pool.advance, step)
    return ccts, raw0, time.perf_counter() - t0, dict(pool.io)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=16)
    ap.add_argument("--coflows", type=int, default=10,
                    help="coflows per session")
    ap.add_argument("--step", type=float, default=0.25,
                    help="virtual seconds per advance (a serving-style "
                    "fine-grained cadence: a few event steps per tick)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=1,
                    help="partition the pooled slab's row axis across "
                    "this many devices (pmap dispatch path)")
    ap.add_argument("--blocking", action="store_true",
                    help="disable async double-buffered dispatch")
    ap.add_argument("--no-assert", action="store_true",
                    help="record numbers without gating on the speedup")
    args = ap.parse_args(argv)

    if args.shards > 1:
        import jax

        if jax.device_count() < args.shards:
            ap.error(
                f"--shards {args.shards} needs {args.shards} devices "
                f"but jax sees {jax.device_count()}; set XLA_FLAGS="
                f"--xla_force_host_platform_device_count="
                f"{args.shards} before python starts (it is set "
                f"automatically only when XLA_FLAGS was unset)")
        if args.sessions % args.shards:
            ap.error("--sessions must be a multiple of --shards")

    traces = _workloads(args.sessions, args.coflows, args.seed)

    # cold pass warms BOTH executables (B=1 and B=N slabs compile
    # separately); best-of-two warm passes absorbs host noise, like
    # Scenario(warm_timing=True)
    pool_kw = dict(shards=args.shards,
                   async_dispatch=not args.blocking)
    _, _, cold_seq = run_sequential(traces, args.step)
    _, _, cold_pool, _ = run_pool(traces, args.step, **pool_kw)
    seq_cct, _, wall_seq = run_sequential(traces, args.step)
    pool_cct, comps, wall_pool, io = run_pool(traces, args.step,
                                              **pool_kw)
    c2, _, w2 = run_sequential(traces, args.step)
    wall_seq = min(wall_seq, w2)
    p2, _, w2, _ = run_pool(traces, args.step, **pool_kw)
    wall_pool = min(wall_pool, w2)

    assert pool_cct == seq_cct == c2 == p2, \
        "pooled sessions diverged from standalone sessions"
    # the device-resident slab contract (ISSUE 5): the DEFAULT workload
    # never outgrows the capacity floors, so the whole pooled drive
    # uploads the full mirrors exactly ONCE (the initial build) — every
    # later advance moves only dirty-row scatters, clean rows move
    # nothing. Gated with the speedup (a custom --coflows load may
    # legitimately grow the slab; --no-assert records without gating).
    if not args.no_assert:
        assert io["full_uploads"] == 1, \
            f"expected one full slab upload, saw {io['full_uploads']}"
    n_cct = sum(len(d) for d in pool_cct)
    speedup = wall_seq / wall_pool
    mode = f"{args.shards} shard(s), " \
        f"{'blocking' if args.blocking else 'async'} dispatch"
    print(f"# pool_throughput: {args.sessions} sessions x "
          f"{args.coflows} coflows ({n_cct} CCTs, bitwise-equal "
          f"pool vs sequential; {mode})", file=sys.stderr)
    print(f"#   sequential {wall_seq:.3f}s (cold {cold_seq:.2f}s) | "
          f"pool {wall_pool:.3f}s (cold {cold_pool:.2f}s) | "
          f"speedup {speedup:.2f}x | "
          f"{args.sessions / wall_pool:.1f} sessions/sec",
          file=sys.stderr)
    print(f"#   device-resident slab: {io['full_uploads']} full upload"
          f" | {io['row_uploads']} row scatters "
          f"({io['upload_bytes'] / 1e6:.2f} MB up) | "
          f"{io['row_downloads']} row gathers "
          f"({io['download_bytes'] / 1e6:.2f} MB down) | "
          f"{io['dispatches']} dispatches", file=sys.stderr)

    # session 0's completions (captured during the measured pooled
    # drive) as a normalized Result, so the record carries standard
    # CCT stats alongside the fleet-level numbers
    res = result_from_completions(comps, wall_seconds=wall_pool)
    rec = record(
        "pool_throughput", res,
        sessions=args.sessions, coflows_per_session=args.coflows,
        wall_pool=wall_pool, wall_sequential=wall_seq,
        compile_pool=max(cold_pool - wall_pool, 0.0),
        compile_sequential=max(cold_seq - wall_seq, 0.0),
        sessions_per_sec=args.sessions / wall_pool,
        speedup=speedup,
        shards=args.shards,
        async_dispatch=not args.blocking,
        ctl_bytes=io["ctl_bytes"],
        full_uploads=io["full_uploads"],
        row_uploads=io["row_uploads"],
        upload_mb=io["upload_bytes"] / 1e6,
        download_mb=io["download_bytes"] / 1e6)

    min_speedup = float(os.environ.get("SAATH_POOL_MIN_SPEEDUP", "4.0"))
    if not args.no_assert:
        assert speedup >= min_speedup, (
            f"pooled fleet speedup {speedup:.2f}x < required "
            f"{min_speedup}x (SAATH_POOL_MIN_SPEEDUP)")
    return rec


if __name__ == "__main__":
    main()
