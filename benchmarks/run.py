"""Benchmark suite entry point: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--engine=jax]

--full replays the 526x150 FB-scale fabric (minutes on one CPU core);
the default quick fabric preserves every qualitative claim. Every
driver runs through `repro.api.run`, so --engine is plain Scenario data
threaded to the Saath side uniformly. Machine-readable perf records
accumulate in BENCH_api.json (benchmarks.common.record). The slow
roofline pass (`python -m benchmarks.roofline --all`) writes
experiments/roofline/; this runner prints its cached table if present.
"""
from __future__ import annotations

import argparse
import glob
import json
import sys
import time

from benchmarks import (fig2_out_of_sync, fig3_offline_policies,
                        fig9_speedup, fig10_breakdown, fig11_bins,
                        fig13_fct_deviation, fig14_sensitivity,
                        table2_coordinator_latency)
from benchmarks.common import Bench

SUITES = [
    ("fig2", fig2_out_of_sync),
    ("fig3", fig3_offline_policies),
    ("fig9", fig9_speedup),
    ("fig10", fig10_breakdown),
    ("fig11", fig11_bins),
    ("fig13", fig13_fct_deviation),
    ("fig14", fig14_sensitivity),
    ("table2", table2_coordinator_latency),
]


def print_cached_roofline(path="experiments/roofline"):
    files = sorted(glob.glob(f"{path}/*.json"))
    if not files:
        print("# roofline: no cached results "
              "(run: python -m benchmarks.roofline --all)")
        return
    from benchmarks.roofline import HEADER, fmt_row
    print("# roofline (cached from experiments/roofline/)")
    print(HEADER)
    for f in files:
        rec = json.load(open(f))
        if "error" in rec:
            print(f"| {rec['arch']} | {rec['shape']} | ERROR |")
        else:
            print(fmt_row(rec))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="FB-scale fabric (526 coflows x 150 ports)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--engine", choices=("numpy", "jax"), default="numpy",
                    help="replay engine for the Saath-side Scenarios")
    args = ap.parse_args()
    bench = Bench(quick=not args.full)
    t0 = time.time()
    failures = []
    for name, mod in SUITES:
        if args.only and name != args.only:
            continue
        t1 = time.time()
        try:
            mod.run(bench, engine=args.engine)
        except AssertionError as e:
            failures.append((name, str(e)))
            print(f"# {name} CLAIM-CHECK FAILED: {e}", file=sys.stderr)
        print(f"# {name} done in {time.time() - t1:.1f}s", file=sys.stderr)
    print_cached_roofline()
    print(f"# total {time.time() - t0:.1f}s; "
          f"{len(failures)} claim-check failures")
    if failures:
        sys.exit(1)


def run_all(quick=True, engine="numpy"):
    bench = Bench(quick=quick)
    return {name: mod.run(bench, engine=engine) for name, mod in SUITES}


if __name__ == "__main__":
    main()
