"""Fig. 11/12: Saath speedup over Aalo per Table-1 bin
(size <=/> 100MB x width <=/> 10).

--engine=jax replays the Saath side through the batched XLA engine
(fabric.jax_engine.run_to_table) instead of the event-driven replay;
Aalo stays on the numpy reference (it has no jitted coordinator).
"""
from __future__ import annotations

from benchmarks.common import Bench, cli_bench, emit
from repro.fabric.metrics import bin_speedups


def run(bench: Bench, engine: str = "numpy"):
    aalo = bench.sim("aalo").table
    if engine == "jax":
        from repro.core.params import SchedulerParams
        from repro.fabric import jax_engine
        saath, _ = jax_engine.run_to_table(bench.trace(), SchedulerParams())
    else:
        saath = bench.sim("saath").table
    bins = bin_speedups(aalo, saath, qs=(50, 90))
    rows = []
    for b, d in bins.items():
        row = {"bin": b, "frac": d.get("frac", 0.0),
               "p50": d.get("p50", float("nan")),
               "p90": d.get("p90", float("nan")),
               "n": d.get("n", 0)}
        rows.append(row)
    emit(f"fig11_bins[{engine}]", rows)
    return rows


if __name__ == "__main__":
    run(*cli_bench())
