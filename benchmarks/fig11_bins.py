"""Fig. 11/12: Saath speedup over Aalo per Table-1 bin
(size <=/> 100MB x width <=/> 10)."""
from __future__ import annotations

from benchmarks.common import Bench, emit
from repro.fabric.metrics import bin_speedups


def run(bench: Bench):
    aalo = bench.sim("aalo").table
    saath = bench.sim("saath").table
    bins = bin_speedups(aalo, saath, qs=(50, 90))
    rows = []
    for b, d in bins.items():
        row = {"bin": b, "frac": d.get("frac", 0.0),
               "p50": d.get("p50", float("nan")),
               "p90": d.get("p90", float("nan")),
               "n": d.get("n", 0)}
        rows.append(row)
    emit("fig11_bins", rows)
    return rows


if __name__ == "__main__":
    run(Bench())
