"""Fig. 11/12: Saath speedup over Aalo per Table-1 bin
(size <=/> 100MB x width <=/> 10).

The Saath side runs on the Scenario's engine; `Result.table()`
materializes a filled FlowTable from either engine, so the bin metrics
consume one shape of data with no engine branching.
"""
from __future__ import annotations

from benchmarks.common import Bench, cli_bench, emit
from repro.fabric.metrics import bin_speedups


def run(bench: Bench, engine: str = "numpy"):
    aalo = bench.run("aalo").table()
    saath = bench.run("saath", engine=engine).table()
    bins = bin_speedups(aalo, saath, qs=(50, 90))
    rows = []
    for b, d in bins.items():
        row = {"bin": b, "frac": d.get("frac", 0.0),
               "p50": d.get("p50", float("nan")),
               "p90": d.get("p90", float("nan")),
               "n": d.get("n", 0)}
        rows.append(row)
    emit(f"fig11_bins[{engine}]", rows)
    return rows


if __name__ == "__main__":
    run(*cli_bench())
