"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------- contention
@pytest.mark.parametrize("C,P", [(3, 5), (64, 64), (130, 150), (257, 96),
                                 (512, 300)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_contention_sweep(C, P, dtype):
    a_s = jnp.asarray((RNG.uniform(size=(C, P)) < 0.15), dtype)
    a_r = jnp.asarray((RNG.uniform(size=(C, P)) < 0.15), dtype)
    act = jnp.asarray(RNG.uniform(size=C) < 0.8)
    got = ops.contention(a_s, a_r, act, force="interpret")
    want = ref.contention_ref(a_s.astype(jnp.float32),
                              a_r.astype(jnp.float32), act)
    np.testing.assert_array_equal(np.array(got), np.array(want))
    # cross-check vs the numpy scheduler reference
    from repro.core.contention import contention as np_contention
    want_np = np_contention(np.array(a_s, np.float32) > 0.5,
                            np.array(a_r, np.float32) > 0.5, np.array(act))
    np.testing.assert_array_equal(np.array(got), want_np)


def test_contention_all_inactive():
    a = jnp.zeros((8, 8), jnp.float32)
    act = jnp.zeros(8, bool)
    got = ops.contention(a, a, act, force="interpret")
    assert (np.array(got) == 0).all()


# ------------------------------------------------------------------- maxmin
@pytest.mark.parametrize("P,F", [(2, 3), (6, 30), (16, 128), (32, 200)])
def test_maxmin_sweep(P, F):
    src_i = RNG.integers(0, P, F)
    dst_i = RNG.integers(0, P, F)
    live = jnp.asarray(RNG.uniform(size=F) < 0.85)
    S = np.zeros((P, F), np.float32)
    S[src_i, np.arange(F)] = 1
    D = np.zeros((P, F), np.float32)
    D[dst_i, np.arange(F)] = 1
    bw = jnp.asarray(RNG.uniform(0.5, 2.0, P), jnp.float32)
    got = ops.maxmin_rates(jnp.asarray(S), jnp.asarray(D), live, bw, bw,
                           force="interpret")
    want = ref.maxmin_ref(jnp.asarray(S), jnp.asarray(D), live, bw, bw)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-5)
    # invariants: capacity respected, dead flows get nothing
    np.testing.assert_array_less(S @ np.array(got), np.array(bw) + 1e-4)
    assert (np.array(got)[~np.array(live)] == 0).all()


def test_maxmin_matches_numpy_waterfill():
    from repro.core.policies.base import maxmin_waterfill
    from repro.fabric.state import FlowTable
    from repro.traces import tiny_trace

    tr = tiny_trace(12, 8, seed=3)
    t = FlowTable.from_trace(tr, 1.0)
    t.active[:] = True
    live = t.flow_live()
    F, P = t.size.shape[0], t.num_ports
    S = np.zeros((P, F), np.float32)
    S[t.src, np.arange(F)] = 1
    D = np.zeros((P, F), np.float32)
    D[t.dst, np.arange(F)] = 1
    got = ops.maxmin_rates(jnp.asarray(S), jnp.asarray(D), jnp.asarray(live),
                           jnp.asarray(t.bw_send, jnp.float32),
                           jnp.asarray(t.bw_recv, jnp.float32),
                           force="interpret")
    want = maxmin_waterfill(t, live)
    np.testing.assert_allclose(np.array(got), want, atol=1e-5)


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize(
    "B,H,Hkv,S,T,D", [(1, 1, 1, 16, 16, 32), (2, 4, 2, 64, 64, 64),
                      (1, 8, 1, 32, 32, 128), (1, 2, 2, 40, 40, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, H, Hkv, S, T, D, dtype, causal):
    q = jnp.asarray(RNG.normal(size=(B, H, S, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, T, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, T, D)), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, bq=16, bk=16,
                              force="interpret")
    want = ref.attention_ref(q, k, v, causal=causal)
    atol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.array(got, np.float32),
                               np.array(want, np.float32), atol=atol)


def test_flash_attention_chunked_prefill_offset():
    """Chunked prefill: attending with q_offset equals slicing the full
    causal result."""
    B, H, S, D = 1, 2, 64, 32
    q = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.float32)
    full = ops.flash_attention(q, k, v, causal=True, bq=16, bk=16,
                               force="interpret")
    half = ops.flash_attention(q[:, :, 32:], k, v, causal=True, bq=16,
                               bk=16, q_offset=32, force="interpret")
    np.testing.assert_allclose(np.array(half), np.array(full[:, :, 32:]),
                               atol=1e-5)


# ----------------------------------------------------------------- ssd scan
@pytest.mark.parametrize(
    "B,L,H,G,Dh,N,lc", [(1, 16, 1, 1, 8, 8, 8), (2, 64, 4, 2, 16, 32, 16),
                        (1, 128, 2, 1, 32, 64, 64), (1, 256, 8, 2, 64, 128,
                                                     128)])
def test_ssd_scan_sweep(B, L, H, G, Dh, N, lc):
    x = jnp.asarray(RNG.normal(size=(B, L, H, Dh)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, size=(B, L, H)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.3, 2.0, size=H), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(B, L, G, N)), jnp.float32)
    c = jnp.asarray(RNG.normal(size=(B, L, G, N)), jnp.float32)
    got_y, got_s = ops.ssd_scan(x, dt, a, b, c, lc=lc, force="interpret")
    want_y, want_s = ref.ssd_ref(x, dt, a, b, c)
    np.testing.assert_allclose(np.array(got_y), np.array(want_y),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.array(got_s), np.array(want_s),
                               atol=5e-4, rtol=1e-3)


def test_ssd_scan_state_chaining():
    """Running two halves with carried state == one full scan (the decode /
    multi-step serving contract)."""
    B, L, H, G, Dh, N = 1, 64, 2, 1, 16, 32
    x = jnp.asarray(RNG.normal(size=(B, L, H, Dh)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, size=(B, L, H)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.3, 2.0, size=H), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(B, L, G, N)), jnp.float32)
    c = jnp.asarray(RNG.normal(size=(B, L, G, N)), jnp.float32)
    y_full, s_full = ops.ssd_scan(x, dt, a, b, c, lc=16, force="interpret")
    y1, s1 = ops.ssd_scan(x[:, :32], dt[:, :32], a, b[:, :32], c[:, :32],
                          lc=16, force="interpret")
    y2, s2 = ops.ssd_scan(x[:, 32:], dt[:, 32:], a, b[:, 32:], c[:, 32:],
                          init_state=s1, lc=16, force="interpret")
    np.testing.assert_allclose(np.array(jnp.concatenate([y1, y2], 1)),
                               np.array(y_full), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.array(s2), np.array(s_full), atol=1e-4,
                               rtol=1e-3)
