"""The scheduling front door: Scenario/run/Result contract.

Covers the engine-equivalence contract now owned by `repro.api`
(numpy vs jax CCTs within 1% through one entry point), the Result
normalizer's NaN/padding semantics (the avg_cct / makespan regression),
and the unified policy registry errors.
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.api import MECHANISM_KEYS, Result, Scenario, run
from repro.core.coflow import Coflow, Flow, Trace
from repro.core.params import SchedulerParams

PORTS = 6
PARAMS = SchedulerParams(port_bw=1.0, delta=1e-2, start_threshold=4.0,
                         growth=4.0, num_queues=5)


def _trace(seed: int = 0, n: int = 6) -> Trace:
    rng = np.random.default_rng(seed)
    coflows, fid = [], 0
    for c in range(n):
        w = int(rng.integers(1, 5))
        flows = [Flow(fid + i, int(rng.integers(0, PORTS)),
                      int(rng.integers(0, PORTS)),
                      float(rng.uniform(1.0, 15.0))) for i in range(w)]
        fid += w
        coflows.append(Coflow(c, float(rng.uniform(0.0, 2.0)), flows))
    return Trace(num_ports=PORTS, coflows=coflows)


def test_run_owns_the_engine_equivalence_contract():
    """One Scenario, two engines: per-coflow CCTs within 1%."""
    tr = _trace(0)
    rn = run(Scenario(policy="saath", engine="numpy", trace=tr,
                      params=PARAMS))
    rj = run(Scenario(policy="saath", engine="jax", trace=tr,
                      params=PARAMS))
    np.testing.assert_allclose(rj.row_cct(), rn.row_cct(), rtol=1e-2,
                               atol=2 * PARAMS.delta)
    np.testing.assert_allclose(rj.makespan, rn.makespan, rtol=1e-2)
    assert abs(rj.avg_cct[0] / rn.avg_cct[0] - 1.0) < 1e-2


def test_mechanism_switches_resolve_identically():
    """The shared ablation names act the same on both engines."""
    tr = _trace(2)
    mech = dict(lcof=False, per_flow_threshold=True,
                work_conservation=False, dynamics_requeue=False)
    rn = run(Scenario(engine="numpy", trace=tr, params=PARAMS,
                      mechanisms=mech))
    rj = run(Scenario(engine="jax", trace=tr, params=PARAMS,
                      mechanisms=mech))
    np.testing.assert_allclose(rj.row_cct(), rn.row_cct(), rtol=1e-2,
                               atol=2 * PARAMS.delta)


def test_sweep_scenario_loops_on_numpy_and_vmaps_on_jax():
    tr = _trace(1)
    sweep = tuple(dataclasses.replace(PARAMS, start_threshold=s)
                  for s in (2.0, 8.0))
    rn = run(Scenario(engine="numpy", trace=tr, params=PARAMS,
                      sweep=sweep))
    rj = run(Scenario(engine="jax", trace=tr, params=PARAMS,
                      sweep=sweep))
    assert rn.batch == rj.batch == 2
    for i in range(2):
        np.testing.assert_allclose(rj.row_cct(i), rn.row_cct(i),
                                   rtol=1e-2, atol=2 * PARAMS.delta)


def test_result_table_rebuilds_for_both_engines():
    tr = _trace(3)
    for engine in ("numpy", "jax"):
        t = run(Scenario(engine=engine, trace=tr, params=PARAMS)).table()
        assert t.finished.all() and t.done.all()
        np.testing.assert_allclose(t.sent, t.size, rtol=1e-5)
        assert np.isfinite(t.cct).all()


# ---- the Result normalizer owns NaN/padding semantics (satellite) -----


def test_empty_replay_reports_nan_not_zero():
    """Regression: SimResult.makespan used to report 0.0 for a replay
    that finished nothing — a unit claim ('zero seconds') the jax
    plane's NaN contradicted. Both planes now agree on NaN, defined
    once in the Result normalizer."""
    from repro.core.policies import make_policy
    from repro.fabric.engine import Simulator
    from repro.fabric.state import FlowTable

    empty = Trace(num_ports=4, coflows=[])
    with warnings.catch_warnings():
        warnings.simplefilter("error")            # no all-NaN warnings
        sim = Simulator(PARAMS).run(
            FlowTable.from_trace(empty, PARAMS.port_bw),
            make_policy("saath", PARAMS))
        assert np.isnan(sim.makespan)
        assert np.isnan(sim.avg_cct)
        res = run(Scenario(engine="numpy", trace=empty, params=PARAMS))
        assert np.isnan(res.makespan[0])
        assert np.isnan(res.avg_cct[0])


def test_engine_result_all_padding_row_is_nan_without_warning():
    """Regression: EngineResult.avg_cct tripped numpy's all-NaN mean
    RuntimeWarning (and an ill-defined value) on an all-padding batch
    row — e.g. a drained session slab."""
    from repro.fabric.jax_engine import EngineResult

    res = EngineResult(
        cct=np.array([[1.0, np.nan], [np.nan, np.nan]]),
        fct=np.full((2, 2), np.nan), sent=np.zeros((2, 2)),
        finished=np.ones((2, 2), bool), ticks=0, events=0)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        avg = res.avg_cct
    assert avg[0] == 1.0 and np.isnan(avg[1])


def test_result_normalizer_row_semantics():
    r = Result(engine="jax", policy="saath",
               cct=np.array([[2.0, np.nan], [np.nan, np.nan]]),
               fct=np.array([[5.0, np.nan], [np.nan, np.nan]]),
               sent=np.zeros((2, 2)), num_coflows=np.array([2, 1]),
               num_flows=np.array([2, 1]), steps=0, wall_seconds=0.0)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert r.avg_cct[0] == 2.0 and np.isnan(r.avg_cct[1])
        assert r.makespan[0] == 5.0 and np.isnan(r.makespan[1])


# ---- registry / validation errors (satellite) -------------------------


def test_unknown_policy_raises_with_available_list():
    from repro.core.policies import make_policy

    with pytest.raises(ValueError, match="saath.*varys-sebf"):
        make_policy("sincronia", PARAMS)
    with pytest.raises(ValueError, match="available:.*aalo"):
        run(Scenario(policy="sincronia", trace=_trace(0)))


def test_host_only_policy_rejected_on_jax_with_capable_list():
    with pytest.raises(ValueError, match="saath"):
        run(Scenario(policy="aalo", engine="jax", trace=_trace(0)))


def test_unknown_engine_and_mechanism_raise():
    with pytest.raises(ValueError, match="numpy, jax"):
        run(Scenario(engine="tpu", trace=_trace(0)))
    with pytest.raises(ValueError, match="work_conservation"):
        run(Scenario(trace=_trace(0), mechanisms={"wc": False}))
    assert "lcof" in MECHANISM_KEYS


def test_exactly_one_trace_source():
    with pytest.raises(ValueError, match="exactly one trace source"):
        run(Scenario(policy="saath"))
    with pytest.raises(ValueError, match="exactly one trace source"):
        run(Scenario(trace=_trace(0), synth={"num_coflows": 4}))


def test_scenario_hash_is_stable_and_discriminating():
    tr = _trace(0)
    a = Scenario(trace=tr, params=PARAMS)
    b = Scenario(trace=tr, params=PARAMS)
    c = Scenario(trace=tr, params=PARAMS, engine="jax")
    d = Scenario(trace=_trace(1), params=PARAMS)
    assert a.hash() == b.hash()
    assert len({a.hash(), c.hash(), d.hash()}) == 3
