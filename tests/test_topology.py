"""FabricModel layer (ISSUE 9): leaf-spine allocation through BOTH
planes, the max-min work-conservation fill, and the Pallas water-filling
backend.

The bitwise big-switch preservation guard lives in
tests/test_fabric_regression.py; this suite covers the NEW semantics:

* oversub=1:1 leaf-spine == big switch (the mediant inequality: uplink
  residual >= the sum of subtended port residuals, so the extra link
  mins never bind) — bitwise on each plane;
* oversub=4:1 measurably degrades CCTs on both planes, and the two
  planes agree within the engine-equivalence envelope (1%);
* wc_fill="maxmin" (the in-network allocation family) runs through the
  shared `kernels.ops.maxmin_rates` backend, with the Pallas kernel
  parity-gated against `kernels.ref` in interpret mode.
"""
import numpy as np
import pytest

from repro.api import Scenario, run
from repro.fabric.topology import (BigSwitch, ExtraLinks, LeafSpine,
                                   normalize_topology)
from repro.traces.synth import tiny_trace

TOPO_1 = LeafSpine(hosts_per_leaf=4, oversub=1.0)
TOPO_4 = LeafSpine(hosts_per_leaf=4, oversub=4.0)


def _go(trace, engine, topology=None, **kw):
    return run(Scenario(policy="saath", engine=engine, trace=trace,
                        topology=topology, **kw))


# ---- model layer ---------------------------------------------------------

def test_normalize_and_validate():
    assert isinstance(normalize_topology(None), BigSwitch)
    t = normalize_topology(TOPO_4)
    assert t is TOPO_4
    with pytest.raises(TypeError):
        normalize_topology(object())
    with pytest.raises(ValueError):
        LeafSpine(hosts_per_leaf=0)
    with pytest.raises(ValueError):
        LeafSpine(oversub=0.0)
    with pytest.raises(ValueError):
        LeafSpine(wc_fill="random")


def test_leaf_layout():
    topo = LeafSpine(hosts_per_leaf=4)
    assert topo.leaf_count(16) == 4
    assert topo.leaf_count(14) == 4  # ragged tail leaf
    np.testing.assert_array_equal(
        topo.leaf_of(np.arange(8)), [0, 0, 0, 0, 1, 1, 1, 1])
    up, dn = topo.flow_links(np.array([0, 0, 5]), np.array([1, 6, 6]))
    np.testing.assert_array_equal(up, [-1, 0, -1])   # intra-leaf = -1
    np.testing.assert_array_equal(dn, [-1, 1, -1])


def test_link_caps_oversub():
    topo = LeafSpine(hosts_per_leaf=4, oversub=2.0)
    bw = np.ones(8)
    cap_up, cap_dn = topo.link_caps(bw, bw)
    # 4 ports x 1.0 each, divided by the 2:1 oversubscription
    np.testing.assert_allclose(cap_up, [2.0, 2.0])
    np.testing.assert_allclose(cap_dn, [2.0, 2.0])


def test_bind_offsets():
    from repro.core.params import SchedulerParams
    from repro.fabric.state import FlowTable

    tr = tiny_trace(6, 8, seed=1)
    table = FlowTable.from_trace(tr, 1.0)
    ex = LeafSpine(hosts_per_leaf=4).bind(table)
    assert isinstance(ex, ExtraLinks)
    Lf = ex.num_uplinks
    assert ex.cap.shape == (2 * Lf,)
    # downlink ids are pre-offset into the stacked cap vector
    assert ((ex.dn < 0) | (ex.dn >= Lf)).all()
    assert ((ex.up < 0) | (ex.up < Lf)).all()


# ---- 1:1 equivalence (both planes) ---------------------------------------

def test_oversub_one_matches_bigswitch_numpy():
    tr = tiny_trace(24, 16, seed=2, load=0.8)
    big = _go(tr, "numpy")
    ls = _go(tr, "numpy", TOPO_1)
    np.testing.assert_array_equal(big.row_cct(), ls.row_cct())
    np.testing.assert_array_equal(big.row_fct(), ls.row_fct())


def test_oversub_one_matches_bigswitch_jax_fleet():
    # a fig9-style (shrunk) fleet: the 1:1 leaf-spine mins can never
    # bind, so the vmapped engine must reproduce the big switch exactly
    fleet = [tiny_trace(20, 16, seed=s, load=0.8) for s in range(4)]
    big = run(Scenario(policy="saath", engine="jax",
                       traces=tuple(fleet)))
    ls = run(Scenario(policy="saath", engine="jax", traces=tuple(fleet),
                      topology=TOPO_1))
    for b in range(len(fleet)):
        np.testing.assert_array_equal(big.row_cct(b), ls.row_cct(b))


# ---- oversubscription bites (both planes) --------------------------------

def test_oversub_degrades_both_planes():
    tr = tiny_trace(30, 16, seed=0, load=0.8)
    res = {}
    for eng in ("numpy", "jax"):
        base = _go(tr, eng, TOPO_1)
        over = _go(tr, eng, TOPO_4)
        assert over.avg_cct[0] > 1.1 * base.avg_cct[0], eng
        res[eng] = over
    # engine-equivalence envelope holds with links binding
    a, b = res["numpy"].row_cct(), res["jax"].row_cct()
    assert np.nanmax(np.abs(a - b) / np.maximum(np.abs(a), 1e-9)) < 0.01


def test_oversub_degrades_sessions():
    # the serving plane sees the same physics: a 4:1 pool drains slower
    from repro.api.pool import SessionPool
    from repro.core.coflow import Coflow, Flow
    from repro.core.params import SchedulerParams

    def _coflows():
        rng = np.random.default_rng(11)
        out = []
        for c in range(4):
            flows = [Flow(0, int(rng.integers(0, 8)),
                          int(rng.integers(8, 16)),
                          float(rng.uniform(1e6, 5e6)))
                     for _ in range(3)]
            out.append(Coflow(cid=c, arrival=0.0, flows=flows))
        return out

    ccts = {}
    for name, topo in (("1:1", LeafSpine(hosts_per_leaf=4, oversub=1.0)),
                       ("4:1", LeafSpine(hosts_per_leaf=4, oversub=4.0))):
        pool = SessionPool(SchedulerParams(), num_ports=16,
                           max_sessions=1, topology=topo)
        s = pool.session()
        s.submit(_coflows())
        done = s.drain(max_seconds=600.0, step=1.0)
        assert len(done) == 4, name
        ccts[name] = sum(d.cct for d in done)
        s.close()
    assert ccts["4:1"] > ccts["1:1"]


# ---- max-min work-conservation fill --------------------------------------

def test_wc_maxmin_parity():
    topo = LeafSpine(hosts_per_leaf=4, oversub=4.0, wc_fill="maxmin")
    tr = tiny_trace(24, 16, seed=4, load=0.8)
    a = _go(tr, "numpy", topo)
    b = _go(tr, "jax", topo)
    ca, cb = a.row_cct(), b.row_cct()
    assert np.nanmax(np.abs(ca - cb) / np.maximum(np.abs(ca), 1e-9)) < 0.01


# ---- Pallas water-filling backend (satellite: use_pallas) ----------------

def test_maxmin_kernel_parity_interpret():
    """The dormant kernels/maxmin.py now backs wc_fill="maxmin":
    interpret mode (kernel body on CPU) must match kernels/ref.py on
    stacked port+link incidence shapes."""
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    rng = np.random.default_rng(5)
    P, Lf, F = 16, 4, 64
    src = rng.integers(0, P, F)
    dst = rng.integers(0, P, F)
    up = rng.integers(0, Lf + 1, F)   # Lf = sentinel -> zero column
    dn = rng.integers(0, Lf + 1, F)

    def onehot(ids, n):
        m = np.zeros((n, F), np.float32)
        ok = ids < n
        m[ids[ok], np.nonzero(ok)[0]] = 1.0
        return m

    a_s = np.concatenate([onehot(src, P), onehot(up, Lf)])
    a_r = np.concatenate([onehot(dst, P), onehot(dn, Lf)])
    live = rng.random(F) < 0.7
    bw_s = np.concatenate([np.ones(P), np.full(Lf, 2.0)]).astype(np.float32)
    bw_r = bw_s.copy()
    want = ref.maxmin_ref(jnp.asarray(a_s), jnp.asarray(a_r),
                          jnp.asarray(live), jnp.asarray(bw_s),
                          jnp.asarray(bw_r))
    got = ops.maxmin_rates(jnp.asarray(a_s), jnp.asarray(a_r),
                           jnp.asarray(live), jnp.asarray(bw_s),
                           jnp.asarray(bw_r), force="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_contention_kernel_parity_interpret():
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    rng = np.random.default_rng(6)
    C, P = 8, 16
    a_s = (rng.random((C, P)) < 0.3).astype(np.float32)
    a_r = (rng.random((C, P)) < 0.3).astype(np.float32)
    act = rng.random(C) < 0.8
    want = ref.contention_ref(jnp.asarray(a_s), jnp.asarray(a_r),
                              jnp.asarray(act))
    got = ops.contention(jnp.asarray(a_s), jnp.asarray(a_r),
                         jnp.asarray(act), force="interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.slow
def test_use_pallas_engine_parity():
    """simulate_batch(use_pallas=True) (interpret off-TPU) reproduces
    the default dispatch within f32 noise — the engine-level gate on the
    accelerated water-filling backend."""
    topo = LeafSpine(hosts_per_leaf=4, oversub=4.0, wc_fill="maxmin")
    tr = tiny_trace(12, 8, seed=7, load=0.8)
    a = _go(tr, "jax", topo)
    b = _go(tr, "jax", topo, use_pallas=True)
    ca, cb = a.row_cct(), b.row_cct()
    assert np.nanmax(np.abs(ca - cb) / np.maximum(np.abs(ca), 1e-9)) < 1e-3


@pytest.mark.slow
def test_oversub_one_matches_bigswitch_jax_fleet_full():
    """The fig9-scale fleet version of the 1:1 gate (nightly tier)."""
    fleet = [tiny_trace(40, 20, seed=s, load=0.8) for s in range(16)]
    big = run(Scenario(policy="saath", engine="jax",
                       traces=tuple(fleet)))
    ls = run(Scenario(policy="saath", engine="jax", traces=tuple(fleet),
                      topology=TOPO_1))
    for b in range(len(fleet)):
        np.testing.assert_array_equal(big.row_cct(b), ls.row_cct(b))
