"""The lint layer itself: per-rule fixtures (positive + negative),
suppression semantics, contract rules, and the self-run gate asserting
`src/repro` stays clean under the full rule set."""
import textwrap
from pathlib import Path

import repro
from repro.analysis.contracts import (api_simulator_imports,
                                      slab_leaf_coverage)
from repro.analysis.lint import lint_paths, lint_text

ENGINE_PATH = "src/repro/fabric/jax_engine.py"   # hot-module gates on
NEUTRAL_PATH = "src/repro/api/fixture.py"        # hot-module gates off


def rules_of(src, path=NEUTRAL_PATH):
    return {f.rule for f in lint_text(textwrap.dedent(src), path)}


# ---- traced-np-call ------------------------------------------------------

def test_traced_np_call_positive():
    assert "traced-np-call" in rules_of("""
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.asarray(x)
    """)


def test_traced_np_call_negative_host_function():
    assert "traced-np-call" not in rules_of("""
        import numpy as np

        def f(x):
            return np.asarray(x)
    """)


def test_traced_scope_propagates_through_call_graph():
    # helper is not decorated, but a jitted caller reaches it
    assert "traced-np-call" in rules_of("""
        import functools

        import jax
        import numpy as np

        def helper(x):
            return np.square(x)

        @functools.partial(jax.jit, static_argnames=("k",))
        def f(x, k):
            return helper(x)
    """)


def test_traced_scope_seeds_lax_control_flow():
    assert "traced-np-call" in rules_of("""
        import jax
        import numpy as np

        def body(c, _):
            return np.abs(c), None

        def run(x):
            return jax.lax.scan(body, x, None, length=3)
    """)


# ---- cast-in-trace -------------------------------------------------------

def test_cast_in_trace_positive():
    assert "cast-in-trace" in rules_of("""
        import jax

        @jax.jit
        def f(x):
            return float(x)
    """)


def test_cast_in_trace_item_positive():
    assert "cast-in-trace" in rules_of("""
        import jax

        @jax.jit
        def f(x):
            return x.item()
    """)


def test_cast_in_trace_negative_host():
    assert "cast-in-trace" not in rules_of("""
        def f(x):
            return float(x)
    """)


# ---- branch-on-tracer ----------------------------------------------------

def test_branch_on_tracer_positive():
    assert "branch-on-tracer" in rules_of("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            y = jnp.sum(x)
            if y > 0:
                return y
            return -y
    """)


def test_branch_on_tracer_negative_static_arg():
    # branching on a (static) parameter is the sanctioned pattern
    assert "branch-on-tracer" not in rules_of("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x, flag):
            if flag:
                return jnp.sum(x)
            return x
    """)


# ---- implicit-dtype ------------------------------------------------------

def test_implicit_dtype_positive_in_hot_module():
    assert "implicit-dtype" in rules_of("""
        import jax.numpy as jnp

        def f(x):
            return jnp.asarray(x)
    """, path=ENGINE_PATH)


def test_implicit_dtype_negative_with_explicit_dtype():
    assert "implicit-dtype" not in rules_of("""
        import jax.numpy as jnp

        def f(x):
            return jnp.asarray(x, jnp.float32)
    """, path=ENGINE_PATH)


def test_implicit_dtype_negative_outside_hot_modules():
    assert "implicit-dtype" not in rules_of("""
        import jax.numpy as jnp

        def f(x):
            return jnp.asarray(x)
    """)


def test_implicit_dtype_f64_literal_in_traced_function():
    assert "implicit-dtype" in rules_of("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return x.astype(jnp.float64)
    """, path=ENGINE_PATH)


def test_implicit_dtype_f64_ok_in_host_result_path():
    assert "implicit-dtype" not in rules_of("""
        import numpy as np

        def results(state):
            return np.asarray(state, np.float64)
    """, path=ENGINE_PATH)


# ---- host-pull-unaccounted -----------------------------------------------

def test_host_pull_unaccounted_positive_pool_method():
    assert "host-pull-unaccounted" in rules_of("""
        import numpy as np

        class P:
            def __init__(self):
                self.io = {}
                self._state = None

            def bad(self):
                return np.asarray(self._state)
    """)


def test_host_pull_accounted_negative():
    assert "host-pull-unaccounted" not in rules_of("""
        import numpy as np

        class P:
            def __init__(self):
                self.io = {}
                self._state = None

            def good(self):
                out = np.asarray(self._state)
                self.io["download_bytes"] = out.nbytes
                return out
    """)


def test_host_pull_shape_reads_are_not_pulls():
    assert "host-pull-unaccounted" not in rules_of("""
        import numpy as np

        class P:
            def __init__(self):
                self.io = {}
                self._state = None

            def meta(self):
                return int(np.prod(self._state.shape))
    """)


def test_host_pull_session_entrypoint_positive():
    assert "host-pull-unaccounted" in rules_of("""
        import numpy as np

        def session_probe(state):
            out, steps = _run_session_block(state)
            return int(np.asarray(steps).max())
    """, path=ENGINE_PATH)


# ---- hygiene rules -------------------------------------------------------

def test_unused_import_positive_and_negative():
    assert "unused-import" in rules_of("import os\nx = 1\n")
    assert "unused-import" not in rules_of(
        "import os\nx = os.getcwd()\n")


def test_unused_variable_positive_and_negative():
    assert "unused-variable" in rules_of("""
        def f():
            y = 1
            return 2
    """)
    assert "unused-variable" not in rules_of("""
        def f():
            y = 1
            return y
    """)


# ---- suppressions --------------------------------------------------------

def test_suppression_with_reason_silences_matching_rule():
    src = ("import jax\nimport numpy as np\n\n"
           "@jax.jit\ndef f(x):\n"
           "    return np.asarray(x)  "
           "# saath: lint-ok(traced-np-call): fixture\n")
    assert "traced-np-call" not in {f.rule for f in lint_text(src)}


def test_suppression_requires_reason():
    # assembled so the scanner doesn't read THIS file's source line as
    # a (reason-less) suppression of its own
    marker = "# saath: " + "lint-ok(traced-np-call)"
    src = ("import jax\nimport numpy as np\n\n"
           "@jax.jit\ndef f(x):\n"
           f"    return np.asarray(x)  {marker}\n")
    rules = {f.rule for f in lint_text(src)}
    assert "bad-suppression" in rules
    assert "traced-np-call" in rules     # unsuppressed without a reason


def test_suppression_wrong_rule_does_not_silence():
    src = ("import jax\nimport numpy as np\n\n"
           "@jax.jit\ndef f(x):\n"
           "    return np.asarray(x)  "
           "# saath: lint-ok(cast-in-trace): wrong rule\n")
    assert "traced-np-call" in {f.rule for f in lint_text(src)}


def test_def_line_suppression_covers_function_body():
    src = ("import jax\nimport numpy as np\n\n"
           "@jax.jit\n"
           "def f(x):  # saath: lint-ok(traced-np-call): whole body\n"
           "    y = np.asarray(x)\n"
           "    return np.square(y)\n")
    assert "traced-np-call" not in {f.rule for f in lint_text(src)}


def test_decorator_line_suppression_covers_function_body():
    src = ("import jax\nimport numpy as np\n\n"
           "@jax.jit  # saath: lint-ok(traced-np-call): fixture\n"
           "def f(x):\n"
           "    return np.asarray(x)\n")
    assert "traced-np-call" not in {f.rule for f in lint_text(src)}


def test_multiline_signature_suppression_covers_function_body():
    src = ("import jax\nimport numpy as np\n\n"
           "@jax.jit\n"
           "def f(\n"
           "    x,  # saath: lint-ok(traced-np-call): fixture\n"
           "):\n"
           "    return np.asarray(x)\n")
    assert "traced-np-call" not in {f.rule for f in lint_text(src)}


def test_body_line_suppression_stays_line_local():
    # a suppression INSIDE the body silences its own line only --
    # header coverage must not leak downward from body comments
    src = ("import jax\nimport numpy as np\n\n"
           "@jax.jit\n"
           "def f(x):\n"
           "    y = np.asarray(x)  "
           "# saath: lint-ok(traced-np-call): this line\n"
           "    return np.square(x)\n")
    findings = [f for f in lint_text(src)
                if f.rule == "traced-np-call"]
    assert [f for f in findings if f.line == 7]     # np.square survives
    assert not [f for f in findings if f.line == 6]


def test_nested_def_header_suppression_covers_inner_span_only():
    # the inner def's header suppression must not blanket the outer
    # function's later lines
    src = ("import jax\nimport numpy as np\n\n"
           "@jax.jit\n"
           "def outer(x):\n"
           "    def inner(y):  "
           "# saath: lint-ok(traced-np-call): inner only\n"
           "        return np.asarray(y)\n"
           "    z = inner(x)\n"
           "    return np.square(z)\n")
    findings = [f for f in lint_text(src)
                if f.rule == "traced-np-call"]
    assert [f for f in findings if f.line == 9]     # outer's np.square
    assert not [f for f in findings if f.line == 7]


# ---- contract rules ------------------------------------------------------

def _fake_tree(tmp_path, pool_body):
    for d in ("traces", "fabric", "core", "api"):
        (tmp_path / "repro" / d).mkdir(parents=True, exist_ok=True)
    (tmp_path / "repro/traces/batch.py").write_text(textwrap.dedent("""
        class TraceBatch(NamedTuple):
            cid: int
            newcol: int

        def empty_batch():
            return dict(cid=0, newcol=0)

        def blank_row(tb):
            tb.cid = 0
            tb.newcol = 0

        def pack_row(tb):
            tb.cid = 1
    """))
    (tmp_path / "repro/fabric/jax_engine.py").write_text(
        "class EngineState(NamedTuple):\n    sent: int\n    tick: int\n")
    (tmp_path / "repro/core/jax_coordinator.py").write_text(
        "class CoordState(NamedTuple):\n    queue: int\n")
    (tmp_path / "repro/api/pool.py").write_text(
        textwrap.dedent(pool_body))
    return tmp_path


def test_slab_leaf_coverage_catches_forgotten_field(tmp_path):
    root = _fake_tree(tmp_path, """
        class SessionPool:
            def _blank_state_row(self):
                return EngineState(sent=0, tick=0), CoordState(0)

            def _sync_row(self, st):
                return st.sent, st.tick, st.queue
    """)
    findings = slab_leaf_coverage(root)
    # pack_row forgot TraceBatch.newcol; everything else is covered
    # (CoordState is constructed positionally-complete)
    assert [f for f in findings
            if "newcol" in f.msg and "pack_row" in f.msg]
    assert not [f for f in findings if "queue" in f.msg]


def test_slab_leaf_coverage_catches_unsynced_engine_leaf(tmp_path):
    root = _fake_tree(tmp_path, """
        class SessionPool:
            def _blank_state_row(self):
                return EngineState(sent=0, tick=0), CoordState(0)

            def _sync_row(self, st):
                return st.sent, st.queue
    """)
    findings = slab_leaf_coverage(root)
    assert [f for f in findings
            if "`tick`" in f.msg and "_sync_row" in f.msg]


def test_api_simulator_import_rule(tmp_path):
    api = tmp_path / "repro" / "api"
    api.mkdir(parents=True)
    (api / "bad.py").write_text(
        "from repro.fabric.engine import Simulator\n")
    (api / "good.py").write_text(
        "def f():\n    from repro.fabric.engine import Simulator\n"
        "    return Simulator\n")
    findings = api_simulator_imports(tmp_path)
    assert [f for f in findings if f.path.endswith("bad.py")]
    assert not [f for f in findings if f.path.endswith("good.py")]


# ---- the self-run gate ---------------------------------------------------

def test_repo_src_is_lint_clean_within_suppression_budget():
    """`src/repro` must stay clean under the full rule set (contract
    rules included) with at most 10 explicit suppressions — the ISSUE 7
    acceptance bar. New findings either get fixed or get a reasoned
    `# saath: lint-ok(rule): why` and a slot of the budget."""
    src_repro = Path(list(repro.__path__)[0])
    findings, n_suppressed = lint_paths([str(src_repro)])
    assert not findings, "\n".join(str(f) for f in findings)
    assert n_suppressed <= 10, (
        f"{n_suppressed} suppressions exceed the <=10 budget")
