"""Bitwise regression guard for the FabricModel refactor (ISSUE 9).

`_LegacySaath` below is the pre-refactor `Saath.schedule` + the
pre-refactor `greedy_flow_alloc`, frozen VERBATIM at the commit that
introduced `fabric.topology`. The property tests assert that routing
the refactored allocation stack through `topology=None` and
`topology=BigSwitch()` reproduces the legacy trajectory EXACTLY
(bitwise `fct`/`cct`/`sent`, not within a tolerance) on the numpy
plane, and that the jax serving plane with an explicit topology stays
bitwise pooled-vs-standalone. Any fabric-model change that perturbs
big-switch arithmetic — a reordered min, an extra subtract, a changed
round limit — trips this suite.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.coflow import Coflow, Flow, Trace
from repro.core.params import SchedulerParams
from repro.core.policies import make_policy
from repro.core.policies.saath import Saath
from repro.fabric.engine import Simulator
from repro.fabric.state import FlowTable
from repro.fabric.topology import BigSwitch, LeafSpine

PORTS = 6
PARAMS = SchedulerParams(port_bw=1.0, delta=1e-2, start_threshold=4.0,
                         growth=4.0, num_queues=5)


def _legacy_greedy_flow_alloc(table, flow_order, live, avail_s, avail_r,
                              rates):
    """`base.greedy_flow_alloc` exactly as of PR 8 (no link handling)."""
    src, dst = table.src, table.dst
    ordered = flow_order[live[flow_order]]
    for _ in range(2 * table.num_ports + 2):
        if ordered.size == 0:
            break
        cand = ordered[(avail_s[src[ordered]] > 0.0)
                       & (avail_r[dst[ordered]] > 0.0)]
        if cand.size == 0:
            break
        _, first_s = np.unique(src[cand], return_index=True)
        _, first_r = np.unique(dst[cand], return_index=True)
        is_first_s = np.zeros(cand.size, bool)
        is_first_r = np.zeros(cand.size, bool)
        is_first_s[first_s] = True
        is_first_r[first_r] = True
        take = cand[is_first_s & is_first_r]
        r = np.minimum(avail_s[src[take]], avail_r[dst[take]])
        rates[take] = r
        avail_s[src[take]] -= r
        avail_r[dst[take]] -= r
        ordered = cand[~(is_first_s & is_first_r)]
    return rates


class _LegacySaath(Saath):
    """`Saath` with the pre-refactor `schedule` body frozen verbatim."""

    def schedule(self, table, now):
        from repro.core.contention import contention
        p = self.params
        live = table.flow_live()
        rates = np.zeros(table.size.shape[0])
        if not live.any():
            return rates

        q_new = self._assign_queues(table, now)
        self._refresh_deadlines(table, q_new, now)

        active = table.active.copy()
        A_s, A_r = table.incidence(live)
        k = contention(A_s, A_r, active)
        expired = active & (now >= self._deadline)
        self.stats_deadline_hits += int(expired.sum())

        cids = np.nonzero(active)[0]
        if self.lcof:
            key = [(0, self._deadline[c], 0, 0, table.arrival[c], c)
                   if expired[c] else
                   (1, q_new[c], k[c], int(~self._running[c]),
                    table.arrival[c], c) for c in cids]
        else:
            key = [(0, self._deadline[c], 0, 0, table.arrival[c], c)
                   if expired[c] else
                   (1, q_new[c], table.arrival[c], 0, 0, c) for c in cids]
        order = cids[sorted(range(len(cids)), key=lambda i: key[i])]

        cnt_s, cnt_r = table.flow_counts(live)
        avail_s = table.bw_send.copy()
        avail_r = table.bw_recv.copy()
        admitted = np.zeros(table.num_coflows, bool)
        missed = []
        for c in order:
            cs, cr = cnt_s[c], cnt_r[c]
            ps, pr = cs > 0, cr > 0
            if not ps.any() and not pr.any():
                continue
            r = np.inf
            if ps.any():
                r = min(r, (avail_s[ps] / cs[ps]).min())
            if pr.any():
                r = min(r, (avail_r[pr] / cr[pr]).min())
            if self.all_or_none and r < p.min_rate:
                missed.append(c)
                continue
            if r <= 0.0:
                missed.append(c)
                continue
            lo, hi = table.flow_lo[c], table.flow_hi[c]
            seg = rates[lo:hi]
            seg[live[lo:hi]] = r
            avail_s -= r * cs
            avail_r -= r * cr
            admitted[c] = True
            self.stats_admitted += 1

        if self.work_conservation and missed:
            wc_order = np.concatenate(
                [np.arange(table.flow_lo[c], table.flow_hi[c])
                 for c in missed])
            before = rates > 0
            _legacy_greedy_flow_alloc(table, wc_order, live, avail_s,
                                      avail_r, rates)
            self.stats_wc_flows += int(((rates > 0) & ~before).sum())

        if p.wc_admitted_round:
            for c in order:
                cs, cr = cnt_s[c], cnt_r[c]
                ps, pr = cs > 0, cr > 0
                if not (ps.any() or pr.any()) or c in missed:
                    continue
                r = np.inf
                if ps.any():
                    r = min(r, (avail_s[ps] / cs[ps]).min())
                if pr.any():
                    r = min(r, (avail_r[pr] / cr[pr]).min())
                if not np.isfinite(r) or r <= 0.0:
                    continue
                sel = live & (table.cid == c)
                rates[sel] += r
                avail_s -= r * cs
                avail_r -= r * cr

        self._running = admitted
        return rates


@st.composite
def traces(draw, max_coflows=8, max_flows=5):
    n = draw(st.integers(1, max_coflows))
    coflows = []
    fid = 0
    for c in range(n):
        arrival = draw(st.floats(0.0, 5.0, allow_nan=False))
        w = draw(st.integers(1, max_flows))
        flows = []
        for _ in range(w):
            src = draw(st.integers(0, PORTS - 1))
            dst = draw(st.integers(0, PORTS - 1))
            size = draw(st.floats(0.5, 20.0, allow_nan=False))
            flows.append(Flow(fid, src, dst, size))
            fid += 1
        coflows.append(Coflow(c, arrival, flows))
    return Trace(num_ports=PORTS, coflows=coflows)


def _run(trace, policy, topology=None):
    table = FlowTable.from_trace(trace, PARAMS.port_bw)
    sim = Simulator(PARAMS, topology=topology)
    return sim.run(table, policy)


def _assert_bitwise(res_a, res_b):
    np.testing.assert_array_equal(res_a.table.fct, res_b.table.fct)
    np.testing.assert_array_equal(res_a.table.cct, res_b.table.cct)
    np.testing.assert_array_equal(res_a.table.sent, res_b.table.sent)
    np.testing.assert_array_equal(res_a.table.rate, res_b.table.rate)


@given(traces())
@settings(max_examples=40, deadline=None)
def test_bigswitch_bitwise_vs_legacy(trace):
    """topology=None AND topology=BigSwitch() through the refactored
    allocation stack == the frozen pre-refactor Saath, bitwise."""
    legacy = _run(trace, _LegacySaath(PARAMS))
    for topo in (None, BigSwitch()):
        cur = _run(trace, make_policy("saath", PARAMS), topology=topo)
        _assert_bitwise(legacy, cur)


@given(traces())
@settings(max_examples=20, deadline=None)
def test_bigswitch_bitwise_no_wc(trace):
    """The non-work-conserving ablation path is guarded too (admission
    loop only — the branch fig10's A/N lane runs)."""
    legacy = _run(trace, _LegacySaath(PARAMS, work_conservation=False))
    cur = _run(trace, make_policy("saath", PARAMS,
                                  work_conservation=False),
               topology=BigSwitch())
    _assert_bitwise(legacy, cur)


@given(traces(max_coflows=5))
@settings(max_examples=15, deadline=None)
def test_greedy_policies_bitwise(trace):
    """Order-driven policies (Aalo) route through the refactored
    `greedy_flow_alloc`; with no topology the rates must be bitwise the
    legacy allocation."""
    from repro.core.policies.base import greedy_flow_alloc

    table = FlowTable.from_trace(trace, PARAMS.port_bw)
    rng = np.random.default_rng(1)
    table.sent = table.size * rng.uniform(0, 1, table.size.shape) * 0.3
    table.active[:] = True
    live = table.flow_live()
    order = np.argsort(table.arrival[table.cid], kind="stable")
    new = greedy_flow_alloc(table, order, live)
    old = _legacy_greedy_flow_alloc(
        table, order, live, table.bw_send.copy(), table.bw_recv.copy(),
        np.zeros(table.size.shape[0]))
    np.testing.assert_array_equal(new, old)


def test_api_run_bigswitch_bitwise():
    """`api.run` with topology=BigSwitch() == topology omitted, exactly
    (the Scenario field changes the hash, not the numbers)."""
    from repro.api import Scenario, run

    base = run(Scenario(policy="saath", engine="numpy",
                        synth=dict(num_coflows=8, num_ports=8, seed=3,
                                   max_width=16)))
    topo = run(Scenario(policy="saath", engine="numpy",
                        synth=dict(num_coflows=8, num_ports=8, seed=3,
                                   max_width=16),
                        topology=BigSwitch()))
    np.testing.assert_array_equal(base.row_cct(), topo.row_cct())


def test_pooled_vs_standalone_jax_topology():
    """A pooled session on a topology-pinned slab is bitwise the
    standalone session with the same topology (the pinned-feature
    contract extended to fabric models)."""
    from repro.api.pool import SessionPool
    from repro.api.session import SaathSession
    from repro.core.coflow import Coflow, Flow

    def _coflows():
        rng = np.random.default_rng(7)
        out = []
        for c in range(4):
            flows = [Flow(0, int(rng.integers(0, 8)),
                          int(rng.integers(0, 8)),
                          float(rng.uniform(1e6, 5e6)))
                     for _ in range(int(rng.integers(1, 4)))]
            out.append(Coflow(cid=c, arrival=0.0, flows=flows))
        return out

    for topo in (BigSwitch(), LeafSpine(hosts_per_leaf=4, oversub=2.0)):
        pool = SessionPool(SchedulerParams(), num_ports=8,
                           max_sessions=2, topology=topo)
        pooled = pool.session()
        solo = SaathSession(SchedulerParams(), num_ports=8,
                            backend="jax", topology=topo)
        pooled.submit(_coflows())
        solo.submit(_coflows())
        done_p = pooled.drain(max_seconds=120.0, step=0.5)
        done_s = solo.drain(max_seconds=120.0, step=0.5)
        assert len(done_p) == len(done_s) == 4
        for a, b in zip(done_p, done_s):
            assert a.cct == b.cct, topo
            np.testing.assert_array_equal(a.fct, b.fct)
        pooled.close()
        solo.close()
