"""SaathSession: online-vs-offline parity, slab lifecycle, wave planning.

The acceptance contract (ISSUE 4, tightened from ISSUE 3's 1%):
submitting a trace's coflows incrementally at their arrival times must
reproduce the offline jax `run(scenario)` CCTs BITWISE (>= 3 traces) —
the pending event horizon carried through `EngineState` makes resume
re-evaluation-free, exactly like the numpy oracle — and
`plan_waves(backend="jax")` must reproduce the numpy wave order
bitwise on the bridge workload. Long-horizon sessions re-base the slab
epoch so f32 arrivals keep δ resolution (regression-tested here).
"""
import numpy as np
import pytest

from repro.api import Scenario, SaathSession, run
from repro.core.coflow import Coflow, Flow, Trace
from repro.core.params import SchedulerParams

PORTS = 6
PARAMS = SchedulerParams(port_bw=1.0, delta=1e-2, start_threshold=4.0,
                         growth=4.0, num_queues=5)


def _trace(seed: int = 0, n: int = 6) -> Trace:
    rng = np.random.default_rng(seed)
    coflows, fid = [], 0
    for c in range(n):
        w = int(rng.integers(1, 5))
        flows = [Flow(fid + i, int(rng.integers(0, PORTS)),
                      int(rng.integers(0, PORTS)),
                      float(rng.uniform(1.0, 15.0))) for i in range(w)]
        fid += w
        coflows.append(Coflow(c, float(rng.uniform(0.0, 2.0)), flows))
    return Trace(num_ports=PORTS, coflows=coflows)


def _replay_online(trace: Trace, backend: str, **kw) -> np.ndarray:
    """Submit the trace's coflows at their arrival times; return CCTs
    in cid order."""
    sess = SaathSession(PARAMS, num_ports=PORTS, backend=backend, **kw)
    ccts = {}
    for c in sorted(trace.coflows, key=lambda c: (c.arrival, c.cid)):
        sess.advance(max(c.arrival - sess.now, 0.0))
        h = sess.submit([c])[0]
        ccts[h] = c.cid
        for d in sess.poll():                     # interleaved polling
            ccts[d.handle] = (ccts[d.handle], d.cct)
    for d in sess.drain(step=5.0, max_seconds=500.0):
        ccts[d.handle] = (ccts[d.handle], d.cct)
    out = np.full(len(trace.coflows), np.nan)
    for cid, cct in ccts.values():
        out[cid] = cct
    return out


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_session_online_matches_offline_run_jax_bitwise(seed):
    """The acceptance gate: incremental jax-slab replay is BITWISE the
    offline jitted run() — no tolerance. The resume-drift fix
    (EngineState's pending event horizon + anchored integration) and
    the t=0 grid origin make every evaluation instant, every f32
    rounding, and every §4.3 re-queue identical to the offline scan."""
    tr = _trace(seed)
    offline = run(Scenario(policy="saath", engine="jax", trace=tr,
                           params=PARAMS))
    got = _replay_online(tr, "jax")
    np.testing.assert_array_equal(got, offline.row_cct())
    # the cross-engine contract still holds through the same replay
    oracle = run(Scenario(policy="saath", engine="numpy", trace=tr,
                          params=PARAMS))
    np.testing.assert_allclose(got, oracle.row_cct(), rtol=1e-2,
                               atol=2 * PARAMS.delta)


def test_session_numpy_backend_is_the_exact_oracle():
    """The numpy session shares integrate_interval with the offline
    Simulator: incremental replay is exact, not just within 1%."""
    tr = _trace(3)
    offline = run(Scenario(policy="saath", engine="numpy", trace=tr,
                           params=PARAMS))
    got = _replay_online(tr, "numpy")
    np.testing.assert_allclose(got, offline.row_cct(), rtol=1e-9)


def test_session_slab_grows_geometrically_and_recycles_slots():
    """Submitting past capacity doubles the slab; polling retires
    coflows so later submissions reuse freed rows instead of growing."""
    sess = SaathSession(PARAMS, num_ports=PORTS, backend="jax",
                        min_coflow_capacity=4, min_flow_capacity=64)
    rng = np.random.default_rng(7)

    def burst(k, base):
        cfs = []
        for i in range(k):
            w = int(rng.integers(1, 4))
            flows = [Flow(j, int(rng.integers(0, PORTS)),
                          int(rng.integers(0, PORTS)),
                          float(rng.uniform(1.0, 8.0)))
                     for j in range(w)]
            cfs.append(Coflow(base + i, sess.now, flows))
        return sess.submit(cfs)

    burst(6, 0)                       # > 4 -> capacity doubles to 8
    sess.advance(1.0)
    assert sess._C_cap == 8
    done = sess.drain(step=5.0, max_seconds=500.0)
    assert len(done) == 6
    cap_after_first = sess._C_cap
    for round_ in range(3):           # churn: slots must be recycled
        burst(6, 100 * (round_ + 1))
        done = sess.drain(step=5.0, max_seconds=500.0)
        assert len(done) == 6
        assert all(np.isfinite(d.cct) and d.cct > 0 for d in done)
    assert sess._C_cap == cap_after_first, "freed rows were not recycled"


def test_session_poll_returns_each_coflow_exactly_once():
    tr = _trace(4)
    sess = SaathSession(PARAMS, num_ports=PORTS, backend="jax")
    handles = sess.submit(sorted(tr.coflows, key=lambda c: c.arrival))
    seen = []
    for _ in range(200):
        sess.advance(2.0)
        seen += [d.handle for d in sess.poll()]
        if not sess.num_live:
            break
    assert sorted(seen) == sorted(handles)
    assert len(seen) == len(set(seen))
    assert sess.poll() == []


def test_session_long_horizon_keeps_delta_resolution():
    """Regression (ISSUE 4): slab arrivals/times are f32, so a session
    hours into virtual time used to lose δ resolution (at t=2e6 ticks
    the absolute f32 grid is ~0.002s coarse vs δ=0.01). Re-basing the
    row epoch on re-pack stores offsets instead, so a late workload
    must replay bitwise-identically to the same workload at t=0."""
    from repro.api.pool import REBASE_TICKS

    t_off = 2.0 * REBASE_TICKS * PARAMS.delta   # 2^21 ticks ~ 21000s
    rng = np.random.default_rng(11)

    def workload(base):
        # binary-exact relative arrivals/sizes: the absolute f64 sums
        # below REBASE are exact, so any mismatch is the f32 slab's
        cfs, fid = [], 0
        for c in range(5):
            w = int(rng.integers(1, 4))
            flows = [Flow(fid + i, int(rng.integers(0, PORTS)),
                          int(rng.integers(0, PORTS)),
                          float(rng.integers(4, 60) * 0.25))
                     for i in range(w)]
            fid += w
            cfs.append(Coflow(c, base + 0.25 * int(rng.integers(0, 8)),
                              flows))
        return cfs

    state = rng.bit_generator.state
    base_cfs = workload(0.0)
    rng.bit_generator.state = state              # identical draws
    late_cfs = workload(t_off)

    sess0 = SaathSession(PARAMS, num_ports=PORTS, backend="jax")
    sess0.submit(base_cfs)
    want = {d.handle: (d.cct, tuple(d.fct - 0.0))
            for d in sess0.drain(step=5.0, max_seconds=500.0)}

    late = SaathSession(PARAMS, num_ports=PORTS, backend="jax")
    late.advance(t_off)                          # idle for ~6 hours
    assert late._epoch == 0                      # nothing packed yet
    late.submit(late_cfs)
    got = {d.handle: (d.cct, tuple(np.asarray(d.fct) - t_off))
           for d in late.drain(step=5.0, max_seconds=500.0)}
    assert late._epoch >= REBASE_TICKS           # the fix engaged
    assert got == want, "long-horizon session lost δ resolution"


def test_session_rejects_bad_input():
    sess = SaathSession(PARAMS, num_ports=4, backend="numpy")
    with pytest.raises(ValueError, match="port out of range"):
        sess.submit([Coflow(0, 0.0, [Flow(0, 9, 1, 5.0)])])
    with pytest.raises(ValueError, match="dt >= 0"):
        sess.advance(-1.0)
    with pytest.raises(ValueError, match="jax, numpy"):
        SaathSession(PARAMS, num_ports=4, backend="torch")
    with pytest.raises(ValueError, match="work_conservation"):
        SaathSession(PARAMS, num_ports=4, mechanisms={"wc": True})


# ---- wave planning (the framework-plane client) -----------------------


def _bridge_workload():
    from repro.runtime.coflow_bridge import CollectiveCoflow

    cfs = [CollectiveCoflow(f"grad/{b}", (48 - 4 * b) << 20,
                            ("ici:data",), b) for b in range(6)]
    cfs += [CollectiveCoflow(f"moe_a2a/{l}", 160 << 20, ("ici:model",),
                             10 + l) for l in (0, 1, 2)]
    cfs += [CollectiveCoflow("ckpt/upload", 4 << 30, ("dcn", "host"), 20),
            CollectiveCoflow("kv/migrate", 512 << 20, ("dcn",), 21),
            CollectiveCoflow("reshard/params", 1 << 30,
                             ("ici:data", "ici:model"), 22)]
    return cfs


def test_plan_waves_jax_backend_reproduces_numpy_wave_order_bitwise():
    """The acceptance gate for the framework plane: the session-slab
    planner and the host oracle emit IDENTICAL wave lists on the bridge
    workload (grad buckets + MoE a2a + background tenants)."""
    from repro.runtime.coflow_bridge import plan_waves

    cfs = _bridge_workload()
    wj = plan_waves(cfs, num_chips=16, backend="jax")
    wn = plan_waves(cfs, num_chips=16, backend="numpy")
    assert wj == wn
    flat = [n for w in wj for n in w]
    assert sorted(flat) == sorted(c.name for c in cfs)
    # gradient buckets all contend on ici:data -> strictly serialized
    grads = [n for n in flat if n.startswith("grad/")]
    assert grads == [f"grad/{i}" for i in range(6)]


@pytest.mark.slow
def test_online_service_demo():
    """The Poisson open-loop tenant-mix demo sustains a SaathSession
    across steps (nightly job; ~1 min)."""
    import importlib.util
    import pathlib

    spec = importlib.util.spec_from_file_location(
        "online_service",
        pathlib.Path(__file__).parent.parent / "examples" /
        "online_service.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    stats = mod.main(seconds=0.05, seed=0, backend="jax")
    assert stats["completed"] >= 10
    assert stats["unfinished"] == 0
    assert np.isfinite(stats["avg_cct"]) and stats["avg_cct"] > 0
