"""Vendored fallback for the `hypothesis` subset this suite uses.

When the real `hypothesis` package is unavailable, ``tests/conftest.py``
installs this module under ``sys.modules['hypothesis']`` so the
property-based test modules collect and run everywhere.  It is NOT a
hypothesis reimplementation: no shrinking, no example database, no
assume/filter machinery — just deterministic seeded-random sampling of
the strategy combinators the tests actually import (`given`, `settings`,
`strategies.integers/floats/lists/sampled_from/composite`).

Determinism: example i of test f draws from ``random.Random(hash((f
qualname, i)))`` so failures are reproducible run-to-run without any
state on disk.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib

DEFAULT_MAX_EXAMPLES = 25


class Strategy:
    """A sampler: example(rng) -> value."""

    def __init__(self, sample):
        self._sample = sample

    def example(self, rng: random.Random):
        return self._sample(rng)


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, *, allow_nan: bool = False,
           allow_infinity: bool = False) -> Strategy:
    del allow_nan, allow_infinity  # bounded draws are always finite
    return Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans() -> Strategy:
    return Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(elements) -> Strategy:
    elements = list(elements)
    if not elements:
        raise ValueError("sampled_from requires a non-empty sequence")
    return Strategy(lambda rng: rng.choice(elements))


def lists(elements: Strategy, *, min_size: int = 0, max_size: int = 10,
          unique: bool = False) -> Strategy:
    def sample(rng: random.Random):
        n = rng.randint(min_size, max_size)
        if not unique:
            return [elements.example(rng) for _ in range(n)]
        out, seen = [], set()
        # bounded retries: the sample space may be smaller than n
        for _ in range(100 * max(n, 1)):
            if len(out) >= n:
                break
            v = elements.example(rng)
            key = repr(v)
            if key not in seen:
                seen.add(key)
                out.append(v)
        if len(out) < min_size:
            raise ValueError("could not draw enough unique elements")
        return out

    return Strategy(sample)


def just(value) -> Strategy:
    return Strategy(lambda rng: value)


def composite(fn):
    """@st.composite def s(draw, **kw): ... -> s(**kw) is a Strategy."""
    @functools.wraps(fn)
    def builder(*args, **kwargs):
        def sample(rng: random.Random):
            return fn(lambda strat: strat.example(rng), *args, **kwargs)
        return Strategy(sample)
    return builder


class settings:  # noqa: N801 — mirrors hypothesis' lowercase decorator
    def __init__(self, max_examples: int = DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._compat_settings = self
        return fn


def given(*strategies_args, **strategies_kw):
    def decorate(fn):
        cfg = getattr(fn, "_compat_settings", None)
        n = cfg.max_examples if cfg is not None else DEFAULT_MAX_EXAMPLES

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            base = zlib.adler32(fn.__qualname__.encode())
            for i in range(n):
                rng = random.Random((base << 20) + i)
                drawn = [s.example(rng) for s in strategies_args]
                drawn_kw = {k: s.example(rng)
                            for k, s in strategies_kw.items()}
                try:
                    fn(*args, *drawn, **kwargs, **drawn_kw)
                except Exception as e:  # pragma: no cover - failure path
                    raise AssertionError(
                        f"falsifying example #{i} of {fn.__qualname__}: "
                        f"args={drawn!r} kwargs={drawn_kw!r}") from e

        # hide the drawn parameters from pytest's fixture resolution
        # (real hypothesis does the same); fixtures are unsupported here.
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        wrapper.hypothesis_compat = True
        return wrapper

    return decorate


# module object importable as `hypothesis.strategies`
strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.booleans = booleans
strategies.sampled_from = sampled_from
strategies.lists = lists
strategies.just = just
strategies.composite = composite
