"""Property fuzz (ISSUE 5): random interleavings of `submit` /
`advance` / `poll` / `release` / re-admission across a pooled slab.

Every script is replayed three ways with an IDENTICAL per-session op
cadence:

* a 4-row `SessionPool` (one device slab, one dispatch chain per
  fleet advance — rows go dirty mid-run via poll retirement, bursts
  double the shared capacities, released rows are recycled);
* standalone `backend="jax"` sessions (each a private 1-row slab);
* standalone `backend="numpy"` oracle sessions (the event-driven
  host reference).

The pooled completions must be BITWISE the standalone jax sessions'
(batching changes the dispatch structure, never the arithmetic). The
numpy oracle validates STRUCTURE: the same coflows complete exactly
once with their exact byte totals and causally-sane times. Its per-CCT
values are deliberately NOT gated: under adversarial burst contention
a single f32-vs-f64 rounding flips an admission decision and the
trajectories fork chaotically (reproducible on the PR-4 seed with
standalone sessions — it is a property of the two arithmetics, not of
the pool), so the 1% cross-engine envelope only holds for the
arrival-time replays tests/test_session.py gates.

`SAATH_FUZZ_EXAMPLES` scales the example count (CI's pool-fuzz smoke
raises it; the default keeps the fast suite fast).
"""
import os

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import SaathSession, SessionPool
from repro.core.coflow import Coflow, Flow
from repro.core.params import SchedulerParams

PORTS = 6
ROWS = 4
PARAMS = SchedulerParams(port_bw=1.0, delta=1e-2, start_threshold=4.0,
                         growth=4.0, num_queues=5)
EXAMPLES = int(os.environ.get("SAATH_FUZZ_EXAMPLES", "6"))

OPS = ("submit", "burst", "poll", "advance_one", "release", "admit")


def _coflows(seed: int, n: int, base: int = 0, spread: float = 3.0):
    rng = np.random.default_rng(seed)
    cfs, fid = [], 0
    for c in range(n):
        w = int(rng.integers(1, 4))
        flows = [Flow(fid + i, int(rng.integers(0, PORTS)),
                      int(rng.integers(0, PORTS)),
                      float(rng.uniform(1.0, 12.0))) for i in range(w)]
        fid += w
        cfs.append(Coflow(base + c, float(rng.uniform(0.0, spread)),
                          flows))
    return sorted(cfs, key=lambda c: (c.arrival, c.cid))


@st.composite
def scripts(draw):
    n_steps = draw(st.integers(min_value=5, max_value=10))
    steps = []
    for _ in range(n_steps):
        ops = []
        for _ in range(draw(st.integers(min_value=0, max_value=2))):
            ops.append((draw(st.sampled_from(OPS)),
                        draw(st.integers(min_value=0,
                                         max_value=ROWS - 1)),
                        draw(st.integers(min_value=0,
                                         max_value=9999))))
        steps.append((ops, draw(st.sampled_from([0.4, 0.9, 1.7]))))
    return steps


def _run_script(steps, make_session, advance_all):
    """Replay one op script; returns {(slot, generation, handle):
    (cct, fct-tuple)} over every completion any poll observed."""
    slots = [None] * ROWS
    gen = [0] * ROWS
    results = {}

    def harvest(i):
        s = slots[i]
        if s is not None:
            results.update(
                {(i, gen[i], d.handle): (d.cct, tuple(d.fct),
                                         tuple(d.size), d.arrival)
                 for d in s.poll()})

    # two seeded rows guarantee every script does real work
    for i in (0, 1):
        slots[i] = make_session()
        slots[i].submit(_coflows(100 + i, 3))

    for ops, dt in steps:
        for kind, slot, seed in ops:
            s = slots[slot]
            if kind == "admit" and s is None:
                gen[slot] += 1
                slots[slot] = make_session()
                slots[slot].submit(_coflows(seed, 2))
            elif kind == "release" and s is not None:
                s.close()               # unpolled completions drop
                slots[slot] = None
            elif kind == "submit" and s is not None:
                s.submit(_coflows(seed, 2, base=50))
            elif kind == "burst" and s is not None:
                # 18 coflows: past the 16-row floor -> the shared
                # coflow capacity doubles mid-run
                s.submit(_coflows(seed, 18, base=500, spread=1.0))
            elif kind == "poll":
                harvest(slot)
            elif kind == "advance_one" and s is not None:
                s.advance(0.5)          # moves ONLY this row
        live = [s for s in slots if s is not None]
        advance_all(live, dt)
        for i in range(ROWS):
            harvest(i)
    for _ in range(300):
        live = [s for s in slots if s is not None]
        if not any(s.num_live for s in live):
            break
        advance_all(live, 1.5)
        for i in range(ROWS):
            harvest(i)
    else:
        raise RuntimeError("fuzz script failed to drain")
    return results


@settings(max_examples=EXAMPLES, deadline=None)
@given(scripts())
def test_fuzzed_interleavings_match_standalone_and_numpy_oracle(steps):
    pool = SessionPool(PARAMS, num_ports=PORTS, max_sessions=ROWS)
    pooled = _run_script(steps, pool.session,
                         lambda live, dt: pool.advance(dt))

    def seq_advance(live, dt):
        for s in live:
            s.advance(dt)

    solo = _run_script(
        steps,
        lambda: SaathSession(PARAMS, num_ports=PORTS, backend="jax"),
        seq_advance)
    assert pooled == solo, "pooled rows diverged from standalone jax"

    oracle = _run_script(
        steps,
        lambda: SaathSession(PARAMS, num_ports=PORTS, backend="numpy"),
        seq_advance)
    assert sorted(pooled) == sorted(oracle), \
        "pooled completion set diverged from the numpy oracle"
    for key, (cct, fct, size, arrival) in pooled.items():
        o_cct, o_fct, o_size, o_arrival = oracle[key]
        # data integrity is exact across backends: the same coflow,
        # the same bytes, the same (clamped) arrival
        assert size == o_size and arrival == o_arrival
        # causal sanity on both planes; CCT values themselves are
        # chaos-amplified between f32 and f64 (see module docstring)
        for got, arr in ((cct, arrival), (o_cct, o_arrival)):
            assert np.isfinite(got) and got > 0
        eps = 2 * PARAMS.delta
        assert all(t >= arrival - eps for t in fct)
        assert all(t >= o_arrival - eps for t in o_fct)
