"""CoflowServer blitz (ISSUE 5): admission at the compiled row cap,
evict-then-reregister row recycling, per-tenant `Result` isolation
under interleaved advances, heterogeneous per-tenant params in one
dispatch, and the trim-on-poll bounded-history fix.

(The original admission/eviction smoke lives in tests/test_pool.py;
this module is the serving-plane deep-dive.)
"""
import numpy as np
import pytest

from repro.core.coflow import Coflow, Flow
from repro.core.params import SchedulerParams
from repro.launch.serve import (AdmissionError, CoflowServer,
                                TenantAggregates, TenantResult)

PORTS = 6
PARAMS = SchedulerParams(port_bw=1.0, delta=1e-2, start_threshold=4.0,
                         growth=4.0, num_queues=5)


def _coflows(seed: int, n: int, base: int = 0, spread: float = 2.0):
    rng = np.random.default_rng(seed)
    cfs, fid = [], 0
    for c in range(n):
        w = int(rng.integers(1, 5))
        flows = [Flow(fid + i, int(rng.integers(0, PORTS)),
                      int(rng.integers(0, PORTS)),
                      float(rng.uniform(1.0, 15.0))) for i in range(w)]
        fid += w
        cfs.append(Coflow(base + c, float(rng.uniform(0.0, spread)),
                          flows))
    return sorted(cfs, key=lambda c: (c.arrival, c.cid))


def _drain(srv, tenants, max_steps=200, step=1.0):
    for _ in range(max_steps):
        srv.advance(step)
        if not any(srv.num_live(t) for t in tenants):
            return
    raise RuntimeError("server failed to drain")


def test_server_evict_then_reregister_recycles_the_row():
    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=2)
    srv.register("a")
    srv.register("b")
    with pytest.raises(AdmissionError):
        srv.register("c")
    srv.submit("a", _coflows(0, 2))
    srv.submit("b", _coflows(1, 2))
    srv.advance(0.5)                      # a/b mid-flight
    srv.evict("a")                        # drops a's unfinished work
    srv.register("c")                     # the freed row, recycled
    assert srv.occupancy == (2, 2)
    srv.submit("c", _coflows(2, 3))
    _drain(srv, ["b", "c"])
    assert len(srv.poll("c")) == 3
    assert len(srv.poll("b")) == 2        # b rode through the churn
    with pytest.raises(KeyError, match="unknown tenant"):
        srv.poll("a")
    # a second evict/register cycle on the same row still works
    srv.evict("c")
    srv.register("d")
    srv.submit("d", _coflows(3, 1))
    _drain(srv, ["b", "d"])
    assert len(srv.poll("d")) == 1


def test_server_per_tenant_result_isolation_under_interleaving():
    """Tenants submitting and completing at interleaved times never see
    each other's completions, counts, or aggregates."""
    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=3)
    for t in ("x", "y", "z"):
        srv.register(t)
    srv.submit("x", _coflows(10, 3))
    srv.submit("y", _coflows(11, 2))
    srv.advance(2.0)
    srv.submit("z", _coflows(12, 4))      # z starts late
    srv.advance(2.0)
    srv.submit("x", _coflows(13, 2, base=100))  # x tops up mid-run
    _drain(srv, ["x", "y", "z"])

    res = {t: srv.result(t) for t in ("x", "y", "z")}
    assert int(res["x"].num_coflows[0]) == 5
    assert int(res["y"].num_coflows[0]) == 2
    assert int(res["z"].num_coflows[0]) == 4
    for t in ("x", "y", "z"):
        assert np.isfinite(res[t].avg_cct[0])
        assert np.isfinite(res[t].makespan[0])
    # polls are per-tenant streams: each completion appears exactly
    # once, under its own tenant
    polls = {t: srv.poll(t) for t in ("x", "y", "z")}
    assert [len(polls[t]) for t in ("x", "y", "z")] == [5, 2, 4]
    assert all(srv.poll(t) == [] for t in ("x", "y", "z"))
    # aggregates survive the poll trim, arrays shrink to the window
    for t, n in (("x", 5), ("y", 2), ("z", 4)):
        after = srv.result(t)
        assert int(after.num_coflows[0]) == n
        np.testing.assert_allclose(after.avg_cct, res[t].avg_cct)
        np.testing.assert_allclose(after.makespan, res[t].makespan)


def test_server_heterogeneous_tenant_params_in_one_dispatch():
    """Two tenants with different thresholds, identical traces, one
    fleet dispatch: the fast-demotion tenant's coflow moves down the
    queues while the huge-threshold tenant's stays in queue 0."""
    slow = SchedulerParams(port_bw=1.0, delta=1e-2,
                           start_threshold=1e9, growth=4.0,
                           num_queues=5)
    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=2)
    srv.register("fast")                      # pool defaults: S = 4.0
    srv.register("slow", params=slow)         # S = 1e9: never demoted
    wl = [Coflow(0, 0.0, [Flow(0, 0, 1, 12.0)])]
    h_fast = srv.submit("fast", wl)[0]
    h_slow = srv.submit("slow", wl)[0]
    d0 = srv.pool.io["dispatches"]
    srv.advance(6.0)       # ~6 bytes sent: past 4.0, far below 1e9
    assert srv.pool.io["dispatches"] == d0 + 1   # ONE fleet dispatch
    q_fast = srv._tenants["fast"].snapshot()[h_fast]["queue"]
    q_slow = srv._tenants["slow"].snapshot()[h_slow]["queue"]
    assert q_fast >= 1, "fast tenant should have been demoted"
    assert q_slow == 0, "slow tenant must still be in queue 0"
    _drain(srv, ["fast", "slow"])
    assert len(srv.poll("fast")) == 1 and len(srv.poll("slow")) == 1


def test_server_rejects_incompatible_tenant_params():
    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=2)
    with pytest.raises(ValueError, match="num_queues"):
        srv.register("bad", params=SchedulerParams(num_queues=3))
    assert srv.occupancy == (0, 2)            # nothing was admitted
    srv.register("ok")                        # the row is still free
    with pytest.raises(ValueError, match="mechanism"):
        srv.register("worse", mechanisms={"wc": True})


def test_server_trim_on_poll_keeps_aggregates_stable_and_memory_bounded():
    """The ISSUE-5 bugfix: per-tenant history is folded into O(1)
    incremental aggregates and trimmed on poll (with a history_limit
    backstop), so a long-lived tenant's aggregates stay exact while
    the server's retained buffers stay bounded."""
    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=1,
                       history_limit=8)
    srv.register("t")
    total = 0
    for round_ in range(4):
        srv.submit("t", _coflows(50 + round_, 3, base=10 * round_))
        _drain(srv, ["t"])
        total += 3
        got = srv.poll("t")
        assert len(got) == 3                  # every completion, once
        assert srv.stats()["retained"] == 0   # trim-on-poll
        agg = srv.aggregates("t")
        assert agg.coflows == total           # lifetime count survives
        assert agg.trimmed == 0
    res1 = srv.result("t")
    res2 = srv.result("t")                    # a second look: stable
    assert int(res1.num_coflows[0]) == total
    np.testing.assert_allclose(res1.avg_cct, res2.avg_cct)
    np.testing.assert_allclose(res1.makespan, res2.makespan)
    assert np.isfinite(res1.avg_cct[0]) and res1.avg_cct[0] > 0
    assert isinstance(res1, TenantResult)

    # a tenant that NEVER polls: the history_limit backstop bounds the
    # retained records; the aggregates keep exact lifetime counts
    for round_ in range(4):
        srv.submit("t", _coflows(90 + round_, 3, base=100 + 10 * round_))
        _drain(srv, ["t"])
        total += 3
    assert srv.stats()["retained"] <= 8
    agg = srv.aggregates("t")
    assert agg.coflows == total
    assert agg.trimmed == 12 - 8
    assert isinstance(agg, TenantAggregates)
    # the retained-window Result still reports the exact lifetime
    # aggregates (trimming shrank only its arrays)
    res = srv.result("t")
    assert int(res.num_coflows[0]) == total
    assert res.cct.shape[1] <= 8
    expect = agg.cct_sum / agg.coflows
    np.testing.assert_allclose(res.avg_cct[0], expect)
