"""CoflowServer blitz (ISSUE 5): admission at the compiled row cap,
evict-then-reregister row recycling, per-tenant `Result` isolation
under interleaved advances, heterogeneous per-tenant params in one
dispatch, and the trim-on-poll bounded-history fix.

(The original admission/eviction smoke lives in tests/test_pool.py;
this module is the serving-plane deep-dive.)
"""
import numpy as np
import pytest

from repro.analysis.sanitize import (assert_no_recompiles,
                                     assert_no_transfers)
from repro.core.coflow import Coflow, Flow
from repro.core.params import SchedulerParams
from repro.launch.serve import (AdmissionError, CoflowServer,
                                TenantAggregates, TenantResult)

PORTS = 6
PARAMS = SchedulerParams(port_bw=1.0, delta=1e-2, start_threshold=4.0,
                         growth=4.0, num_queues=5)


def _coflows(seed: int, n: int, base: int = 0, spread: float = 2.0):
    rng = np.random.default_rng(seed)
    cfs, fid = [], 0
    for c in range(n):
        w = int(rng.integers(1, 5))
        flows = [Flow(fid + i, int(rng.integers(0, PORTS)),
                      int(rng.integers(0, PORTS)),
                      float(rng.uniform(1.0, 15.0))) for i in range(w)]
        fid += w
        cfs.append(Coflow(base + c, float(rng.uniform(0.0, spread)),
                          flows))
    return sorted(cfs, key=lambda c: (c.arrival, c.cid))


def _drain(srv, tenants, max_steps=200, step=1.0):
    for _ in range(max_steps):
        srv.advance(step)
        if not any(srv.num_live(t) for t in tenants):
            return
    raise RuntimeError("server failed to drain")


def test_server_evict_then_reregister_recycles_the_row():
    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=2)
    srv.register("a")
    srv.register("b")
    with pytest.raises(AdmissionError):
        srv.register("c")
    srv.submit("a", _coflows(0, 2))
    srv.submit("b", _coflows(1, 2))
    srv.advance(0.5)                      # a/b mid-flight
    srv.evict("a")                        # drops a's unfinished work
    srv.register("c")                     # the freed row, recycled
    assert srv.occupancy == (2, 2)
    srv.submit("c", _coflows(2, 3))
    _drain(srv, ["b", "c"])
    assert len(srv.poll("c")) == 3
    assert len(srv.poll("b")) == 2        # b rode through the churn
    with pytest.raises(KeyError, match="unknown tenant"):
        srv.poll("a")
    # a second evict/register cycle on the same row still works -- and
    # by now every program (advance, scatter, gather, blank-row) is
    # warm, so the steady-state recycle path must neither recompile
    # nor move an unaccounted byte host-to-device
    with assert_no_recompiles(), assert_no_transfers():
        srv.evict("c")
        srv.register("d")
        srv.submit("d", _coflows(3, 1))
        _drain(srv, ["b", "d"])
        assert len(srv.poll("d")) == 1


def test_server_per_tenant_result_isolation_under_interleaving():
    """Tenants submitting and completing at interleaved times never see
    each other's completions, counts, or aggregates."""
    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=3)
    for t in ("x", "y", "z"):
        srv.register(t)
    srv.submit("x", _coflows(10, 3))
    srv.submit("y", _coflows(11, 2))
    srv.advance(2.0)
    srv.submit("z", _coflows(12, 4))      # z starts late
    srv.advance(2.0)
    srv.submit("x", _coflows(13, 2, base=100))  # x tops up mid-run
    _drain(srv, ["x", "y", "z"])

    res = {t: srv.result(t) for t in ("x", "y", "z")}
    assert int(res["x"].num_coflows[0]) == 5
    assert int(res["y"].num_coflows[0]) == 2
    assert int(res["z"].num_coflows[0]) == 4
    for t in ("x", "y", "z"):
        assert np.isfinite(res[t].avg_cct[0])
        assert np.isfinite(res[t].makespan[0])
    # polls are per-tenant streams: each completion appears exactly
    # once, under its own tenant
    polls = {t: srv.poll(t) for t in ("x", "y", "z")}
    assert [len(polls[t]) for t in ("x", "y", "z")] == [5, 2, 4]
    assert all(srv.poll(t) == [] for t in ("x", "y", "z"))
    # aggregates survive the poll trim, arrays shrink to the window
    for t, n in (("x", 5), ("y", 2), ("z", 4)):
        after = srv.result(t)
        assert int(after.num_coflows[0]) == n
        np.testing.assert_allclose(after.avg_cct, res[t].avg_cct)
        np.testing.assert_allclose(after.makespan, res[t].makespan)


def test_server_heterogeneous_tenant_params_in_one_dispatch():
    """Two tenants with different thresholds, identical traces, one
    fleet dispatch: the fast-demotion tenant's coflow moves down the
    queues while the huge-threshold tenant's stays in queue 0."""
    slow = SchedulerParams(port_bw=1.0, delta=1e-2,
                           start_threshold=1e9, growth=4.0,
                           num_queues=5)
    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=2)
    srv.register("fast")                      # pool defaults: S = 4.0
    srv.register("slow", params=slow)         # S = 1e9: never demoted
    wl = [Coflow(0, 0.0, [Flow(0, 0, 1, 12.0)])]
    h_fast = srv.submit("fast", wl)[0]
    h_slow = srv.submit("slow", wl)[0]
    d0 = srv.pool.io["dispatches"]
    srv.advance(6.0)       # ~6 bytes sent: past 4.0, far below 1e9
    assert srv.pool.io["dispatches"] == d0 + 1   # ONE fleet dispatch
    q_fast = srv._tenants["fast"].snapshot()[h_fast]["queue"]
    q_slow = srv._tenants["slow"].snapshot()[h_slow]["queue"]
    assert q_fast >= 1, "fast tenant should have been demoted"
    assert q_slow == 0, "slow tenant must still be in queue 0"
    _drain(srv, ["fast", "slow"])
    assert len(srv.poll("fast")) == 1 and len(srv.poll("slow")) == 1


def test_server_rejects_incompatible_tenant_params():
    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=2)
    with pytest.raises(ValueError, match="num_queues"):
        srv.register("bad", params=SchedulerParams(num_queues=3))
    assert srv.occupancy == (0, 2)            # nothing was admitted
    srv.register("ok")                        # the row is still free
    with pytest.raises(ValueError, match="mechanism"):
        srv.register("worse", mechanisms={"wc": True})


def test_server_trim_on_poll_keeps_aggregates_stable_and_memory_bounded():
    """The ISSUE-5 bugfix: per-tenant history is folded into O(1)
    incremental aggregates and trimmed on poll (with a history_limit
    backstop), so a long-lived tenant's aggregates stay exact while
    the server's retained buffers stay bounded."""
    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=1,
                       history_limit=8)
    srv.register("t")
    total = 0
    for round_ in range(4):
        srv.submit("t", _coflows(50 + round_, 3, base=10 * round_))
        _drain(srv, ["t"])
        total += 3
        got = srv.poll("t")
        assert len(got) == 3                  # every completion, once
        assert srv.stats()["retained"] == 0   # trim-on-poll
        agg = srv.aggregates("t")
        assert agg.coflows == total           # lifetime count survives
        assert agg.trimmed == 0
    res1 = srv.result("t")
    res2 = srv.result("t")                    # a second look: stable
    assert int(res1.num_coflows[0]) == total
    np.testing.assert_allclose(res1.avg_cct, res2.avg_cct)
    np.testing.assert_allclose(res1.makespan, res2.makespan)
    assert np.isfinite(res1.avg_cct[0]) and res1.avg_cct[0] > 0
    assert isinstance(res1, TenantResult)

    # a tenant that NEVER polls: the history_limit backstop bounds the
    # retained records; the aggregates keep exact lifetime counts
    for round_ in range(4):
        srv.submit("t", _coflows(90 + round_, 3, base=100 + 10 * round_))
        _drain(srv, ["t"])
        total += 3
    assert srv.stats()["retained"] <= 8
    agg = srv.aggregates("t")
    assert agg.coflows == total
    assert agg.trimmed == 12 - 8
    assert isinstance(agg, TenantAggregates)
    # the retained-window Result still reports the exact lifetime
    # aggregates (trimming shrank only its arrays)
    res = srv.result("t")
    assert int(res.num_coflows[0]) == total
    assert res.cct.shape[1] <= 8
    expect = agg.cct_sum / agg.coflows
    np.testing.assert_allclose(res.avg_cct[0], expect)


# ---- the ISSUE-6 serving-layer bugfix sweep -------------------------------


def test_server_noncap_runtime_error_propagates_untouched(monkeypatch):
    """The register bugfix: only the pool's `PoolFullError` is an
    admission decision. Any other RuntimeError is a real fault — it
    must propagate as itself (not as `AdmissionError`) and must NOT
    bump the `rejected` counter."""
    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=2)

    def boom(*a, **k):
        raise RuntimeError("engine exploded mid-admission")

    monkeypatch.setattr(srv.pool, "session", boom)
    with pytest.raises(RuntimeError, match="engine exploded") as ei:
        srv.register("t")
    assert not isinstance(ei.value, AdmissionError)
    assert srv.rejected == 0
    assert "t" not in srv.tenants
    monkeypatch.undo()
    # the genuine cap still counts and still translates
    srv.register("a")
    srv.register("b")
    with pytest.raises(AdmissionError, match="admission cap"):
        srv.register("c")
    assert srv.rejected == 1


def test_aggregates_zero_flow_completions_yield_nan_makespan():
    """The makespan bugfix: folding completions whose `fct` arrays are
    all empty bumps `coflows` without touching `last_fct`; the old
    coflows-gate then reported the -inf initializer. The guard is on
    `last_fct` being finite."""
    from repro.api.session import CompletedCoflow

    agg = TenantAggregates()
    agg.fold([CompletedCoflow(handle=0, arrival=0.0, cct=0.0,
                              fct=np.array([]))])
    assert agg.coflows == 1
    assert np.isnan(agg.makespan), \
        f"zero-flow fold must give NaN makespan, got {agg.makespan}"
    # a later real completion restores a finite makespan
    agg.fold([CompletedCoflow(handle=1, arrival=0.0, cct=2.5,
                              fct=np.array([2.5, 1.0]))])
    assert agg.makespan == 2.5


def test_tenant_result_lifts_lifetime_bytes():
    """`TenantResult.from_window` lifts lifetime `bytes` exactly like
    `num_coflows`/`num_flows`: after a poll trims the window, the
    lifetime byte total survives in `total_bytes`."""
    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=1)
    srv.register("t")
    wl = _coflows(7, 3)
    sent = sum(c.total_bytes for c in wl)
    srv.submit("t", wl)
    _drain(srv, ["t"])
    srv.poll("t")                          # trims the window to zero
    res = srv.result("t")
    assert res.cct.shape[1] == 0           # window empty...
    assert int(res.num_coflows[0]) == 3    # ...lifetime counts survive
    np.testing.assert_allclose(res.total_bytes[0], sent)


def test_advance_harvests_only_completed_rows():
    """The harvest bugfix: `advance` routes through the pool's
    new-completion bitmap, so a tenant whose row finished nothing is
    NEVER polled — a clean tenant costs zero host work per fleet
    step (previously every advance probed every tenant)."""
    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=3)
    polls = {t: 0 for t in ("busy", "idle", "empty")}
    for t in polls:
        srv.register(t)
        sess = srv._tenants[t]
        orig = sess.poll

        def counted(t=t, orig=orig):
            polls[t] += 1
            return orig()

        sess.poll = counted
    srv.submit("busy", _coflows(0, 2))
    srv.submit("idle", _coflows(1, 1, spread=0.0))
    steps = 0
    for _ in range(60):
        srv.advance(1.0)
        steps += 1
        if not (srv.num_live("busy") or srv.num_live("idle")):
            break
    assert steps < 60
    # a tenant with NO work is never polled by the advance loop
    assert polls["empty"] == 0
    # live tenants are polled only when completions actually landed —
    # far fewer probes than one per tenant per step
    assert 1 <= polls["busy"] <= 3
    assert 1 <= polls["idle"] <= 3
    # nothing was lost to the lazy harvest
    assert len(srv.poll("busy")) == 2
    assert len(srv.poll("idle")) == 1


# ---- overload shedding ----------------------------------------------------


def test_quota_reject_sheds_whole_batches():
    from repro.launch.serve import QuotaExceededError, TenantQuota

    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=2)
    srv.register("q", quota=TenantQuota(max_live_coflows=2))
    srv.register("free")                   # no quota: never shed
    wl = _coflows(20, 3)
    with pytest.raises(QuotaExceededError):
        srv.submit("q", wl)                # 3 > 2: refused WHOLE
    assert srv.num_live("q") == 0          # nothing partially admitted
    assert srv.aggregates("q").shed == 3
    srv.submit("q", wl[:2])                # in-budget batch admits
    with pytest.raises(QuotaExceededError):
        srv.submit("q", wl[2:])            # row full: shed again
    assert srv.aggregates("q").shed == 4
    srv.submit("free", _coflows(21, 6))    # unquota'd tenant unbounded
    _drain(srv, ["q", "free"])
    assert len(srv.poll("q")) == 2
    assert len(srv.poll("free")) == 6
    st = srv.stats()
    assert st["shed"] == 4 and st["deferred"] == 0


def test_quota_defer_admits_as_budget_frees():
    """policy="defer": the in-budget prefix is admitted now, the rest
    queues server-side and is admitted by later advances as
    completions free the budget; every deferred coflow eventually
    completes (none lost, none duplicated)."""
    from repro.launch.serve import TenantQuota

    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=1)
    srv.register("d", quota=TenantQuota(max_live_coflows=2,
                                        policy="defer"))
    wl = _coflows(30, 6, spread=0.0)
    handles = srv.submit("d", wl)
    assert len(handles) == 2               # the in-budget prefix
    assert srv.num_live("d") == 2
    agg = srv.aggregates("d")
    assert agg.deferred == 4 and agg.shed == 0
    assert srv.stats()["deferred_pending"] == 4
    done = 0
    # warm phase: run until the first completion has exercised the
    # gather path (the sanitizers assert cache hits, not first builds)
    for _ in range(100):
        srv.advance(1.0)
        done += len(srv.poll("d"))
        assert srv.num_live("d") <= 2      # the budget is a hard cap
        if done:
            break
    assert done, "no completion within the warmup budget"
    # steady state: deferred re-admission rides the SAME programs --
    # admitting a queued coflow must not recompile or upload
    # unaccounted bytes
    with assert_no_recompiles(), assert_no_transfers():
        for _ in range(300):
            srv.advance(1.0)
            done += len(srv.poll("d"))
            assert srv.num_live("d") <= 2
            if done == 6 and srv.stats()["deferred_pending"] == 0:
                break
    assert done == 6, f"only {done}/6 deferred coflows completed"
    assert srv.aggregates("d").coflows == 6
    assert srv.aggregates("d").shed == 0


def test_quota_slo_sheds_aged_deferrals_keeping_backlog_bounded():
    """The overload scenario: a tenant pushed far past its budget with
    a tight SLO sheds the aged backlog instead of queueing it into
    unbounded latency — deferred_pending drains to zero, the shed
    counter accounts for every dropped coflow, and the live load
    never exceeds the budget."""
    from repro.launch.serve import TenantQuota

    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=1)
    srv.register("o", quota=TenantQuota(max_live_coflows=1,
                                        slo=2.0, policy="defer"))
    wl = _coflows(40, 12, spread=0.0)      # 12x the live budget
    srv.submit("o", wl)
    agg = srv.aggregates("o")
    assert agg.deferred == 11
    done = 0
    for _ in range(100):
        srv.advance(1.0)
        done += len(srv.poll("o"))
        assert srv.num_live("o") <= 1
        if srv.stats()["deferred_pending"] == 0 and \
                srv.num_live("o") == 0:
            break
    st = srv.stats()
    assert st["deferred_pending"] == 0, "backlog must drain, not grow"
    assert agg.shed > 0, "a tight SLO must shed aged deferrals"
    # every coflow is accounted for exactly once: completed or shed
    assert agg.coflows + agg.shed == 12
    assert done == agg.coflows
