"""SessionPool: K sessions on one slab == K standalone sessions,
bitwise — plus admission control and the CoflowServer front door.

The acceptance contract (ISSUE 4): a pooled fleet changes the DISPATCH
structure (one vmapped scan instead of K sequential ones), never the
arithmetic. Mid-run admission, capacity doubling triggered by one row,
and a session finishing while others run must all leave every
session's CCTs/FCTs bitwise-equal to the same session run standalone.
"""
import numpy as np
import pytest

from repro.api import SaathSession, SessionPool
from repro.core.coflow import Coflow, Flow
from repro.core.params import SchedulerParams

PORTS = 6
PARAMS = SchedulerParams(port_bw=1.0, delta=1e-2, start_threshold=4.0,
                         growth=4.0, num_queues=5)


def _coflows(seed: int, n: int, base: int = 0, spread: float = 2.0):
    rng = np.random.default_rng(seed)
    cfs, fid = [], 0
    for c in range(n):
        w = int(rng.integers(1, 5))
        flows = [Flow(fid + i, int(rng.integers(0, PORTS)),
                      int(rng.integers(0, PORTS)),
                      float(rng.uniform(1.0, 15.0))) for i in range(w)]
        fid += w
        cfs.append(Coflow(base + c, float(rng.uniform(0.0, spread)),
                          flows))
    return cfs


def _harvest(results, sessions):
    for i, s in enumerate(sessions):
        results[i].update({d.handle: (d.cct, tuple(d.fct))
                           for d in s.poll()})


@pytest.mark.parametrize("seed", [0, 1])
def test_pool_bitwise_equals_standalone_sessions(seed):
    """The property test: K pooled sessions vs K standalone ones under
    an adversarial script — session 2 admitted mid-run, session 0
    doubling the shared coflow capacity with a burst, session 1 tiny so
    it finishes while the others still run — produce bitwise-identical
    per-session CCTs and FCTs. The script is advance-cadence-identical
    on both sides (same dt sequence from each session's birth)."""
    workloads = [_coflows(seed, 6), _coflows(seed + 50, 2, spread=0.5),
                 _coflows(seed + 100, 5)]
    burst = _coflows(seed + 200, 20, base=500, spread=1.0)

    def script(make_session, advance_all):
        # phases: [s0, s1] run; s2 admitted after 3 steps; s0 bursts
        # past the 16-row coflow capacity after 5 steps
        sessions = [make_session(), make_session()]
        results = [dict(), dict(), dict()]
        for s, w in zip(sessions, workloads[:2]):
            s.submit(sorted(w, key=lambda c: (c.arrival, c.cid)))
        s1_drained_at = None
        for step in range(200):
            if step == 3:
                s2 = make_session()
                s2.submit(sorted(workloads[2],
                                 key=lambda c: (c.arrival, c.cid)))
                sessions.append(s2)
            if step == 5:
                sessions[0].submit(
                    sorted(burst, key=lambda c: (c.arrival, c.cid)))
            advance_all(sessions, 0.9)
            _harvest(results, sessions)
            if s1_drained_at is None and not sessions[1].num_live:
                s1_drained_at = step
            if not any(s.num_live for s in sessions):
                assert s1_drained_at < step, \
                    "script expects session 1 to finish early"
                return results
        raise RuntimeError("script failed to drain")

    pool = SessionPool(PARAMS, num_ports=PORTS, max_sessions=4)

    def pool_advance(sessions, dt):
        pool.advance(dt)  # ONE dispatch chain for every row

    pooled = script(pool.session, pool_advance)
    assert pool._C_cap >= 26                     # the burst doubled it

    def standalone_advance(sessions, dt):
        for s in sessions:
            s.advance(dt)

    solo = script(
        lambda: SaathSession(PARAMS, num_ports=PORTS, backend="jax"),
        standalone_advance)
    assert pooled == solo


def test_pool_admission_cap_and_row_recycling():
    pool = SessionPool(PARAMS, num_ports=PORTS, max_sessions=2)
    a, b = pool.session(), pool.session()
    assert pool.num_sessions == 2
    with pytest.raises(RuntimeError, match="full"):
        pool.session()
    a.submit(_coflows(3, 2))
    pool.advance(0.5)
    pool.release(a)                  # frees row 0 (drops a's coflows)
    with pytest.raises(RuntimeError, match="closed"):
        a.advance(0.1)
    c = pool.session()               # recycled row
    assert c._row == 0 and pool.num_sessions == 2
    c.submit(_coflows(4, 2))
    done = []
    for _ in range(100):
        pool.advance(1.0)
        done += c.poll()
        if not c.num_live:
            break
    assert len(done) == 2 and all(np.isfinite(d.cct) for d in done)
    assert b.num_live == 0           # b never submitted; clock moved
    assert b.now > 0


def test_pool_idle_sessions_do_not_block_the_fleet():
    pool = SessionPool(PARAMS, num_ports=PORTS, max_sessions=3)
    idle = pool.session()
    busy = pool.session()
    busy.submit(_coflows(7, 3))
    done = []
    for _ in range(100):
        pool.advance(1.0)
        done += busy.poll()
        if not busy.num_live:
            break
    assert len(done) == 3
    assert idle.num_live == 0 and idle.now == busy.now


def test_single_session_advance_noops_other_rows():
    """`advance` on ONE pooled view moves only its row; the others'
    coordinators stay frozen at their own horizons."""
    pool = SessionPool(PARAMS, num_ports=PORTS, max_sessions=2)
    a, b = pool.session(), pool.session()
    a.submit(_coflows(9, 3))
    b.submit(_coflows(10, 3))
    a.advance(200.0)
    assert a.now == 200.0 and b.now == 0.0
    done_a = a.poll()
    assert len(done_a) == 3          # a drained alone
    assert not b.poll()              # b never ticked
    b.advance(200.0)
    assert len(b.poll()) == 3


# ---- the serving front door (launch.serve.CoflowServer) ----------------


def test_coflow_server_admission_results_and_eviction():
    from repro.launch.serve import AdmissionError, CoflowServer

    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=2)
    srv.register("alice")
    srv.register("bob")
    with pytest.raises(ValueError, match="already registered"):
        srv.register("alice")
    with pytest.raises(AdmissionError, match="admission cap"):
        srv.register("carol")
    assert srv.rejected == 1
    with pytest.raises(KeyError, match="unknown tenant"):
        srv.submit("carol", _coflows(1, 1))

    srv.submit("alice", _coflows(20, 3))
    srv.submit("bob", _coflows(21, 2))
    for _ in range(100):
        srv.advance(1.0)
        if not (srv.num_live("alice") or srv.num_live("bob")):
            break
    res = srv.result("alice")                # normalized per-tenant
    assert int(res.num_coflows[0]) == 3
    assert len(srv.poll("alice")) == 3       # result() is a pure
    assert srv.poll("alice") == []           # accessor; poll is once-each
    assert np.isfinite(res.avg_cct[0]) and np.isfinite(res.makespan[0])
    idle = srv.result("bob")
    assert int(idle.num_coflows[0]) == 2

    srv.evict("alice")
    srv.register("carol")                    # the freed row
    assert sorted(srv.tenants) == ["bob", "carol"]
    assert np.isnan(srv.result("carol").avg_cct[0])   # nothing yet
