"""SessionPool: K sessions on one slab == K standalone sessions,
bitwise — plus admission control and the CoflowServer front door.

The acceptance contract (ISSUE 4): a pooled fleet changes the DISPATCH
structure (one vmapped scan instead of K sequential ones), never the
arithmetic. Mid-run admission, capacity doubling triggered by one row,
and a session finishing while others run must all leave every
session's CCTs/FCTs bitwise-equal to the same session run standalone.
"""
import dataclasses

import numpy as np
import pytest

from repro.analysis.sanitize import assert_no_transfers
from repro.api import SaathSession, SessionPool
from repro.core.coflow import Coflow, Flow
from repro.core.params import SchedulerParams

PORTS = 6
PARAMS = SchedulerParams(port_bw=1.0, delta=1e-2, start_threshold=4.0,
                         growth=4.0, num_queues=5)


def _coflows(seed: int, n: int, base: int = 0, spread: float = 2.0):
    rng = np.random.default_rng(seed)
    cfs, fid = [], 0
    for c in range(n):
        w = int(rng.integers(1, 5))
        flows = [Flow(fid + i, int(rng.integers(0, PORTS)),
                      int(rng.integers(0, PORTS)),
                      float(rng.uniform(1.0, 15.0))) for i in range(w)]
        fid += w
        cfs.append(Coflow(base + c, float(rng.uniform(0.0, spread)),
                          flows))
    return cfs


def _harvest(results, sessions):
    for i, s in enumerate(sessions):
        results[i].update({d.handle: (d.cct, tuple(d.fct))
                           for d in s.poll()})


@pytest.mark.parametrize("seed", [0, 1])
def test_pool_bitwise_equals_standalone_sessions(seed):
    """The property test: K pooled sessions vs K standalone ones under
    an adversarial script — session 2 admitted mid-run, session 0
    doubling the shared coflow capacity with a burst, session 1 tiny so
    it finishes while the others still run — produce bitwise-identical
    per-session CCTs and FCTs. The script is advance-cadence-identical
    on both sides (same dt sequence from each session's birth)."""
    workloads = [_coflows(seed, 6), _coflows(seed + 50, 2, spread=0.5),
                 _coflows(seed + 100, 5)]
    burst = _coflows(seed + 200, 20, base=500, spread=1.0)

    def script(make_session, advance_all):
        # phases: [s0, s1] run; s2 admitted after 3 steps; s0 bursts
        # past the 16-row coflow capacity after 5 steps
        sessions = [make_session(), make_session()]
        results = [dict(), dict(), dict()]
        for s, w in zip(sessions, workloads[:2]):
            s.submit(sorted(w, key=lambda c: (c.arrival, c.cid)))
        s1_drained_at = None
        for step in range(200):
            if step == 3:
                s2 = make_session()
                s2.submit(sorted(workloads[2],
                                 key=lambda c: (c.arrival, c.cid)))
                sessions.append(s2)
            if step == 5:
                sessions[0].submit(
                    sorted(burst, key=lambda c: (c.arrival, c.cid)))
            advance_all(sessions, 0.9)
            _harvest(results, sessions)
            if s1_drained_at is None and not sessions[1].num_live:
                s1_drained_at = step
            if not any(s.num_live for s in sessions):
                assert s1_drained_at < step, \
                    "script expects session 1 to finish early"
                return results
        raise RuntimeError("script failed to drain")

    pool = SessionPool(PARAMS, num_ports=PORTS, max_sessions=4)

    def pool_advance(sessions, dt):
        pool.advance(dt)  # ONE dispatch chain for every row

    pooled = script(pool.session, pool_advance)
    assert pool._C_cap >= 26                     # the burst doubled it

    def standalone_advance(sessions, dt):
        for s in sessions:
            s.advance(dt)

    solo = script(
        lambda: SaathSession(PARAMS, num_ports=PORTS, backend="jax"),
        standalone_advance)
    assert pooled == solo


def test_pool_admission_cap_and_row_recycling():
    pool = SessionPool(PARAMS, num_ports=PORTS, max_sessions=2)
    a, b = pool.session(), pool.session()
    assert pool.num_sessions == 2
    with pytest.raises(RuntimeError, match="full"):
        pool.session()
    a.submit(_coflows(3, 2))
    pool.advance(0.5)
    pool.release(a)                  # frees row 0 (drops a's coflows)
    with pytest.raises(RuntimeError, match="closed"):
        a.advance(0.1)
    c = pool.session()               # recycled row
    assert c._row == 0 and pool.num_sessions == 2
    c.submit(_coflows(4, 2))
    done = []
    for _ in range(100):
        pool.advance(1.0)
        done += c.poll()
        if not c.num_live:
            break
    assert len(done) == 2 and all(np.isfinite(d.cct) for d in done)
    assert b.num_live == 0           # b never submitted; clock moved
    assert b.now > 0


def test_pool_idle_sessions_do_not_block_the_fleet():
    pool = SessionPool(PARAMS, num_ports=PORTS, max_sessions=3)
    idle = pool.session()
    busy = pool.session()
    busy.submit(_coflows(7, 3))
    done = []
    for _ in range(100):
        pool.advance(1.0)
        done += busy.poll()
        if not busy.num_live:
            break
    assert len(done) == 3
    assert idle.num_live == 0 and idle.now == busy.now


def test_single_session_advance_noops_other_rows():
    """`advance` on ONE pooled view moves only its row; the others'
    coordinators stay frozen at their own horizons."""
    pool = SessionPool(PARAMS, num_ports=PORTS, max_sessions=2)
    a, b = pool.session(), pool.session()
    a.submit(_coflows(9, 3))
    b.submit(_coflows(10, 3))
    a.advance(200.0)
    assert a.now == 200.0 and b.now == 0.0
    done_a = a.poll()
    assert len(done_a) == 3          # a drained alone
    assert not b.poll()              # b never ticked
    b.advance(200.0)
    assert len(b.poll()) == 3


def test_pool_device_resident_clean_rows_never_reupload():
    """The ISSUE-5 tentpole contract: after the first (full) upload,
    advances over clean rows move ZERO slab bytes host->device; only
    rows whose membership/state changed are scattered, and host
    mirrors materialize lazily (on poll), not per advance."""
    pool = SessionPool(PARAMS, num_ports=PORTS, max_sessions=3)
    a, b = pool.session(), pool.session()
    # big flows so nothing completes during the probe advances
    a.submit([Coflow(0, 0.0, [Flow(0, 0, 1, 500.0)])])
    b.submit([Coflow(0, 0.0, [Flow(0, 2, 3, 500.0)])])
    pool.advance(1.0)                     # first _ensure: ONE full upload
    io = pool.io
    assert io["full_uploads"] == 1
    base_rows, base_bytes = io["row_uploads"], io["upload_bytes"]
    downloads = io["row_downloads"]
    # guard + counters together make "zero clean-row uploads"
    # structural: an UNACCOUNTED h2d upload raises inside the guard,
    # an accounted one moves the io counters asserted unchanged below
    with assert_no_transfers():
        for _ in range(5):
            pool.advance(1.0)             # clean rows: nothing uploads
    assert io["full_uploads"] == 1
    assert io["row_uploads"] == base_rows
    assert io["upload_bytes"] == base_bytes
    assert io["row_downloads"] == downloads   # nobody looked: no gathers
    a.submit([Coflow(1, a.now, [Flow(1, 1, 2, 500.0)])])  # dirty ONE row
    pool.advance(1.0)
    assert io["full_uploads"] == 1            # still no full mirror
    assert io["row_uploads"] == base_rows + 1  # just a's row scattered
    # nothing completed: polling gathers NOTHING (the completions-only
    # fast path), while a snapshot forces the lazy row materialization
    downloads = io["row_downloads"]
    assert a.poll() == [] and b.poll() == []
    assert io["row_downloads"] == downloads
    assert a.snapshot()[0]["sent"] > 0
    assert io["row_downloads"] > downloads    # ...via row gathers
    tb, st = pool.host_view()                 # the lazy debug view
    assert isinstance(tb.size, np.ndarray)
    assert int(np.asarray(st.tick).max()) > 0


def test_pool_epoch_rebase_is_per_row():
    """Regression (ISSUE 5): the f32 epoch re-base is strictly PER ROW.
    One row ages past REBASE_TICKS and re-bases on its next re-pack
    while its neighbor stays young at epoch 0 — both rows must keep
    full δ resolution (a slab-global re-base would drag the young
    row's times negative and fork its trajectory)."""
    from repro.api.pool import REBASE_TICKS

    t_off = 2.0 * REBASE_TICKS * PARAMS.delta   # 2^21 ticks ~ 21000s
    rng = np.random.default_rng(17)

    def workload(base):
        # binary-exact relative arrivals/sizes (0.25-grained): any
        # mismatch is a lost-resolution f32 slab artifact
        cfs, fid = [], 0
        for c in range(5):
            w = int(rng.integers(1, 4))
            flows = [Flow(fid + i, int(rng.integers(0, PORTS)),
                          int(rng.integers(0, PORTS)),
                          float(rng.integers(4, 60) * 0.25))
                     for i in range(w)]
            fid += w
            cfs.append(Coflow(c, base + 0.25 * int(rng.integers(0, 8)),
                              flows))
        return cfs

    state = rng.bit_generator.state
    base_cfs = workload(0.0)
    rng.bit_generator.state = state              # identical draws
    late_cfs = workload(t_off)

    ref = SaathSession(PARAMS, num_ports=PORTS, backend="jax")
    ref.submit(base_cfs)
    want = {d.handle: (d.cct, tuple(d.fct))
            for d in ref.drain(step=5.0, max_seconds=500.0)}

    pool = SessionPool(PARAMS, num_ports=PORTS, max_sessions=2)
    old, young = pool.session(), pool.session()
    old.advance(t_off)                  # only old's row ages
    old.submit(late_cfs)
    young.submit(base_cfs)              # young stays on the t=0 grid
    got_old, got_young = {}, {}
    for _ in range(200):
        pool.advance(5.0)
        got_old.update({d.handle: (d.cct, tuple(np.asarray(d.fct)
                                                - t_off))
                        for d in old.poll()})
        got_young.update({d.handle: (d.cct, tuple(d.fct))
                          for d in young.poll()})
        if not (old.num_live or young.num_live):
            break
    assert not (old.num_live or young.num_live)
    assert old._epoch >= REBASE_TICKS, "the old row never re-based"
    assert young._epoch == 0, "re-basing leaked onto the young row"
    assert got_old == want, "old row lost δ resolution"
    assert got_young == want, "young row's grid was perturbed"


def test_pool_heterogeneous_params_bitwise_vs_standalone():
    """Three tenants under THREE different SchedulerParams (pool
    default, huge start_threshold, 2x δ) on one slab: every tenant's
    completions are bitwise those of a standalone session running its
    own params — heterogeneity changes the stacked parameter rows,
    never the arithmetic."""
    slow = dataclasses.replace(PARAMS, start_threshold=1e9)
    coarse = dataclasses.replace(PARAMS, delta=2e-2)
    trio = [PARAMS, slow, coarse]
    workloads = [_coflows(30 + i, 4) for i in range(3)]

    def drive(sessions, advance_all):
        results = [dict(), dict(), dict()]
        for s, w in zip(sessions, workloads):
            s.submit(sorted(w, key=lambda c: (c.arrival, c.cid)))
        for _ in range(200):
            advance_all(sessions, 0.9)
            _harvest(results, sessions)
            if not any(s.num_live for s in sessions):
                return results
        raise RuntimeError("failed to drain")

    pool = SessionPool(PARAMS, num_ports=PORTS, max_sessions=3)
    pooled_sessions = [pool.session(params=p) for p in trio]
    pooled = drive(pooled_sessions, lambda s, dt: pool.advance(dt))

    solo_sessions = [SaathSession(p, num_ports=PORTS, backend="jax")
                     for p in trio]

    def seq_advance(sessions, dt):
        for s in sessions:
            s.advance(dt)

    solo = drive(solo_sessions, seq_advance)
    assert pooled == solo
    # and the slow tenant really ran its own thresholds: its queue
    # never left 0 (nothing reaches 1e9 bytes)
    assert all(v["queue"] <= 0 for v in
               pooled_sessions[1].snapshot().values())


def test_pool_async_ctl_download_charged_once_at_sync_point():
    """ISSUE 8 satellite: under async dispatch a chain of K advances
    enqueues K dispatches but moves ZERO control bytes — the deferred
    (tick, finished) download is charged exactly once, at `_sync_ctl`
    time (the first poll), not per dispatch."""
    pool = SessionPool(PARAMS, num_ports=PORTS, max_sessions=2)
    assert pool._async                      # async is the default
    a = pool.session()
    # one huge flow: nothing completes, so poll gathers no rows and the
    # only download in play is the ctl mirror itself
    a.submit([Coflow(0, 0.0, [Flow(0, 0, 1, 500.0)])])
    pool.advance(0.5)                       # first upload + parked ctl
    base_ctl = pool.io["ctl_bytes"]
    base_disp = pool.io["dispatches"]
    for _ in range(5):
        pool.advance(0.5)                   # chain: re-park, no sync
    assert pool._ctl is not None
    assert pool.io["dispatches"] == base_disp + 5
    assert pool.io["ctl_bytes"] == base_ctl, \
        "async dispatch paid a ctl download at dispatch time"
    expect = pool._ticks.nbytes + pool._fin.nbytes
    assert a.poll() == []                   # the sync point
    assert pool._ctl is None                # handle consumed
    assert pool.io["ctl_bytes"] == base_ctl + expect, \
        "one chain of K advances must cost exactly ONE ctl download"
    assert a.poll() == []                   # no parked ctl: no charge
    assert pool.io["ctl_bytes"] == base_ctl + expect


# ---- the serving front door (launch.serve.CoflowServer) ----------------


def test_coflow_server_admission_results_and_eviction():
    from repro.launch.serve import AdmissionError, CoflowServer

    srv = CoflowServer(PARAMS, num_ports=PORTS, max_tenants=2)
    srv.register("alice")
    srv.register("bob")
    with pytest.raises(ValueError, match="already registered"):
        srv.register("alice")
    with pytest.raises(AdmissionError, match="admission cap"):
        srv.register("carol")
    assert srv.rejected == 1
    with pytest.raises(KeyError, match="unknown tenant"):
        srv.submit("carol", _coflows(1, 1))

    srv.submit("alice", _coflows(20, 3))
    srv.submit("bob", _coflows(21, 2))
    for _ in range(100):
        srv.advance(1.0)
        if not (srv.num_live("alice") or srv.num_live("bob")):
            break
    res = srv.result("alice")                # normalized per-tenant
    assert int(res.num_coflows[0]) == 3
    assert len(srv.poll("alice")) == 3       # result() is a pure
    assert srv.poll("alice") == []           # accessor; poll is once-each
    assert np.isfinite(res.avg_cct[0]) and np.isfinite(res.makespan[0])
    idle = srv.result("bob")
    assert int(idle.num_coflows[0]) == 2

    srv.evict("alice")
    srv.register("carol")                    # the freed row
    assert sorted(srv.tenants) == ["bob", "carol"]
    assert np.isnan(srv.result("carol").avg_cct[0])   # nothing yet
