"""Sharded mega-pool (ISSUE 6): the row axis partitioned across
devices via the pmap dispatch path.

The load-bearing property is BITWISE parity: an N-shard pool produces
bit-identical per-session CCTs/FCTs to the 1-shard (single-device)
pool, async and blocking dispatch alike — pmap runs the exact
single-slab program per device (no GSPMD partitioner, no collectives),
so sharding is purely a placement decision. CPU runners get the
devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the CI sharded step / `make pool-sharded`); sharded cases skip when
the devices aren't there, the async-vs-blocking case runs everywhere.
"""
import numpy as np
import pytest

import jax

from repro.api import SessionPool
from repro.core.coflow import Coflow, Flow
from repro.core.params import SchedulerParams

PORTS = 6
PARAMS = SchedulerParams(port_bw=1.0, delta=1e-2, start_threshold=4.0,
                         growth=4.0, num_queues=5)

needs_devices = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >=4 devices (set XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


def _coflows(seed: int, n: int, spread: float = 2.0):
    rng = np.random.default_rng(seed)
    cfs, fid = [], 0
    for c in range(n):
        w = int(rng.integers(1, 5))
        flows = [Flow(fid + i, int(rng.integers(0, PORTS)),
                      int(rng.integers(0, PORTS)),
                      float(rng.uniform(1.0, 15.0))) for i in range(w)]
        fid += w
        cfs.append(Coflow(c, float(rng.uniform(0.0, spread)), flows))
    return sorted(cfs, key=lambda c: (c.arrival, c.cid))


def _run_fleet(shards: int, *, async_dispatch: bool = True, B: int = 8,
               steps: int = 40, dt: float = 0.9, late_join: bool = True):
    """An adversarial fleet script: B sessions with different
    workloads, one admitted mid-run onto a recycled row, one released
    early; returns per-session completion records (handle, cct, fcts)
    in a canonical layout for bitwise comparison."""
    pool = SessionPool(PARAMS, num_ports=PORTS, max_sessions=B,
                       shards=shards, async_dispatch=async_dispatch)
    sessions = [pool.session() for _ in range(B)]
    for i, s in enumerate(sessions):
        s.submit(_coflows(100 + i, 3 + i % 3))
    results = {i: [] for i in range(B + 1)}
    extra = None
    for step in range(steps):
        pool.advance(dt)
        if step == 5 and late_join:
            sessions[1].close()           # frees a row mid-run...
            extra = pool.session()        # ...recycled by a late joiner
            extra.submit(_coflows(999, 2, spread=0.5))
        for s, d in pool.poll():
            key = B if s is extra else sessions.index(s)
            results[key].append((d.handle, d.cct, tuple(d.fct)))
    for s in sessions:
        if s._pool is not None:
            s.close()
    if extra is not None:
        extra.close()
    return results


@needs_devices
@pytest.mark.parametrize("shards", [2, 4])
def test_sharded_pool_bitwise_equals_single_device(shards):
    ref = _run_fleet(1)
    got = _run_fleet(shards)
    assert got == ref, (
        f"{shards}-shard pool diverged from the single-device pool")


@needs_devices
def test_sharded_blocking_path_bitwise_too():
    """The MAX_REL_TICKS split loop (blocking path) through the pmap
    dispatch is the same arithmetic as the async fast path."""
    assert _run_fleet(4, async_dispatch=False) == \
        _run_fleet(1, async_dispatch=False)


def test_async_dispatch_bitwise_equals_blocking():
    """Async double-buffering is pure pipelining: deferring the ctl
    download can never change a row's arithmetic (runs on any device
    count)."""
    assert _run_fleet(1, async_dispatch=True) == \
        _run_fleet(1, async_dispatch=False)


def test_async_dispatch_defers_ctl_downloads():
    """A burst of K advances costs K dispatches but ONE deferred ctl
    download at the next sync point."""
    pool = SessionPool(PARAMS, num_ports=PORTS, max_sessions=2,
                       async_dispatch=True)
    s = pool.session()
    s.submit(_coflows(7, 3))
    pool.advance(0.2)                      # first dispatch + ensure
    pool.poll()                            # sync: a clean baseline
    d0 = pool.io["dispatches"]
    c0 = pool.io["ctl_bytes"]
    for _ in range(5):
        pool.advance(0.05)                 # chained: no ctl download
    assert pool.io["dispatches"] == d0 + 5
    assert pool.io["ctl_bytes"] == c0
    pool.poll()                            # ONE download for the burst
    burst = pool.io["ctl_bytes"] - c0
    assert burst > 0
    pool.advance(0.05)
    pool.poll()
    single = pool.io["ctl_bytes"] - c0 - burst
    assert burst == single, "K chained advances must cost ONE ctl read"


def test_shard_validation():
    with pytest.raises(ValueError, match="multiple of shards"):
        SessionPool(PARAMS, num_ports=PORTS, max_sessions=6, shards=4)
    if jax.device_count() < 64:
        with pytest.raises(ValueError, match="devices"):
            SessionPool(PARAMS, num_ports=PORTS, max_sessions=64,
                        shards=64)


def test_pinned_features_join_never_recompiles():
    """The pinned-features serving contract, enforced at the XLA cache:
    once the fleet executables are warm, admitting a NEW tenant — even
    one with heterogeneous SchedulerParams — must be pure data movement
    (a row scatter + the warm dispatch), zero fresh compiles. Params
    live in the stacked EngineParams rows, so per-tenant values change
    operands, never the traced program."""
    from repro.analysis.sanitize import assert_no_recompiles

    pool = SessionPool(PARAMS, num_ports=PORTS, max_sessions=4,
                       features=(True, True, False))
    a = pool.session()
    a.submit(_coflows(11, 3))
    pool.advance(0.5)                      # compile the fleet programs
    b = pool.session()                     # warm the JOIN path too:
    b.submit(_coflows(12, 2))              # k=1 scatter + ep restack
    pool.advance(0.5)
    pool.poll()                            # ...and the gather/sync path
    hetero = SchedulerParams(port_bw=1.0, delta=2e-2,
                             start_threshold=8.0, growth=4.0,
                             num_queues=5)
    with assert_no_recompiles():
        c = pool.session(params=hetero)
        c.submit(_coflows(13, 2, spread=0.5))
        pool.advance(0.5)
    pool.poll()                            # gather idx shape varies —
    pool.advance(5.0)                      # correctness stays outside
    assert {s for s, _ in pool.poll()} <= {a, b, c}


def test_pinned_features_reject_out_of_superset_tenant():
    """Pinned features freeze the compiled structure: a tenant whose
    mechanisms need a feature outside the pinned set is refused at
    admission (instead of silently recompiling the fleet)."""
    pool = SessionPool(PARAMS, num_ports=PORTS, max_sessions=2,
                       features=(True, True, False))
    s = pool.session()                     # defaults fit the pinned set
    s.submit(_coflows(3, 2))
    pool.advance(0.5)
    with pytest.raises(ValueError, match="pinned"):
        pool.session(mechanisms={"lcof": False})  # needs ablations
    # the refusal didn't leak a row
    assert pool.num_sessions == 1
    pool.advance(2.0)
