"""Integration: train loop, checkpoint/restart determinism, elasticity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, read_metadata, restore, save
from repro.launch.train import train


@pytest.mark.slow
def test_loss_decreases(tmp_path):
    out = train("starcoder2-3b", steps=30, smoke=True, batch=4, seq=64,
                ckpt_dir=None, log_every=1000, coflow_plan=False)
    losses = out["losses"]
    assert len(losses) == 30
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, losses


@pytest.mark.slow
def test_checkpoint_restart_bitwise(tmp_path):
    """Crash-and-resume reproduces the uninterrupted run exactly: run to
    20 with periodic checkpoints, 'lose' everything after step 12 (the
    crash), resume, and compare the replayed losses (stateless data
    pipeline + saved train state)."""
    import shutil

    d = str(tmp_path / "ckpt")
    full = train("starcoder2-3b", steps=20, smoke=True, batch=4, seq=64,
                 ckpt_dir=d, ckpt_every=6, log_every=1000,
                 coflow_plan=False)
    assert latest_step(d) == 18
    shutil.rmtree(f"{d}/step_{18:08d}")  # the crash
    assert latest_step(d) == 12
    resumed = train("starcoder2-3b", steps=20, smoke=True, batch=4,
                    seq=64, ckpt_dir=d, ckpt_every=6, log_every=1000,
                    coflow_plan=False)
    assert resumed["final_step"] == 20
    # losses after resume equal the uninterrupted run's tail
    np.testing.assert_allclose(resumed["losses"], full["losses"][12:],
                               rtol=1e-6)


def test_checkpoint_atomic_and_metadata(tmp_path):
    tree = {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.zeros(3)}
    p = save(str(tmp_path), 7, tree, metadata={"arch": "x"})
    assert os.path.isdir(p)
    meta = read_metadata(str(tmp_path), 7)
    assert meta["step"] == 7 and meta["metadata"]["arch"] == "x"
    back = restore(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))


def test_elastic_reshard_roundtrip(tmp_path):
    """A checkpoint written replicated restores under a (1,1) mesh with
    explicit specs — the elastic-rescale path at CPU scale."""
    from jax.sharding import PartitionSpec as P

    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    save(str(tmp_path), 1, tree)
    if hasattr(jax.sharding, "AxisType"):  # jax >= 0.6 explicit-axes API
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    else:  # jax 0.4.x: meshes are implicitly Auto on every axis
        mesh = jax.make_mesh((1, 1), ("data", "model"))
    back = restore(str(tmp_path), 1, tree, mesh=mesh,
                   specs={"w": P("data", "model")})
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))
    assert back["w"].sharding.spec == P("data", "model")


def test_straggler_watchdog():
    from repro.launch.train import StragglerWatchdog

    dog = StragglerWatchdog(factor=3.0)
    for i in range(20):
        dog.observe(i, 0.1)
    assert not dog.events
    assert dog.observe(20, 1.0)   # 10x median -> flagged
    assert dog.events[0]["step"] == 20
