"""The coherence checker itself: per-rule fixtures (positive and
negative), waiver semantics, the clean-source gate, the committed
golden manifest, drift detection, and the seeded-mutation harness."""
import io
import json
import textwrap

import repro.analysis.coherence as coh

POOL_PASS = "class SessionPool:\n    pass\n"
SESSION_PASS = "class SaathSession:\n    pass\n"
SERVE_PASS = "class CoflowServer:\n    pass\n"


def findings_of(pool="", session="", serve=""):
    sources = {
        "api/pool.py": textwrap.dedent(pool) or POOL_PASS,
        "api/session.py": textwrap.dedent(session) or SESSION_PASS,
        "launch/serve.py": textwrap.dedent(serve) or SERVE_PASS,
    }
    return coh.check_protocol(sources)


def rules_of(**kw):
    return {f.rule for f in findings_of(**kw)}


# ---- coh-dirty-on-write --------------------------------------------------

def test_membership_write_without_dirty_flag():
    assert coh.R_DIRTY in rules_of(session="""
        class SaathSession:
            def submit(self, cf):
                self._live[cf.handle] = cf
                return cf.handle
    """)


def test_membership_write_with_dirty_flag_is_clean():
    assert coh.R_DIRTY not in rules_of(session="""
        class SaathSession:
            def submit(self, cf):
                self._live[cf.handle] = cf
                self._tb_dirty = True
                return cf.handle
    """)


def test_dirty_flag_must_hold_on_all_paths():
    # flag set on only one branch: the other exit leaks a silent
    # membership change
    assert coh.R_DIRTY in rules_of(session="""
        class SaathSession:
            def submit(self, cf, fast):
                self._live[cf.handle] = cf
                if fast:
                    self._tb_dirty = True
                return cf.handle
    """)


def test_entry_write_requires_state_dirty():
    src = """
        class SaathSession:
            def complete(self, e, now):
                e.finished = True
                e.cct = now{flag}
    """
    assert coh.R_DIRTY in rules_of(session=src.format(flag=""))
    assert coh.R_DIRTY not in rules_of(session=src.format(
        flag="\n                self._state_dirty = True"))


def test_legal_sync_writers_are_exempt():
    # _sync_row copies FROM the authoritative device row; dirtying
    # would be wrong, and the checker knows it
    assert coh.R_DIRTY not in rules_of(pool="""
        class SessionPool:
            def _sync_row(self, e, host):
                e.finished = host.finished
    """)


# ---- coh-sync-before-mirror ----------------------------------------------

def test_mirror_read_without_sync():
    assert coh.R_SYNC in rules_of(pool="""
        class SessionPool:
            def _sync_ctl(self):
                self._ctl = None

            def peek(self):
                return self._ticks
    """)


def test_mirror_read_after_sync_is_clean():
    assert coh.R_SYNC not in rules_of(pool="""
        class SessionPool:
            def _sync_ctl(self):
                self._ctl = None

            def peek(self):
                self._sync_ctl()
                return self._ticks
    """)


def test_sync_requirement_propagates_through_helpers():
    # the unsynced access is in a private helper; the finding lands on
    # the public entry that reaches it
    fs = findings_of(pool="""
        class SessionPool:
            def _sync_ctl(self):
                self._ctl = None

            def _probe(self):
                return self._fin.any()

            def peek(self):
                return self._probe()
    """)
    hits = [f for f in fs if f.rule == coh.R_SYNC]
    assert hits and "SessionPool.peek" in hits[0].msg
    assert "_probe" in hits[0].msg


def test_sync_via_providing_callee_is_clean():
    # a callee that syncs on every exit dominates the later access
    assert coh.R_SYNC not in rules_of(pool="""
        class SessionPool:
            def _sync_ctl(self):
                self._ctl = None

            def _refresh(self):
                self._sync_ctl()
                return True

            def peek(self):
                self._refresh()
                return self._ticks
    """)


def test_rearming_the_ctl_revokes_the_sync_fact():
    # sync, then an async dispatch parks a NEW ctl: the mirror is
    # stale again and the read must be flagged
    assert coh.R_SYNC in rules_of(pool="""
        class SessionPool:
            def _sync_ctl(self):
                self._ctl = None

            def peek(self, work):
                self._sync_ctl()
                self._ctl = work
                return self._ticks
    """)


# ---- coh-stale-folded-cache ----------------------------------------------

def test_slab_rewrite_without_cache_invalidation():
    assert coh.R_CACHE in rules_of(pool="""
        class SessionPool:
            def _rebuild(self, tb):
                self._tb = tb
    """)


def test_slab_rewrite_with_cache_invalidation_is_clean():
    assert coh.R_CACHE not in rules_of(pool="""
        class SessionPool:
            def _rebuild(self, tb):
                self._tb = tb
                self._tb_disp = None
    """)


def test_setting_slab_to_none_is_an_invalidation_not_a_rewrite():
    assert coh.R_CACHE not in rules_of(pool="""
        class SessionPool:
            def drop(self):
                self._ep_stack = None
    """)


# ---- coh-ctl-consume-once ------------------------------------------------

def test_only_the_blessed_pair_may_touch_the_handle():
    assert coh.R_HANDLE in rules_of(pool="""
        class SessionPool:
            def steal(self):
                return self._ctl
    """)


def test_consumer_must_reset_the_handle():
    assert coh.R_HANDLE in rules_of(pool="""
        class SessionPool:
            def _sync_ctl(self):
                tick, fin = self._ctl
                return tick, fin
    """)
    assert coh.R_HANDLE not in rules_of(pool="""
        class SessionPool:
            def _sync_ctl(self):
                tick, fin = self._ctl
                self._ctl = None
                return tick, fin
    """)


# ---- coh-unaccounted-transfer --------------------------------------------

def test_public_transfer_outside_accounted_frame():
    assert coh.R_IO in rules_of(pool="""
        import numpy as np

        class SessionPool:
            def host_view(self):
                return np.asarray(self._state)
    """)


def test_accounted_frame_is_clean():
    assert coh.R_IO not in rules_of(pool="""
        import numpy as np

        class SessionPool:
            @_io_accounted
            def host_view(self):
                return np.asarray(self._state)
    """)


def test_transfer_reached_through_helper_is_flagged():
    fs = findings_of(pool="""
        class SessionPool:
            def _pull(self, rows):
                return self._je.gather_rows(self._tb, rows)

            def poll(self):
                return self._pull([0])
    """)
    hits = [f for f in fs if f.rule == coh.R_IO]
    assert hits and "gather_rows" in hits[0].msg


# ---- coh-fresh-index -----------------------------------------------------

def test_new_done_without_fresh_update():
    assert coh.R_FRESH in rules_of(pool="""
        class SessionPool:
            def mark(self, s):
                s._new_done = True
    """)


def test_new_done_with_fresh_update_is_clean():
    assert coh.R_FRESH not in rules_of(pool="""
        class SessionPool:
            def mark(self, s):
                s._new_done = True
                self._fresh.add(s)
    """)


# ---- coh-harvest-before-read ---------------------------------------------

def test_pending_read_without_harvest():
    assert coh.R_HARVEST in rules_of(serve="""
        class CoflowServer:
            def poll(self, tenant):
                return self._pending[tenant]
    """)


def test_pending_read_after_harvest_is_clean():
    assert coh.R_HARVEST not in rules_of(serve="""
        class CoflowServer:
            def poll(self, tenant):
                self._harvest(tenant)
                return self._pending[tenant]
    """)


def test_pending_write_needs_no_harvest():
    assert coh.R_HARVEST not in rules_of(serve="""
        class CoflowServer:
            def register(self, tenant):
                self._pending[tenant] = []
    """)


# ---- waivers -------------------------------------------------------------

def test_waiver_silences_and_its_removal_reinstates(monkeypatch):
    # CoflowServer.stats reads _pending without a harvest by design;
    # dropping the waiver must resurface the finding on the real tree
    assert coh.check_protocol() == []
    monkeypatch.delitem(coh.WAIVERS,
                        ("CoflowServer.stats", coh.R_HARVEST))
    fs = coh.check_protocol()
    assert [f for f in fs if f.rule == coh.R_HARVEST
            and "CoflowServer.stats" in f.msg]


# ---- the real tree: clean gate + committed manifest ----------------------

def test_repo_serving_plane_is_coherence_clean():
    fs = coh.check_protocol()
    assert not fs, "\n".join(str(f) for f in fs)


def test_committed_manifest_matches_extraction():
    path = coh.default_manifest_path()
    assert path.exists(), (
        f"no {path} -- run `make coherence-update` and commit it")
    problems = coh.check_manifest(json.loads(path.read_text()))
    assert not problems, "\n".join(problems)


def test_manifest_covers_the_async_protocol_core():
    manifest = json.loads(coh.default_manifest_path().read_text())
    m = manifest["methods"]
    sync = m["SessionPool._sync_ctl"]
    assert sync["provides_sync"] and sync["accounted"]
    assert "_ctl" in sync["invalidates"]
    disp = m["SessionPool._dispatch_async"]
    assert "_ctl" in disp["writes"] and not disp["provides_sync"]


# ---- drift detection -----------------------------------------------------

POOL_V1 = """
    class SessionPool:
        def _sync_ctl(self):
            self._ctl = None

        def peek(self):
            self._sync_ctl()
            return self._ticks
"""

POOL_V2 = """
    class SessionPool:
        def _sync_ctl(self):
            self._ctl = None

        def peek(self):
            self._sync_ctl()
            self._fin = None
            return self._ticks

        def extra(self):
            return 1
"""


def _sources(pool_src):
    return {"api/pool.py": textwrap.dedent(pool_src),
            "api/session.py": SESSION_PASS,
            "launch/serve.py": SERVE_PASS}


def test_drift_is_reported_as_a_structured_diff():
    manifest = coh.build_manifest(_sources(POOL_V1))
    problems = coh.check_manifest(manifest, _sources(POOL_V2))
    text = "\n".join(problems)
    assert "SessionPool.extra: new method" in text
    assert "SessionPool.peek: effect drift" in text
    assert "+ invalidate: _fin" in text
    # and the same manifest against the same sources is quiet
    assert coh.check_manifest(manifest, _sources(POOL_V1)) == []


def test_removed_method_is_reported():
    manifest = coh.build_manifest(_sources(POOL_V2))
    problems = coh.check_manifest(manifest, _sources(POOL_V1))
    assert any("SessionPool.extra" in p and "no longer" in p
               for p in problems)


# ---- the seeded-mutation harness -----------------------------------------

def test_selftest_catches_all_seeded_coherence_bugs():
    out = io.StringIO()
    rc = coh.run_selftest(out=out)
    assert rc == 0, out.getvalue()
    n = len(coh.SEEDED_MUTATIONS)
    assert n >= 6
    assert f"{n}/{n} seeded coherence bugs caught" in out.getvalue()


# ---- CLI -----------------------------------------------------------------

def test_cli_update_then_gate_roundtrip(tmp_path, capsys):
    path = tmp_path / "coherence_manifest.json"
    assert coh.main(["--manifest", str(path)]) == 1      # no manifest
    assert "coherence-update" in capsys.readouterr().err
    assert coh.main(["--update", "--manifest", str(path)]) == 0
    assert coh.main(["--manifest", str(path)]) == 0
    capsys.readouterr()
    # poison one pinned method: the gate must fail with the hint
    manifest = json.loads(path.read_text())
    manifest["methods"]["SessionPool._sync_ctl"]["reads"] = []
    path.write_text(json.dumps(manifest))
    assert coh.main(["--manifest", str(path)]) == 1
    captured = capsys.readouterr()
    assert "effect drift" in captured.out
    assert "--update" in captured.err
