"""Per-architecture smoke tests (reduced same-family configs, CPU).

For each assigned arch: one forward pass, one real train step (loss
decreases-ish / finite), and prefill->decode agreement with the
teacher-forced forward. The FULL configs are exercised only by the
dry-run (launch/dryrun.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import lm
from repro.models.common import Parallelism
from repro.optim import make_optimizer

PAR = Parallelism(None)
RNG = np.random.default_rng(7)

# big configs dominate the suite's wall clock (~30s each for a smoke
# train step); tier-1 keeps one fast arch per family, the heavy ones
# run with `-m slow` (see pytest.ini)
HEAVY = {"jamba-v0.1-52b", "deepseek-v2-236b", "chameleon-34b",
         "qwen3-moe-235b-a22b", "seamless-m4t-medium",
         "deepseek-coder-33b"}
ARCHS = [pytest.param(a, marks=pytest.mark.slow) if a in HEAVY else a
         for a in sorted(ARCH_IDS)]


def _batch(cfg, B=2, S=32, with_labels=False):
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, S + 1)),
                       jnp.int32)
    out = {"tokens": toks[:, :S]}
    if with_labels:
        out["labels"] = toks[:, 1:S + 1]
    if cfg.enc_dec:
        out["src_embeds"] = jnp.asarray(
            RNG.normal(size=(B, 16, cfg.d_model)), jnp.float32)
    return out, toks


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    params, axes, meta = lm.init_model(cfg, jax.random.key(0))
    batch, _ = _batch(cfg)
    logits = lm.forward_train(cfg, params, meta, batch, PAR)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_runs(arch):
    from repro.launch.steps import make_train_step

    cfg = get_smoke_config(arch)
    params, axes, meta = lm.init_model(cfg, jax.random.key(0))
    opt = make_optimizer(cfg, total_steps=100)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, meta, PAR, opt))
    batch, _ = _batch(cfg, with_labels=True)
    p2, o2, m = step_fn(params, opt_state, jnp.int32(0), batch)
    assert bool(jnp.isfinite(m["loss"]))
    assert float(m["loss"]) > 0
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, p2)
    assert max(jax.tree.leaves(d)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32",
                              moe_capacity_factor=64.0)
    params, axes, meta = lm.init_model(cfg, jax.random.key(1))
    B, S = 2, 16
    batch, toks = _batch(cfg, B, S)
    full_batch = {"tokens": toks[:, :S + 1]}
    if cfg.enc_dec:
        full_batch["src_embeds"] = batch["src_embeds"]
    full = lm.forward_train(cfg, params, meta, full_batch, PAR)
    cache = lm.init_cache(cfg, meta, B, S + 4, PAR,
                          src_len=16 if cfg.enc_dec else 0)
    lg_pre, cache = lm.forward_prefill(cfg, params, meta, batch, cache, PAR)
    np.testing.assert_allclose(np.asarray(lg_pre[:, 0]),
                               np.asarray(full[:, S - 1]), atol=2e-3)
    lg_dec, _ = lm.forward_decode(cfg, params, meta, toks[:, S:S + 1],
                                  cache, jnp.int32(S), PAR)
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]),
                               np.asarray(full[:, S]), atol=2e-3)


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_full_config_exact(arch):
    """The full (dry-run) config matches the assignment numbers."""
    cfg = get_config(arch)
    expected = {
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 0, 151936),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (got, expected)
    # MoE structure
    if arch == "qwen3-moe-235b-a22b":
        assert (cfg.num_experts, cfg.num_experts_per_tok) == (128, 8)
    if arch == "deepseek-v2-236b":
        assert (cfg.num_experts, cfg.num_experts_per_tok,
                cfg.num_shared_experts, cfg.kv_lora_rank) == (160, 6, 2, 512)
    if arch == "jamba-v0.1-52b":
        assert (cfg.num_experts, cfg.num_experts_per_tok,
                cfg.attn_period) == (16, 2, 8)
    if arch == "mamba2-1.3b":
        assert cfg.ssm_state == 128 and cfg.subquadratic


def test_param_counts_plausible():
    """Analytic parameter counts are in the advertised ballpark."""
    expect = {"starcoder2-3b": (2.5e9, 4e9),
              "gemma-7b": (7.5e9, 9.5e9),
              "deepseek-coder-33b": (3.0e10, 3.6e10),
              "deepseek-7b": (6.0e9, 7.5e9),
              "qwen3-moe-235b-a22b": (2.2e11, 2.5e11),
              "deepseek-v2-236b": (2.1e11, 2.5e11),
              "chameleon-34b": (3.1e10, 3.7e10),
              "mamba2-1.3b": (1.1e9, 1.6e9),
              "jamba-v0.1-52b": (4.6e10, 5.6e10),
              "seamless-m4t-medium": (0.8e9, 1.6e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    a = cfg.active_param_count()
    assert 1.5e10 <= a <= 3e10, a  # "A22B"
