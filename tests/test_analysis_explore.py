"""The interleaving explorer: schedule generator determinism and
validity, divergence reporting, and a small in-process race check of
the async pool against the blocking oracle."""
import io

import repro.analysis.explore as ex


def test_schedules_are_deterministic_and_valid():
    a = ex.make_schedule(7, 40)
    b = ex.make_schedule(7, 40)
    assert a == b
    assert a != ex.make_schedule(8, 40)
    # replay the roster bookkeeping: admission cap respected, every
    # targeted sid live at its op
    live = set()
    for op in a:
        kind = op[0]
        if kind == "admit":
            live.add(op[1])
            assert len(live) <= ex.MAX_SESSIONS
        elif kind == "release":
            live.remove(op[1])
        elif kind in ("submit", "advance_one", "poll_one",
                      "snapshot"):
            assert op[1] in live
        else:
            assert kind in ("advance", "poll")
    assert any(op[0] == "submit" for op in a)
    assert any(op[0] == "advance" for op in a)


def test_first_divergence():
    assert ex.first_divergence([(1,), (2,)], [(1,), (2,)]) is None
    assert ex.first_divergence([(1,), (2,)], [(1,), (3,)]) == \
        (1, (2,), (3,))
    assert ex.first_divergence([(1,)], [(1,), (2,)]) == \
        (1, "<end>", (2,))


def test_norm_is_exact_and_nan_safe():
    import numpy as np
    assert ex._norm(np.float32(1.5)) == 1.5
    assert ex._norm(float("nan")) == "nan"
    assert ex._norm({"b": [1, 2], "a": np.arange(2)}) == \
        (("a", (0, 1)), ("b", (1, 2)))


def test_async_pool_matches_blocking_oracle_in_process():
    """The race check proper (1-shard CI variant): one fuzzed
    schedule, async double-buffered dispatch vs the blocking oracle,
    every observation bitwise-equal."""
    out = io.StringIO()
    rc = ex.explore(schedules=1, n_ops=16, seed=3, out=out)
    assert rc == 0, out.getvalue()
    assert "no divergences" in out.getvalue()


def test_explorer_reports_a_divergence(monkeypatch):
    """Force the candidate run to observe something the oracle did
    not: the explorer must exit nonzero and name the observation."""
    real = ex.run_schedule
    calls = {"n": 0}

    def crooked(ops, **kw):
        obs = real(ops, **kw)
        calls["n"] += 1
        if calls["n"] > 1:              # leave the oracle run alone
            obs[-1] = ("final", "corrupted")
        return obs

    monkeypatch.setattr(ex, "run_schedule", crooked)
    out = io.StringIO()
    rc = ex.explore(schedules=1, n_ops=12, seed=0, out=out)
    assert rc == 1
    assert "RACE" in out.getvalue()
    assert "corrupted" in out.getvalue()
