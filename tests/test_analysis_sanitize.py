"""Runtime sanitizers: jit-cache-miss counting and transfer guarding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sanitize import (RecompileError, accounted_transfer,
                                     assert_no_recompiles,
                                     assert_no_transfers)


def test_assert_no_recompiles_flags_fresh_compile():
    @jax.jit
    def f(x):
        return x * 2.0 + 1.0

    x = jnp.ones((3,), jnp.float32)
    with pytest.raises(RecompileError, match="compiled inside"):
        with assert_no_recompiles():
            f(x).block_until_ready()


def test_assert_no_recompiles_passes_on_cache_hits():
    @jax.jit
    def g(x):
        return x - 3.0

    x = jnp.ones((4,), jnp.float32)
    g(x).block_until_ready()                 # warm
    with assert_no_recompiles():
        g(x).block_until_ready()             # cache hit: clean
    # a NEW input shape is a cache miss again
    y = jnp.ones((5,), jnp.float32)
    with pytest.raises(RecompileError):
        with assert_no_recompiles():
            g(y).block_until_ready()


def test_assert_no_recompiles_allow_budget_and_scope_listing():
    @jax.jit
    def h(x):
        return x + 7.0

    x = jnp.ones((6,), jnp.float32)
    with assert_no_recompiles(allow=1) as scope:
        h(x).block_until_ready()
    assert scope.compiles, "the scope should record what was built"


def test_assert_no_transfers_blocks_unaccounted_uploads():
    x = np.ones((4,), np.float32)
    with pytest.raises(Exception, match="[Tt]ransfer"):
        with assert_no_transfers():
            jax.device_put(x)


def test_accounted_transfer_carves_out_sanctioned_uploads():
    x = np.ones((4,), np.float32)
    with assert_no_transfers():
        with accounted_transfer():
            y = jax.device_put(x)
    np.testing.assert_array_equal(np.asarray(y), x)
