"""End-to-end behaviour of the paper's system: trace -> schedulers ->
claims, and the framework bridge on top of the same coordinator."""
import numpy as np

from repro.api import Scenario, run
from repro.core.params import SchedulerParams
from repro.fabric.metrics import percentile_speedup
from repro.traces import tiny_trace


def _run(trace, policy, params):
    return run(Scenario(policy=policy, engine="numpy", trace=trace,
                        params=params))


def test_end_to_end_saath_beats_aalo_tail():
    tr = tiny_trace(60, 24, seed=5)
    p = SchedulerParams()
    aalo = _run(tr, "aalo", p)
    saath = _run(tr, "saath", p)
    assert np.isfinite(saath.row_cct()).all()
    assert np.isfinite(aalo.row_cct()).all()
    s = percentile_speedup(aalo.row_cct(), saath.row_cct())
    # the paper's effect is in the tail; median should not regress much
    assert s["p90"] > 1.0, s
    assert s["p50"] > 0.8, s


def test_online_saath_tracks_offline_varys():
    tr = tiny_trace(60, 24, seed=6)
    p = SchedulerParams()
    varys = _run(tr, "varys-sebf", p)   # clairvoyant
    saath = _run(tr, "saath", p)        # online
    a = float(varys.avg_cct[0])
    b = float(saath.avg_cct[0])
    assert b <= 2.0 * a, (a, b)  # online within 2x of clairvoyant avg


def test_all_policies_agree_on_total_work():
    """Every scheduler moves exactly the trace's bytes (no lost or
    duplicated traffic) regardless of policy."""
    tr = tiny_trace(30, 12, seed=7)
    total = sum(f.size for c in tr.coflows for f in c.flows)
    for pol in ("saath", "saath-jax", "aalo", "uc-tcp", "varys-sebf"):
        res = _run(tr, pol, SchedulerParams())
        assert abs(float(res.sent.sum()) - total) < 1e-6 * total
