"""End-to-end behaviour of the paper's system: trace -> schedulers ->
claims, and the framework bridge on top of the same coordinator."""
import numpy as np

from repro.core.params import SchedulerParams
from repro.fabric.engine import simulate
from repro.fabric.metrics import percentile_speedup
from repro.traces import tiny_trace


def test_end_to_end_saath_beats_aalo_tail():
    tr = tiny_trace(60, 24, seed=5)
    p = SchedulerParams()
    aalo = simulate(tr, "aalo", p)
    saath = simulate(tr, "saath", p)
    assert saath.table.finished.all() and aalo.table.finished.all()
    s = percentile_speedup(aalo.table.cct, saath.table.cct)
    # the paper's effect is in the tail; median should not regress much
    assert s["p90"] > 1.0, s
    assert s["p50"] > 0.8, s


def test_online_saath_tracks_offline_varys():
    tr = tiny_trace(60, 24, seed=6)
    p = SchedulerParams()
    varys = simulate(tr, "varys-sebf", p)   # clairvoyant
    saath = simulate(tr, "saath", p)        # online
    a = float(np.nanmean(varys.table.cct))
    b = float(np.nanmean(saath.table.cct))
    assert b <= 2.0 * a, (a, b)  # online within 2x of clairvoyant avg


def test_all_policies_agree_on_total_work():
    """Every scheduler moves exactly the trace's bytes (no lost or
    duplicated traffic) regardless of policy."""
    tr = tiny_trace(30, 12, seed=7)
    total = sum(f.size for c in tr.coflows for f in c.flows)
    for pol in ("saath", "saath-jax", "aalo", "uc-tcp", "varys-sebf"):
        res = simulate(tr, pol, SchedulerParams())
        assert abs(float(res.table.sent.sum()) - total) < 1e-6 * total
