"""Non-clairvoyant Saath (ISSUE 10): pilot-flow size learning.

* `core.sampling`: pilot layout (first K flows of a coflow in slab
  order) and the `SizeEstimator` update rule — mean finished-pilot
  size, falling back to bytes-sent-so-far before the first pilot
  completes, converging to the exact coflow size as pilots finish.
* clairvoyant=True must be semantics-FREE: the default engine call is
  byte-identical to the pre-PR program (the sampling machinery is an
  empty pytree subtree — the dispatch audit pins the jaxprs), and a
  mixed sweep that compiles sampling IN must leave its clairvoyant
  rows bitwise unchanged (the traced switch only masks).
* learned mode agrees across planes (numpy reference vs XLA engine)
  and actually changes the schedule versus known sizes.
* serving plane: a learned-mode tenant joining a pinned sampling pool
  never recompiles; a pool pinned WITHOUT sampling refuses one.

Plus the ISSUE-10 bugfix-sweep regressions (metrics empty-mask /
all-NaN summaries, synth 1KB-floor byte conservation).
"""
import dataclasses
import types
import warnings

import numpy as np
import pytest

from repro.core.coflow import Coflow, Flow, Trace
from repro.core.params import SchedulerParams
from repro.core.policies import make_policy
from repro.core.sampling import SizeEstimator, pilot_count, pilot_mask
from repro.fabric import jax_engine
from repro.fabric.engine import Simulator
from repro.fabric.metrics import RunSummary, percentile_speedup
from repro.fabric.state import FlowTable
from repro.traces.synth import fb_like_trace, tiny_trace

PORTS = 12
# toy-scale params for the hand-built shuffles below (unit sizes);
# tiny_trace emits FB-scale byte counts, so the engine/pool tests run
# under the DEFAULT params (Gbps ports) with the §4.3 re-queue on
FULL = SchedulerParams(port_bw=1.0, delta=1e-2, start_threshold=4.0,
                       growth=4.0, num_queues=5, dynamics_requeue=True)
DYN = SchedulerParams(dynamics_requeue=True)


def _shuffle(widths, sizes=None, seed=0):
    """One coflow per width, all flows port-disjoint per coflow."""
    rng = np.random.default_rng(seed)
    coflows, fid = [], 0
    for c, w in enumerate(widths):
        per = np.full(w, 6.0) if sizes is None else np.asarray(sizes[c])
        flows = [Flow(fid + i, int(rng.integers(0, PORTS)),
                      int(rng.integers(0, PORTS)), float(per[i]))
                 for i in range(w)]
        fid += w
        coflows.append(Coflow(c, 0.4 * c, flows))
    return Trace(num_ports=PORTS, coflows=coflows)


# ---- pilot layout + estimator ----------------------------------------


def test_pilot_count_and_mask_layout():
    w = np.array([1, 4, 10, 40])
    k = pilot_count(w, 0.1)
    # K = min(width, max(1, ceil(frac * width))): every coflow pilots
    # at least one flow, never more than it has
    assert k.tolist() == [1, 1, 1, 4]
    assert pilot_count(w, 0.5).tolist() == [1, 2, 5, 20]
    cid = np.array([0, 0, 0, 1, 1, 1, 1])
    lo = np.array([0, 3])
    m = pilot_mask(cid, lo, np.array([3, 4]), 0.5)
    # pilots are the FIRST K flows of each coflow in layout order
    assert m.tolist() == [True, True, False, True, True, False, False]


def test_estimator_converges_as_pilots_finish():
    p = dataclasses.replace(FULL, clairvoyant=False, pilot_frac=0.5)
    tr = _shuffle([6])                      # 6 equal flows of 6.0
    table = FlowTable.from_trace(tr, p.port_bw)
    est = SizeEstimator(p)
    pm = est.pilot_mask(table)
    assert pm.sum() == 3                    # ceil(0.5 * 6)

    # before the first pilot completes: fall back to bytes sent so far
    table.sent[:] = 1.5
    ef, et, learned = est.estimates(table)
    assert not learned[0]
    assert et[0] == pytest.approx(9.0)      # 6 x 1.5 bytes in flight
    assert ef[0] == pytest.approx(1.5)      # max flow bytes sent

    # pilots finish one by one: the estimate is exact (equal flows)
    for npilots in (1, 2, 3):
        table.done[:] = False
        table.done[:npilots] = True
        table.sent[:npilots] = 6.0
        ef, et, learned = est.estimates(table)
        assert learned[0]
        assert ef[0] == pytest.approx(6.0)
        assert et[0] == pytest.approx(36.0)  # the exact coflow total


def test_estimator_unequal_pilots_use_the_mean():
    p = dataclasses.replace(FULL, clairvoyant=False, pilot_frac=0.5)
    tr = _shuffle([4], sizes=[[2.0, 10.0, 7.0, 5.0]])
    table = FlowTable.from_trace(tr, p.port_bw)
    table.done[:2] = True
    table.sent[:2] = [2.0, 10.0]
    ef, et, learned = SizeEstimator(p).estimates(table)
    assert learned[0]
    assert ef[0] == pytest.approx(6.0)      # mean(2, 10)
    assert et[0] == pytest.approx(24.0)     # f_hat * width


# ---- clairvoyant=True is the pre-PR engine ---------------------------


def test_clairvoyant_explicit_bitwise_equals_default():
    traces = [tiny_trace(8, PORTS, seed=s, load=1.2) for s in (0, 1)]
    base = jax_engine.simulate_batch(traces, DYN)
    expl = jax_engine.simulate_batch(traces, DYN, clairvoyant=True)
    np.testing.assert_array_equal(np.asarray(base.cct),
                                  np.asarray(expl.cct))
    np.testing.assert_array_equal(np.asarray(base.fct),
                                  np.asarray(expl.fct))


def test_mixed_sweep_keeps_clairvoyant_rows_bitwise():
    """Compiling the sampling machinery IN (a learned row in the
    sweep) must not perturb a clairvoyant row by a single bit: the
    traced switch only masks the estimator's queue choice."""
    tr = tiny_trace(10, PORTS, seed=3, load=1.2)
    solo = jax_engine.simulate_batch([tr], DYN)
    learned = dataclasses.replace(DYN, clairvoyant=False)
    sweep = jax_engine.simulate_sweep(tr, [DYN, learned])
    np.testing.assert_array_equal(np.asarray(sweep.cct[0]),
                                  np.asarray(solo.cct[0]))
    # ...and the learned row is a genuinely different schedule
    a = np.asarray(sweep.cct[1])
    assert not np.array_equal(a, np.asarray(solo.cct[0]))
    assert np.isfinite(a).any()


def test_numpy_clairvoyant_skips_the_estimator():
    pol = make_policy("saath", FULL)
    assert pol.estimator is None            # estimator never allocated
    learned = make_policy(
        "saath", dataclasses.replace(FULL, clairvoyant=False))
    assert learned.estimator is not None


# ---- learned-mode cross-plane agreement ------------------------------


def test_learned_mode_matches_numpy_reference():
    p = dataclasses.replace(DYN, clairvoyant=False)
    traces = [tiny_trace(10, PORTS, seed=s, load=1.2) for s in (5, 6)]
    res = jax_engine.simulate_batch(traces, p)
    for b, tr in enumerate(traces):
        table = FlowTable.from_trace(tr, p.port_bw)
        Simulator(p).run(table, make_policy("saath", p))
        got = res.cct[b, :len(tr.coflows)]
        assert res.finished[b].all()
        np.testing.assert_allclose(got, table.cct, rtol=1e-2,
                                   atol=2 * p.delta)


def test_learned_mode_changes_the_schedule():
    tr = tiny_trace(12, PORTS, seed=7, load=2.0)
    known = jax_engine.simulate_batch([tr], DYN)
    p = dataclasses.replace(DYN, clairvoyant=False)
    learned = jax_engine.simulate_batch([tr], p)
    assert not np.array_equal(np.asarray(known.cct),
                              np.asarray(learned.cct))


# ---- serving plane ---------------------------------------------------


def test_pool_learned_tenant_join_never_recompiles():
    """A pool pinned with sampling compiled in admits a learned-mode
    tenant mid-flight as pure data movement: the pilot leaf and the
    traced clairvoyant parameter row are already part of the warm
    executables."""
    from repro.analysis.sanitize import assert_no_recompiles
    from repro.api.pool import SessionPool

    pool = SessionPool(DYN, num_ports=PORTS, max_sessions=4,
                       min_flow_capacity=256,
                       features=(True, True, False, False, True))
    a = pool.session()
    a.submit(tiny_trace(4, PORTS, seed=3, load=1.5).coflows)
    pool.advance(0.5)                      # compile the fleet programs
    b = pool.session()                     # warm the join path too
    b.submit(tiny_trace(4, PORTS, seed=4, load=1.5).coflows)
    pool.advance(0.5)
    pool.poll()
    with assert_no_recompiles():
        c = pool.session(mechanisms={"clairvoyant": False})
        c.submit(tiny_trace(4, PORTS, seed=5, load=1.5).coflows)
        pool.advance(0.5)
    pool.poll()                            # gather idx shape varies —
    pool.advance(60.0)                     # correctness stays outside
    assert {s for s, _ in pool.poll()} <= {a, b, c}


def test_pool_without_sampling_pin_rejects_learned_tenant():
    from repro.api.pool import SessionPool

    pool = SessionPool(DYN, num_ports=PORTS, max_sessions=2,
                       features=(True, True, False, False))
    pool.session()                         # clairvoyant default is fine
    with pytest.raises(ValueError, match="with_sampling"):
        pool.session(mechanisms={"clairvoyant": False})


def test_session_learned_mode_cross_backend():
    p = dataclasses.replace(DYN, clairvoyant=False)
    from repro.api.session import SaathSession

    ccts = {}
    for backend in ("jax", "numpy"):
        s = SaathSession(p, num_ports=PORTS, backend=backend)
        s.submit(tiny_trace(8, PORTS, seed=9, load=1.5).coflows)
        done = {}
        for _ in range(4000):
            s.advance(0.05)
            for d in s.poll():
                done[d.handle] = d.cct
            if len(done) == 8:
                break
        assert len(done) == 8
        ccts[backend] = np.array([done[h] for h in sorted(done)])
    np.testing.assert_allclose(ccts["jax"], ccts["numpy"], rtol=1e-2)


# ---- ISSUE-10 bugfix sweep regressions -------------------------------


def test_percentile_speedup_empty_ok_mask():
    # pre-PR: IndexError on np.percentile of an empty speedup vector
    nan = np.full(4, np.nan)
    out = percentile_speedup(nan, nan)
    assert out["n"] == 0
    for k in ("p10", "p50", "p90", "mean", "overall"):
        assert np.isnan(out[k])
    out = percentile_speedup(np.array([]), np.array([]))
    assert out["n"] == 0 and np.isnan(out["p50"])


def test_run_summary_all_nan_cct_is_silent():
    # pre-PR: "Mean of empty slice" RuntimeWarning from np.nanmean
    res = types.SimpleNamespace(
        table=types.SimpleNamespace(cct=np.full(3, np.nan)),
        makespan=0.0, steps=0, sched_seconds=0.0)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        s = RunSummary.from_result("saath", res)
    assert np.isnan(s.avg_cct) and np.isnan(s.p50_cct) \
        and np.isnan(s.p90_cct)


def test_fb_like_floor_conserves_coflow_totals():
    """The 1KB per-flow floor must renormalize INSIDE the drawn coflow
    total, not inflate it (pre-PR: `np.maximum(per, 1024)` after
    normalization added bytes on every skewed wide coflow). The drawn
    totals are reconstructible because they come off the RNG stream
    before any per-coflow draws."""
    seed, n = 11, 60
    MB = 1024.0 * 1024.0
    rng = np.random.default_rng(seed)
    rng.uniform(size=n)                     # kind draws
    want = np.clip(np.exp(rng.normal(np.log(30 * MB), 2.3, n)),
                   64 * 1024, 4e12)
    tr = fb_like_trace(n, 40, seed=seed, frac_equal_of_multi=0.0)
    for c in tr.coflows:
        got = sum(f.size for f in c.flows)
        assert got == pytest.approx(want[c.cid], rel=1e-9), \
            f"coflow {c.cid} ({len(c.flows)} flows) inflated its total"


def test_floor_helper_edge_cases():
    from repro.traces.synth import _FLOW_FLOOR, _floor_preserving_total

    # heavy skew: floored flows pinned, remainder renormalized
    per = np.array([1e8, 10.0, 20.0, 5e7])
    out = _floor_preserving_total(per.copy(), per.sum())
    assert out.sum() == pytest.approx(per.sum())
    assert (out >= _FLOW_FLOOR - 1e-9).all()
    # infeasible floor (total < w * 1KB): equal split, still conserved
    out = _floor_preserving_total(np.array([900.0, 100.0]), 1000.0)
    np.testing.assert_allclose(out, [500.0, 500.0])
    # deterministic: same input, same output
    a = _floor_preserving_total(per.copy(), per.sum())
    b = _floor_preserving_total(per.copy(), per.sum())
    np.testing.assert_array_equal(a, b)
