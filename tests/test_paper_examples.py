"""The paper's worked examples as executable tests.

Fig. 17 (Appendix A): SJF is suboptimal — LCoF beats it 8.33 vs 9.33.
Fig. 8: LCoF's own limitation — 2.83 vs optimal 2.66.
Fig. 4: work conservation recovers the ports all-or-none leaves idle.
Fig. 5: per-flow thresholds transition a partially-served coflow faster.
"""
import numpy as np

from repro.core.coflow import Coflow, Flow, Trace
from repro.api import Scenario, run
from repro.core.params import SchedulerParams


def simulate(trace, policy, params, policy_kwargs=None):
    """Worked examples go through the one front door (the old
    fabric.engine.simulate shim is gone)."""
    return run(Scenario(policy=policy, engine="numpy", trace=trace,
                        params=params, policy_kwargs=policy_kwargs))

# 1 byte/s ports; sizes in bytes = durations in seconds.
PARAMS = SchedulerParams(port_bw=1.0, delta=1e-3,
                         start_threshold=1e18,  # keep everything in Q0
                         dynamics_requeue=False)

A, B, X, Y = 0, 1, 2, 3


def fig17_trace():
    """C1: A->X size 5 (k=2). C2: A->Y size 6 (k=1). C3: B->X size 7 (k=1).
    All arrive at t=0."""
    return Trace(num_ports=4, coflows=[
        Coflow(0, 0.0, [Flow(0, A, X, 5.0)]),
        Coflow(1, 0.0, [Flow(1, A, Y, 6.0)]),
        Coflow(2, 0.0, [Flow(2, B, X, 7.0)]),
    ])


def test_fig17_sjf_suboptimal():
    # SCF (= SJF on total bytes): C1 first -> CCTs 5, 11, 12 (avg 9.33)
    res = simulate(fig17_trace(), "scf", PARAMS)
    np.testing.assert_allclose(sorted(res.row_cct()), [5, 11, 12], atol=0.05)
    # Saath/LCoF: C2, C3 first (k=1), C1 waits for both ports -> 6, 7, 12
    res = simulate(fig17_trace(), "saath", PARAMS)
    np.testing.assert_allclose(sorted(res.row_cct()), [6, 7, 12], atol=0.05)
    assert np.nanmean(res.row_cct()) < 8.34  # 8.33 vs SJF's 9.33


def test_fig17_aalo_matches_sjf_order():
    # Aalo: all in Q0, FIFO by arrival (C1 first by id) -> 5, 11, 12
    res = simulate(fig17_trace(), "aalo", PARAMS)
    np.testing.assert_allclose(sorted(res.row_cct()), [5, 11, 12], atol=0.05)


def fig8_trace():
    """LCoF limitation: C1 (two flows of 1.0 across A,B; k=2) vs two
    longer single-flow coflows (2.5 each; k=1)."""
    return Trace(num_ports=4, coflows=[
        Coflow(0, 0.0, [Flow(0, A, X, 1.0), Flow(1, B, Y, 1.0)]),
        Coflow(1, 0.0, [Flow(2, A, X, 2.5)]),
        Coflow(2, 0.0, [Flow(3, B, Y, 2.5)]),
    ])


def test_fig8_lcof_limitation():
    # LCoF schedules the two low-contention 2.5s coflows first: 2.5,2.5,3.5
    res = simulate(fig8_trace(), "saath", PARAMS)
    np.testing.assert_allclose(sorted(res.row_cct()), [2.5, 2.5, 3.5],
                               atol=0.05)
    # total-bytes SCF picks C1 (total 2.0) first: 1, 3.5, 3.5 (the optimum)
    res = simulate(fig8_trace(), "scf", PARAMS)
    np.testing.assert_allclose(sorted(res.row_cct()), [1.0, 3.5, 3.5],
                               atol=0.05)


def fig4_trace():
    """All-or-none can idle ports: C1 holds port A; C2 needs A and B; B
    would idle without work conservation."""
    return Trace(num_ports=4, coflows=[
        Coflow(0, 0.0, [Flow(0, A, X, 2.0)]),
        Coflow(1, 0.0, [Flow(1, A, Y, 2.0), Flow(2, B, Y, 2.0)]),
    ])


def test_fig4_work_conservation_helps():
    no_wc = simulate(fig4_trace(), "saath", PARAMS,
                     policy_kwargs={"work_conservation": False})
    wc = simulate(fig4_trace(), "saath", PARAMS)
    # Without WC, C2 waits for port A entirely: starts at 2, ends at 4.
    # (C2's two flows go to the same receiver Y, so they serialize on Y:
    #  2 + 2 = 4 either way; use distinct receivers to see the pure effect.)
    assert np.nanmean(wc.row_cct()) <= np.nanmean(no_wc.row_cct()) + 1e-6


def fig4b_trace():
    """Same as fig4 but C2's flows go to distinct receivers so WC can
    genuinely overlap the B->Z flow while A is held by C1."""
    Z = 3
    return Trace(num_ports=5, coflows=[
        Coflow(0, 0.0, [Flow(0, A, X, 2.0)]),
        Coflow(1, 0.0, [Flow(1, A, Y, 2.0), Flow(2, B, Z, 2.0)]),
    ])


def test_fig4b_work_conservation_strictly_better():
    no_wc = simulate(fig4b_trace(), "saath", PARAMS,
                     policy_kwargs={"work_conservation": False})
    wc = simulate(fig4b_trace(), "saath", PARAMS)
    # no WC: C2 fully blocked until t=2, CCT(C2)=4. With WC its B->Z flow
    # streams during [0,2): CCT(C2)=2+2=... the A->Y flow still waits, so
    # CCT(C2)=4 BUT the B flow finished at 2 — with per-flow progress the
    # remaining all-or-none admission at t=2 only needs A: CCT stays 4 for
    # A->Y; C2's CCT is driven by its last flow = 4 in both. The win shows
    # up in *other* coflows' slots; here assert WC never hurts and the B
    # port was actually used early.
    assert np.nanmean(wc.row_cct()) <= np.nanmean(no_wc.row_cct()) + 1e-6
    tb = wc.table(0)
    b_flow = 2
    assert tb.fct[b_flow] <= 2.1  # WC streamed it immediately


def test_fig1_out_of_sync_collapse():
    """Fig. 1/13 mechanism: under Saath, flows of an equal-length coflow
    finish (nearly) together; under Aalo they can drift far apart."""
    # Two 2-flow coflows sharing one port: Aalo serves C2's port-A flow
    # after C1 but its port-B flow immediately -> out of sync.
    tr = Trace(num_ports=6, coflows=[
        Coflow(0, 0.0, [Flow(0, A, X, 3.0)]),
        Coflow(1, 0.0, [Flow(1, A, Y, 3.0), Flow(2, B, 5, 3.0)]),
    ])
    aalo = simulate(tr, "aalo", PARAMS)
    saath = simulate(tr, "saath", PARAMS,
                     policy_kwargs={"work_conservation": False})
    t = aalo.table(0)
    drift_aalo = abs(t.fct[1] - t.fct[2])
    t = saath.table(0)
    drift_saath = abs(t.fct[1] - t.fct[2])
    assert drift_aalo > 2.5          # B flow done at 3, A flow at 6
    assert drift_saath < 0.1         # all-or-none keeps them in lockstep


def test_fig5_per_flow_threshold_transitions_faster():
    """Fig. 5: a 4-flow coflow with only 2 flows being served crosses the
    per-flow threshold (Q/N) ~2x sooner than the total-bytes threshold."""
    from repro.core import queues

    p = SchedulerParams(start_threshold=4.0, port_bw=1.0)
    width = np.array([4])
    # two of four flows served for t=1: total=2, max-flow=1
    assert queues.aalo_queue(np.array([2.0]), p)[0] == 0     # 2 < 4
    assert queues.saath_queue(np.array([1.0]), width, p)[0] == 1  # 1*4 >= 4
    # all four served for t=1: total=4 crosses too
    assert queues.aalo_queue(np.array([4.0]), p)[0] == 1


def starvation_trace():
    """C0 spans both port pairs, forever contended by streams of short
    single-flow coflows (C0 always has the higher contention)."""
    flows0 = [Flow(0, A, X, 4.0), Flow(1, B, Y, 4.0)]
    coflows = [Coflow(0, 0.0, flows0)]
    fid = 2
    t = 0.0
    for i in range(1, 40):
        t += 0.25
        coflows.append(Coflow(i, t, [Flow(fid, A, X, 0.5)]))
        fid += 1
        coflows.append(Coflow(100 + i, t, [Flow(fid, B, Y, 0.5)]))
        fid += 1
    return Trace(num_ports=4, coflows=coflows)


def test_starvation_deadline_forces_progress():
    """A high-contention coflow under adversarial arrivals is rescued by
    the FIFO-derived deadline (D5); with deadlines effectively disabled it
    waits for the whole short-coflow stream."""
    from repro.core.policies import make_policy
    from repro.fabric.engine import Simulator
    from repro.fabric.state import FlowTable

    ccts = {}
    for d in (2.0, 1e9):
        params = SchedulerParams(port_bw=1.0, delta=1e-3,
                                 start_threshold=1.0, growth=2.0,
                                 num_queues=6, deadline_factor=d,
                                 dynamics_requeue=False)
        table = FlowTable.from_trace(starvation_trace(), params.port_bw)
        pol = make_policy("saath", params)
        res = Simulator(params).run(table, pol)
        assert res.table.finished.all()
        ccts[d] = float(res.table.cct[0])
        if d == 2.0:
            assert pol.stats_deadline_hits > 0  # the guarantee actually fired
    assert ccts[2.0] <= ccts[1e9] + 1e-6
