"""Coflow bridge / wave planner / barrier-issue properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.runtime.buckets import bucketize
from repro.runtime.coflow_bridge import (CollectiveCoflow,
                                         grad_bucket_coflows, plan_waves)
from repro.runtime.overlap import scheduled_psum


def test_bucketize_order_and_coverage():
    tree = {f"l{i}": jnp.zeros((128, 128)) for i in range(6)}
    bks = bucketize(tree, bucket_bytes=3 * 128 * 128 * 4)
    idx = [i for b in bks for i in b.leaf_idx]
    assert sorted(idx) == list(range(6))        # every leaf exactly once
    assert idx == idx[::-1][::-1] and idx[0] == 5  # reverse-layer order
    assert all(b.bytes <= 3 * 128 * 128 * 4 for b in bks)


@given(st.lists(st.sampled_from(["ici:data", "ici:model", "dcn", "host"]),
                min_size=1, max_size=3, unique=True),
       st.integers(2, 10))
@settings(max_examples=25, deadline=None)
def test_plan_waves_properties(res, n):
    rng = np.random.default_rng(0)
    coflows = [CollectiveCoflow(f"c{i}", int(rng.integers(1 << 20, 1 << 28)),
                                tuple(rng.choice(res, rng.integers(
                                    1, len(res) + 1), replace=False)),
                                i)
               for i in range(n)]
    waves = plan_waves(coflows, num_chips=8)
    flat = [c for w in waves for c in w]
    assert sorted(flat) == sorted(c.name for c in coflows)  # all, once
    # within a wave, coflows share no resource (all-or-none feasibility)
    by_name = {c.name: c for c in coflows}
    for w in waves:
        used = []
        for nme in w:
            for r in by_name[nme].resources:
                assert r not in used, (w, r)
                used.append(r)


def test_grad_buckets_serialize_lcof_orders_tenants():
    bks = bucketize({f"l{i}": jnp.zeros((64, 64)) for i in range(4)},
                    bucket_bytes=64 * 64 * 4)
    cfs = grad_bucket_coflows(bks)
    cfs += [CollectiveCoflow("bg/dcn", 1 << 30, ("dcn",), 99)]
    waves = plan_waves(cfs, num_chips=4)
    # grad buckets all on ici:data -> exactly one per wave, arrival order
    grads = [n for w in waves for n in w if n.startswith("grad/")]
    assert grads == [f"grad/{i}" for i in range(len(bks))]
    per_wave = [sum(n.startswith("grad/") for n in w) for w in waves]
    assert max(per_wave) == 1
    # the DCN tenant rides wave 0 (disjoint resource)
    assert "bg/dcn" in waves[0]


def test_plan_waves_colliding_ranks_keep_all_collectives():
    """Regression: two tenants built with the same rank_offset used to
    collide in the rank->position maps and silently drop collectives
    from the wave plan. Ranks are now densely renumbered preserving
    (rank, submission) order, so every collective is planned once."""
    bks = bucketize({f"l{i}": jnp.zeros((64, 64)) for i in range(3)},
                    bucket_bytes=64 * 64 * 4)
    tenant_a = grad_bucket_coflows(bks, rank_offset=0)
    tenant_b = grad_bucket_coflows(bks, axes=("ici:model",), rank_offset=0)
    tenant_b = [dataclasses.replace(c, name=f"b/{c.name}")
                for c in tenant_b]
    cfs = tenant_a + tenant_b + [
        CollectiveCoflow("bg/dcn", 1 << 30, ("dcn",), 0)]  # third collision
    waves = plan_waves(cfs, num_chips=4)
    flat = [n for w in waves for n in w]
    assert sorted(flat) == sorted(c.name for c in cfs), flat
    assert len(flat) == len(cfs)  # nothing dropped, nothing duplicated
    # serialization per resource still holds despite the collisions
    grads_a = [n for w in waves for n in w
               if n.startswith("grad/")]
    assert grads_a == [f"grad/{i}" for i in range(len(bks))]


def test_scheduled_psum_preserves_values_and_orders():
    from jax.sharding import Mesh, PartitionSpec as P

    tree = {"a": jnp.arange(16.0).reshape(4, 4), "b": jnp.ones((8,))}
    bks = bucketize(tree, bucket_bytes=1 << 10)
    waves = [[f"grad/{b.bid}"] for b in bks]
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    flat, _ = jax.tree_util.tree_flatten(tree)

    def f(*g):
        return tuple(scheduled_psum(list(g), bks, waves, "data"))

    if hasattr(jax, "shard_map"):  # jax >= 0.5
        shard_map = jax.shard_map
    else:  # jax 0.4.x
        from jax.experimental.shard_map import shard_map
    fn = shard_map(f, mesh=mesh, in_specs=tuple(P() for _ in flat),
                   out_specs=tuple(P() for _ in flat))
    out = jax.jit(fn)(*flat)
    for a, b in zip(out, flat):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    # issue order is enforced by optimization barriers in the stablehlo
    txt = jax.jit(fn).lower(*flat).as_text()
    assert txt.count("optimization_barrier") >= len(waves) - 1


def test_hlo_analysis_counts_loops():
    """Trip-count multipliers: a scanned matmul counts L x flops."""
    from benchmarks.hlo_analysis import analyze

    L, n = 7, 64
    w = jnp.ones((L, n, n))

    def f(x):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    hlo = jax.jit(f).lower(jnp.ones((n, n))).compile().as_text()
    res = analyze(hlo, 1)
    want = L * 2 * n ** 3
    assert 0.9 * want <= res["flops"] <= 1.2 * want, (res["flops"], want)
