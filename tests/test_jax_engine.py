"""Equivalence + batching properties of the XLA fleet engine.

The batched `fabric.jax_engine` must reproduce the event-driven
`fabric.engine.Simulator`:

* exactly (1% tolerance, actual agreement ~1e-3 from f32) against the
  numpy `Saath` reference on the FULL configuration — per-flow work
  conservation AND the §4.3 dynamics re-queue on (DESIGN.md §2/§3);
* likewise on the ablated configurations (work conservation off);
* exactly against `Simulator` driving the SAME jitted coordinator one
  tick at a time (`saath-jax` policy), full config.

Plus: per-trace results are independent of batch packing, and
`simulate_sweep` equals per-setting runs.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.coflow import Coflow, Flow, Trace
from repro.core.params import SchedulerParams
from repro.core.policies import make_policy
from repro.fabric import jax_engine
from repro.fabric.engine import Simulator
from repro.fabric.state import FlowTable
from repro.traces.batch import pack

PORTS = 6
PARAMS = SchedulerParams(port_bw=1.0, delta=1e-2, start_threshold=4.0,
                         growth=4.0, num_queues=5, dynamics_requeue=False)


def _trace(kind: str, seed: int = 0, n: int = 6) -> Trace:
    """Synthetic equivalence families: uniform all-to-all shuffles,
    skewed-width mixes, staggered arrivals."""
    rng = np.random.default_rng(seed)
    coflows, fid = [], 0
    for c in range(n):
        if kind == "uniform":
            m = int(rng.integers(1, 3))
            r = int(rng.integers(1, 3))
            senders = rng.choice(PORTS, m, replace=False)
            receivers = rng.choice(PORTS, r, replace=False)
            size = float(rng.uniform(2.0, 20.0))
            flows = [Flow(fid + i, int(s), int(d), size)
                     for i, (s, d) in enumerate(
                         (s, d) for s in senders for d in receivers)]
            arrival = float(rng.uniform(0.0, 2.0))
        elif kind == "skewed":
            w = int(rng.integers(1, 6))
            flows = [Flow(fid + i, int(rng.integers(0, PORTS)),
                          int(rng.integers(0, PORTS)),
                          float(np.exp(rng.normal(1.5, 1.0))))
                     for i in range(w)]
            arrival = float(rng.uniform(0.0, 2.0))
        elif kind == "staggered":
            w = int(rng.integers(1, 4))
            flows = [Flow(fid + i, int(rng.integers(0, PORTS)),
                          int(rng.integers(0, PORTS)),
                          float(rng.uniform(1.0, 15.0)))
                     for i in range(w)]
            arrival = 3.0 * c  # strictly staggered, mostly disjoint
        else:  # pragma: no cover
            raise ValueError(kind)
        fid += len(flows)
        coflows.append(Coflow(c, arrival, flows))
    return Trace(num_ports=PORTS, coflows=coflows)


FAMILIES = ("uniform", "skewed", "staggered")


def _reference_cct(trace, policy_kwargs=None, params=PARAMS):
    table = FlowTable.from_trace(trace, params.port_bw)
    pol = make_policy("saath", params, **(policy_kwargs or {}))
    Simulator(params).run(table, pol)
    return table.cct


@pytest.mark.parametrize("kind", FAMILIES)
def test_engine_matches_numpy_reference_within_1pct(kind):
    """Batched engine vs Simulator + numpy Saath at the coordinator
    granularity: average AND per-coflow CCT within 1%."""
    traces = [_trace(kind, seed=s) for s in range(3)]
    res = jax_engine.simulate_batch(traces, PARAMS, work_conservation=False)
    for b, tr in enumerate(traces):
        want = _reference_cct(tr, {"work_conservation": False})
        got = res.cct[b, :len(tr.coflows)]
        assert res.finished[b].all()
        np.testing.assert_allclose(got, want, rtol=1e-2)
        assert abs(np.nanmean(got) / np.nanmean(want) - 1.0) < 1e-2


@pytest.mark.parametrize("kind", FAMILIES)
def test_engine_matches_tickwise_coordinator(kind):
    """Same jitted coordinator, batched scan vs one-tick-at-a-time
    through the event simulator (full config both sides)."""
    tr = _trace(kind, seed=11)
    full = SchedulerParams(port_bw=1.0, delta=1e-2, start_threshold=4.0,
                           growth=4.0, num_queues=5)
    table = FlowTable.from_trace(tr, full.port_bw)
    Simulator(full).run(table, make_policy("saath-jax", full))
    res = jax_engine.simulate_batch([tr], full)
    got = res.cct[0, :len(tr.coflows)]
    np.testing.assert_allclose(got, table.cct, rtol=1e-2)


@pytest.mark.parametrize("kind", FAMILIES)
def test_engine_full_saath_matches_reference_1pct(kind):
    """The acceptance gate: per-flow work conservation AND the §4.3
    dynamics re-queue ON — the batched engine matches the full numpy
    Saath reference within 1% per-coflow AND on average (the 2x
    granularity envelope this replaced is closed)."""
    full = SchedulerParams(port_bw=1.0, delta=1e-2, start_threshold=4.0,
                           growth=4.0, num_queues=5)
    traces = [_trace(kind, seed=s) for s in range(3)]
    res = jax_engine.simulate_batch(traces, full)
    for b, tr in enumerate(traces):
        want = _reference_cct(tr, params=full)
        got = res.cct[b, :len(tr.coflows)]
        assert res.finished[b].all()
        np.testing.assert_allclose(got, want, rtol=1e-2,
                                   atol=2 * full.delta)
        assert abs(np.nanmean(got) / np.nanmean(want) - 1.0) < 1e-2


@pytest.mark.parametrize("kw", [
    dict(lcof=False, per_flow_threshold=False),   # Fig. 10 "A/N"
    dict(lcof=False, per_flow_threshold=True),    # Fig. 10 "A/N+PF"
])
def test_engine_ablations_match_reference(kw):
    """The Fig. 10 ablation switches (Aalo total-bytes queues, FIFO
    within queue) replay through the traced tick exactly as the numpy
    policy ablations. Dynamics re-queue is pinned off here: its
    continuous remaining-length drift makes the trajectory sensitive to
    f32-vs-f64 event-grid straddles under the ablated orderings (the
    full-SAATH config is covered at 1% above)."""
    p = dataclasses.replace(PARAMS)  # PARAMS already pins dynamics off
    for kind in FAMILIES:
        tr = _trace(kind, seed=2)
        want = _reference_cct(tr, dict(kw), params=p)
        res = jax_engine.simulate_batch([tr], p, **kw)
        got = res.cct[0, :len(tr.coflows)]
        np.testing.assert_allclose(got, want, rtol=1e-2, atol=2 * p.delta)


def test_two_queue_config_matches_reference():
    """K=2 regression: thresholds[1] is +inf, so the last-queue span must
    come from the explicit growth factor (a +inf span would disable the
    D5 starvation deadlines only on the jax side)."""
    p2 = dataclasses.replace(PARAMS, num_queues=2)
    tr = _trace("skewed", seed=4)
    want = _reference_cct(tr, {"work_conservation": False}, params=p2)
    res = jax_engine.simulate_batch([tr], p2, work_conservation=False)
    # K=2 keeps most coflows in the deadline-driven last queue, so
    # expiry-tick reorderings shift CCTs by a few δ; 2% cleanly
    # separates that from the broken +inf-span behaviour (starvation)
    np.testing.assert_allclose(res.cct[0, :len(tr.coflows)], want,
                               rtol=2e-2)


def test_engine_moves_exactly_the_trace_bytes():
    tr = _trace("skewed", seed=3)
    res = jax_engine.simulate_batch([tr], PARAMS)
    tb = pack([tr], port_bw=PARAMS.port_bw)
    total = sum(f.size for c in tr.coflows for f in c.flows)
    got = float((res.sent[0] * tb.flow_valid[0]).sum())
    assert abs(got - total) < 1e-5 * total


def test_packing_independence_under_vmap():
    """A trace's results don't depend on what it is batched with or how
    much padding the batch forces."""
    small = _trace("uniform", seed=1, n=4)
    big = _trace("skewed", seed=2, n=14)   # forces more C/F padding
    alone = jax_engine.simulate_batch([small], PARAMS)
    packed = jax_engine.simulate_batch([big, small, small], PARAMS)
    C = len(small.coflows)
    np.testing.assert_allclose(packed.cct[1, :C], alone.cct[0, :C],
                               rtol=1e-6)
    np.testing.assert_allclose(packed.cct[2, :C], alone.cct[0, :C],
                               rtol=1e-6)


def test_sweep_matches_individual_runs():
    tr = _trace("uniform", seed=7)
    settings = [dataclasses.replace(PARAMS, start_threshold=s)
                for s in (2.0, 4.0, 16.0)]
    sw = jax_engine.simulate_sweep(tr, settings)
    C = len(tr.coflows)
    for i, p in enumerate(settings):
        solo = jax_engine.simulate_batch([tr], p)
        np.testing.assert_allclose(sw.cct[i, :C], solo.cct[0, :C],
                                   rtol=1e-5)


def test_result_table_replaces_run_to_table():
    """`run_to_table` is gone: the front door's `Result.table()` is the
    one way to materialize a filled FlowTable from the jax engine."""
    from repro.api import Scenario, run

    tr = _trace("staggered", seed=9)
    table = run(Scenario(policy="saath", engine="jax", trace=tr,
                         params=PARAMS)).table()
    assert table.finished.all() and table.done.all()
    assert np.isfinite(table.cct).all()
    np.testing.assert_allclose(table.sent, table.size, rtol=1e-5)
    assert not hasattr(jax_engine, "run_to_table")
