"""Test-suite wiring.

* Installs the vendored ``tests/_hypothesis_compat`` shim as
  ``hypothesis`` when the real package is missing, so the
  property-based modules collect and run everywhere (the CI image has
  hypothesis; the hermetic jax_pallas image does not).
"""
from __future__ import annotations

import importlib.util
import os
import sys


def _install_hypothesis_shim() -> None:
    try:
        import hypothesis  # noqa: F401  (real package wins when present)
        return
    except ImportError:
        pass
    path = os.path.join(os.path.dirname(__file__), "_hypothesis_compat.py")
    spec = importlib.util.spec_from_file_location("hypothesis", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies


_install_hypothesis_shim()
