"""Dispatch auditor: golden-manifest round-trip, drift detection, and
the hard gates — a callback or f64 site injected into the real
`session_advance` hot path must fail the audit."""
import json

import jax
import jax.numpy as jnp
import numpy as np

import repro.analysis.audit as au


# ---- toy entrypoints (cheap; exercise the manifest machinery) ------------

def _toy_entry():
    return jax.make_jaxpr(lambda x: x * 2.0 + 1.0)(
        np.ones((3,), np.float32))


def _toy_entry_drifted():
    return jax.make_jaxpr(lambda x: jnp.sin(x * 2.0 + 1.0))(
        np.ones((3,), np.float32))


def _toy_entry_reshaped():
    return jax.make_jaxpr(lambda x: x * 2.0 + 1.0)(
        np.ones((4,), np.float32))


def _toy_entry_callback():
    def f(x):
        jax.debug.callback(lambda *_: None, x)
        return x * 2.0
    return jax.make_jaxpr(f)(np.ones((3,), np.float32))


def test_manifest_round_trip_is_clean():
    reg = {"toy": _toy_entry}
    manifest = au.build_manifest(reg)
    assert manifest["jax_version"] == jax.__version__
    assert manifest["entrypoints"]["toy"]["callbacks"] == []
    assert manifest["entrypoints"]["toy"]["f64_sites"] == []
    assert au.check_manifest(manifest, reg) == []


def test_primitive_drift_is_flagged_under_same_jax_version():
    manifest = au.build_manifest({"toy": _toy_entry})
    problems = au.check_manifest(manifest, {"toy": _toy_entry_drifted})
    assert any("primitive-count drift" in p and "sin" in p
               for p in problems), problems


def test_primitive_drift_diff_is_grouped_by_direction():
    # toy -> drifted adds `sin`; drifted -> toy removes it. The diff
    # must say WHICH, not dump both manifests.
    manifest = au.build_manifest({"toy": _toy_entry})
    problems = au.check_manifest(manifest, {"toy": _toy_entry_drifted})
    drift = next(p for p in problems if "primitive-count drift" in p)
    assert "added:" in drift and "sin x1" in drift
    assert "removed:" not in drift
    back = au.check_manifest(au.build_manifest(
        {"toy": _toy_entry_drifted}), {"toy": _toy_entry})
    drift = next(p for p in back if "primitive-count drift" in p)
    assert "removed:" in drift and "sin" in drift


def test_aval_signature_drift_is_flagged():
    manifest = au.build_manifest({"toy": _toy_entry})
    problems = au.check_manifest(manifest, {"toy": _toy_entry_reshaped})
    assert any("input signature drift" in p for p in problems), problems


def test_aval_drift_diff_is_positional():
    manifest = au.build_manifest({"toy": _toy_entry})
    problems = au.check_manifest(manifest, {"toy": _toy_entry_reshaped})
    drift = next(p for p in problems if "input signature drift" in p)
    # only the drifted slot, by position, old -> new
    assert "[0]" in drift and "->" in drift
    assert "float32[3]" in drift and "float32[4]" in drift


def test_aval_diff_marks_arity_changes():
    assert au._aval_diff(["f32[3]"], ["f32[3]", "i32[]"]) == \
        ["  [1] <absent> -> i32[]"]
    assert au._aval_diff(["f32[3]", "i32[]"], ["f32[3]"]) == \
        ["  [1] i32[] -> <absent>"]


def test_gate_failure_prints_the_update_hint(tmp_path, monkeypatch,
                                             capsys):
    monkeypatch.setattr(au, "ENTRYPOINTS", {"toy": _toy_entry})
    path = tmp_path / "manifest.json"
    assert au.main(["--update", "--manifest", str(path)]) == 0
    monkeypatch.setattr(au, "ENTRYPOINTS", {"toy": _toy_entry_drifted})
    capsys.readouterr()
    assert au.main(["--manifest", str(path)]) == 1
    captured = capsys.readouterr()
    assert "audit-update" in captured.err        # the one-line hint
    assert "added:" in captured.out              # the structured diff


def test_missing_and_stale_entries_are_flagged():
    manifest = au.build_manifest({"toy": _toy_entry})
    problems = au.check_manifest(
        manifest, {"other": _toy_entry})
    assert any(p.startswith("other: not in the manifest")
               for p in problems), problems
    assert any("toy" in p and "no longer audited" in p
               for p in problems), problems


def test_update_refuses_to_bless_callbacks(tmp_path, monkeypatch):
    """`--update` must never launder a hard-invariant violation into
    the golden manifest."""
    monkeypatch.setattr(au, "ENTRYPOINTS",
                        {"toy": _toy_entry_callback})
    path = tmp_path / "manifest.json"
    assert au.main(["--update", "--manifest", str(path)]) == 1
    assert not path.exists()


def test_cli_round_trip_update_then_gate(tmp_path, monkeypatch):
    monkeypatch.setattr(au, "ENTRYPOINTS", {"toy": _toy_entry})
    path = tmp_path / "manifest.json"
    assert au.main(["--manifest", str(path)]) == 1   # no manifest yet
    assert au.main(["--update", "--manifest", str(path)]) == 0
    written = json.loads(path.read_text())
    assert "toy" in written["entrypoints"]
    assert au.main(["--manifest", str(path)]) == 0


# ---- the real hot path ---------------------------------------------------

def test_committed_manifest_matches_live_entrypoints():
    """The golden manifest in analysis/ must stay in sync with the real
    hot entrypoints — this is `make audit` run as a test."""
    path = au.default_manifest_path()
    assert path.exists(), (
        f"no committed manifest at {path}; run `make audit-update`")
    manifest = json.loads(path.read_text())
    problems = au.check_manifest(manifest)
    assert problems == [], "\n".join(problems)


def _session_advance_inputs():
    tb, _, ep_rows, state = au._canonical_slab()
    ne = np.full((au.B,), 4.0, np.float32)
    return state, tb, ep_rows, ne, np.int32(64)


def test_callback_injected_into_session_advance_fails_gate():
    """If a host callback sneaks into the session block (e.g. a debug
    print left in the while_loop body), the audit must fail."""
    from repro.fabric.jax_engine import _run_session_block

    def poisoned():
        def noisy(s, t, e, n, m):
            out = _run_session_block(s, t, e, n, m, kernel=None,
                                     features=au.FEATURES)
            jax.debug.callback(lambda *_: None,
                               jax.tree_util.tree_leaves(out)[0])
            return out
        return jax.make_jaxpr(noisy)(*_session_advance_inputs())

    manifest = json.loads(au.default_manifest_path().read_text())
    problems = au.check_manifest(
        manifest, {"session_advance": poisoned})
    assert any("session_advance" in p and "callback" in p
               for p in problems), problems


def test_f64_cast_injected_into_session_advance_fails_gate():
    """An f64 convert in the hot loop (dtype drift) must fail the
    audit.  Tracing runs under enable_x64 because with x64 disabled the
    cast is silently dropped from the jaxpr — the exact failure mode
    the gate exists to catch before it ships to an x64-enabled host."""
    from jax.experimental import enable_x64

    from repro.fabric.jax_engine import _run_session_block

    def poisoned():
        def drifted(s, t, e, n, m):
            out = _run_session_block(s, t, e, n, m, kernel=None,
                                     features=au.FEATURES)
            leaf = jax.tree_util.tree_leaves(out)[0]
            bad = jax.lax.convert_element_type(leaf, jnp.float64)
            return out, bad
        with enable_x64():
            return jax.make_jaxpr(drifted)(*_session_advance_inputs())

    manifest = json.loads(au.default_manifest_path().read_text())
    problems = au.check_manifest(
        manifest, {"session_advance": poisoned})
    assert any("session_advance" in p and "float64" in p
               for p in problems), problems
