"""The jitted coordinator agrees with the numpy Saath reference."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.params import SchedulerParams
from repro.core.policies import make_policy
from repro.fabric.engine import Simulator
from repro.fabric.state import FlowTable

from tests.test_properties import PARAMS, mid_state, traces


@given(traces())
@settings(max_examples=30, deadline=None)
def test_admission_matches_numpy(trace):
    """All-or-none admission rates: jitted tick == numpy Fig. 7 loop."""
    t = mid_state(trace)
    ref = make_policy("saath", PARAMS, work_conservation=False)
    ref.reset(t)
    want = ref.schedule(t, 1.0)

    jaxp = make_policy("saath-jax", PARAMS, work_conservation=False)
    jaxp.reset(t)
    got = jaxp.schedule(t, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)


@given(traces())
@settings(max_examples=15, deadline=None)
def test_full_sim_close_to_numpy(trace):
    """End-to-end the jitted coordinator completes every coflow; its
    coflow-granular work conservation may deviate from the per-flow
    reference (documented granularity difference) but stays within a 2x
    envelope on adversarial micro-traces."""
    ta = FlowTable.from_trace(trace, PARAMS.port_bw)
    ra = Simulator(PARAMS).run(ta, make_policy("saath", PARAMS))
    tb = FlowTable.from_trace(trace, PARAMS.port_bw)
    rb = Simulator(PARAMS).run(tb, make_policy("saath-jax", PARAMS))
    assert rb.table.finished.all()
    a = float(np.nanmean(ra.table.cct))
    b = float(np.nanmean(rb.table.cct))
    assert b <= 2.0 * a + 4 * PARAMS.delta


def test_jax_coordinator_states_roll_forward():
    """Deadlines and queues persist across ticks (stateless-restart also
    re-derivable, mirroring the paper's stateless coordinator)."""
    import jax.numpy as jnp

    from repro.core import jax_coordinator as jc

    cp = jc.CoordParams.from_params(SchedulerParams(port_bw=1.0))
    C, P = 8, 4
    state = jc.init_state(C)
    rng = np.random.default_rng(0)
    batch = jc.CoflowBatch(
        active=jnp.asarray(np.ones(C, bool)),
        arrival=jnp.arange(C, dtype=jnp.int32),
        m=jnp.zeros(C, jnp.float32),
        width=jnp.ones(C, jnp.int32),
        cnt_s=jnp.asarray((rng.uniform(size=(C, P)) < 0.4).astype(np.float32)),
        cnt_r=jnp.asarray((rng.uniform(size=(C, P)) < 0.4).astype(np.float32)),
        bw_s=jnp.ones(P, jnp.float32),
        bw_r=jnp.ones(P, jnp.float32),
    )
    s1, o1 = jc.schedule_tick(state, batch, jnp.float32(0.0), cp=cp)
    assert np.isfinite(np.asarray(s1.deadline)).all()
    s2, o2 = jc.schedule_tick(s1, batch, jnp.float32(0.5), cp=cp)
    # same fabric, same tick inputs -> stable admission (no churn)
    np.testing.assert_array_equal(np.asarray(o1["admitted"]),
                                  np.asarray(o2["admitted"]))
    # deadlines unchanged when queues did not change
    np.testing.assert_allclose(np.asarray(s1.deadline),
                               np.asarray(s2.deadline))
