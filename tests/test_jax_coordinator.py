"""The jitted coordinator agrees with the numpy Saath reference."""
import numpy as np
from hypothesis import given, settings

from repro.core.params import SchedulerParams
from repro.core.policies import make_policy
from repro.fabric.engine import Simulator
from repro.fabric.state import FlowTable

from tests.test_properties import PARAMS, mid_state, traces


@given(traces())
@settings(max_examples=30, deadline=None)
def test_admission_matches_numpy(trace):
    """All-or-none admission rates: jitted tick == numpy Fig. 7 loop."""
    t = mid_state(trace)
    ref = make_policy("saath", PARAMS, work_conservation=False)
    ref.reset(t)
    want = ref.schedule(t, 1.0)

    jaxp = make_policy("saath-jax", PARAMS, work_conservation=False)
    jaxp.reset(t)
    got = jaxp.schedule(t, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)


@given(traces())
@settings(max_examples=15, deadline=None)
def test_full_sim_close_to_numpy(trace):
    """End-to-end on the FULL reference config (per-flow work
    conservation + §4.3 dynamics re-queue, both defaults): the jitted
    coordinator's replay matches the numpy reference's average CCT
    within 1% — the former 2x coflow-granularity envelope is closed."""
    ta = FlowTable.from_trace(trace, PARAMS.port_bw)
    ra = Simulator(PARAMS).run(ta, make_policy("saath", PARAMS))
    tb = FlowTable.from_trace(trace, PARAMS.port_bw)
    rb = Simulator(PARAMS).run(tb, make_policy("saath-jax", PARAMS))
    assert rb.table.finished.all()
    a = float(np.nanmean(ra.table.cct))
    b = float(np.nanmean(rb.table.cct))
    assert abs(b - a) <= 1e-2 * a + 2 * PARAMS.delta


def mixed_state(trace, frac=0.5):
    """A state where some flows FINISHED and some are live — the §4.3
    re-queue trigger — with every coflow keeping >= 1 live flow."""
    t = FlowTable.from_trace(trace, PARAMS.port_bw)
    rng = np.random.default_rng(1)
    t.sent = t.size * rng.uniform(0, 1, t.size.shape) * 0.5
    done = rng.uniform(size=t.size.shape) < frac
    for c in range(t.num_coflows):
        lo, hi = t.flow_lo[c], t.flow_hi[c]
        if done[lo:hi].all():
            done[lo] = False
    t.done[:] = done
    t.sent[done] = t.size[done]
    t.fct[done] = 0.5
    t.active[:] = True
    return t


@given(traces())
@settings(max_examples=30, deadline=None)
def test_requeue_matches_numpy(trace):
    """§4.3 re-queue: on randomized mixed done/live tables the jitted
    tick's queue assignment (median-estimated remaining length, Eq. 1)
    equals the numpy Saath._assign_queues."""
    t = mixed_state(trace)
    ref = make_policy("saath", PARAMS)
    ref.reset(t)
    want_q = ref._assign_queues(t, 1.0)
    jaxp = make_policy("saath-jax", PARAMS)
    jaxp.reset(t)
    jaxp.schedule(t, 1.0)
    got_q = np.asarray(jaxp._last_out["queue"])[:t.num_coflows]
    np.testing.assert_array_equal(got_q, want_q)


@given(traces())
@settings(max_examples=30, deadline=None)
def test_per_flow_wc_rates_match_numpy(trace):
    """Full-config single tick on mixed done/live tables: admission +
    per-flow work conservation + §4.3 re-queue — the per-FLOW rates
    (a strict subset of a missed coflow's flows may be rescued) equal
    the numpy reference's greedy_flow_alloc fill."""
    t = mixed_state(trace)
    ref = make_policy("saath", PARAMS)
    ref.reset(t)
    want = ref.schedule(t, 1.0)
    jaxp = make_policy("saath-jax", PARAMS)
    jaxp.reset(t)
    got = jaxp.schedule(t, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_jax_coordinator_states_roll_forward():
    """Deadlines and queues persist across ticks (stateless-restart also
    re-derivable, mirroring the paper's stateless coordinator)."""
    import jax.numpy as jnp

    from repro.core import jax_coordinator as jc

    cp = jc.CoordParams.from_params(SchedulerParams(port_bw=1.0))
    C, P = 8, 4
    state = jc.init_state(C)
    rng = np.random.default_rng(0)
    batch = jc.CoflowBatch(
        active=jnp.asarray(np.ones(C, bool)),
        arrival=jnp.arange(C, dtype=jnp.int32),
        m=jnp.zeros(C, jnp.float32),
        width=jnp.ones(C, jnp.int32),
        cnt_s=jnp.asarray((rng.uniform(size=(C, P)) < 0.4).astype(np.float32)),
        cnt_r=jnp.asarray((rng.uniform(size=(C, P)) < 0.4).astype(np.float32)),
        bw_s=jnp.ones(P, jnp.float32),
        bw_r=jnp.ones(P, jnp.float32),
    )
    s1, o1 = jc.schedule_tick(state, batch, jnp.float32(0.0), cp=cp)
    assert np.isfinite(np.asarray(s1.deadline)).all()
    s2, o2 = jc.schedule_tick(s1, batch, jnp.float32(0.5), cp=cp)
    # same fabric, same tick inputs -> stable admission (no churn)
    np.testing.assert_array_equal(np.asarray(o1["admitted"]),
                                  np.asarray(o2["admitted"]))
    # deadlines unchanged when queues did not change
    np.testing.assert_allclose(np.asarray(s1.deadline),
                               np.asarray(s2.deadline))
