"""Property-based tests (hypothesis) for the scheduler invariants."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.coflow import Coflow, Flow, Trace
from repro.core.params import SchedulerParams
from repro.core.policies import REGISTRY, make_policy
from repro.core.policies.base import greedy_flow_alloc
from repro.fabric.engine import Simulator
from repro.fabric.state import FlowTable

PORTS = 6


@st.composite
def traces(draw, max_coflows=8, max_flows=5):
    n = draw(st.integers(1, max_coflows))
    coflows = []
    fid = 0
    for c in range(n):
        arrival = draw(st.floats(0.0, 5.0, allow_nan=False))
        w = draw(st.integers(1, max_flows))
        flows = []
        for _ in range(w):
            src = draw(st.integers(0, PORTS - 1))
            dst = draw(st.integers(0, PORTS - 1))
            size = draw(st.floats(0.5, 20.0, allow_nan=False))
            flows.append(Flow(fid, src, dst, size))
            fid += 1
        coflows.append(Coflow(c, arrival, flows))
    return Trace(num_ports=PORTS, coflows=coflows)


PARAMS = SchedulerParams(port_bw=1.0, delta=1e-2, start_threshold=4.0,
                         growth=4.0, num_queues=5)


def mid_state(trace, frac=0.3):
    """A half-served state: some bytes sent, some flows done."""
    t = FlowTable.from_trace(trace, PARAMS.port_bw)
    rng = np.random.default_rng(0)
    t.sent = t.size * rng.uniform(0, 1, t.size.shape) * frac
    t.active[:] = True
    return t


@given(traces())
@settings(max_examples=60, deadline=None)
def test_capacity_never_exceeded(trace):
    t = mid_state(trace)
    for name in REGISTRY:
        pol = make_policy(name, PARAMS)
        pol.reset(t)
        rates = pol.schedule(t, 1.0)
        live = t.flow_live()
        assert (rates[~live] == 0).all(), name
        load_s = np.bincount(t.src, weights=rates, minlength=PORTS)
        load_r = np.bincount(t.dst, weights=rates, minlength=PORTS)
        # 1e-6 relative slack: the jitted coordinator runs in f32
        assert (load_s <= PARAMS.port_bw * (1 + 1e-6)).all(), name
        assert (load_r <= PARAMS.port_bw * (1 + 1e-6)).all(), name


@given(traces())
@settings(max_examples=60, deadline=None)
def test_all_or_none_equal_rates(trace):
    """With WC off, every coflow's live flows get one equal rate or none
    (all-or-none + MADD equal-rate D2)."""
    t = mid_state(trace)
    pol = make_policy("saath", PARAMS, work_conservation=False)
    pol.reset(t)
    rates = pol.schedule(t, 1.0)
    live = t.flow_live()
    for c in range(t.num_coflows):
        lo, hi = t.flow_lo[c], t.flow_hi[c]
        r = rates[lo:hi][live[lo:hi]]
        if r.size == 0:
            continue
        assert (r == 0).all() or (r > 0).all(), "partial coflow scheduled"
        if (r > 0).all():
            np.testing.assert_allclose(r, r[0], rtol=1e-9)


@given(traces())
@settings(max_examples=60, deadline=None)
def test_work_conservation_no_idle_pair(trace):
    """After Saath's schedule, every live flow faces at least one
    saturated port (otherwise WC would have given it bandwidth)."""
    t = mid_state(trace)
    pol = make_policy("saath", PARAMS)
    pol.reset(t)
    rates = pol.schedule(t, 1.0)
    live = t.flow_live()
    avail_s = PARAMS.port_bw - np.bincount(t.src, weights=rates,
                                           minlength=PORTS)
    avail_r = PARAMS.port_bw - np.bincount(t.dst, weights=rates,
                                           minlength=PORTS)
    slack = np.minimum(avail_s[t.src], avail_r[t.dst])
    assert (slack[live & (rates <= 0)] <= 1e-9).all()


@given(traces(), st.sampled_from(sorted(REGISTRY)))
@settings(max_examples=40, deadline=None)
def test_simulation_completes_and_conserves(trace, name):
    table = FlowTable.from_trace(trace, PARAMS.port_bw)
    res = Simulator(PARAMS).run(table, make_policy(name, PARAMS))
    t = res.table
    assert t.finished.all()
    assert t.done.all()
    np.testing.assert_allclose(t.sent, t.size, rtol=1e-9)
    # CCT lower bound: the coflow's bottleneck-port bytes at 1 byte/s,
    # minus grid quantization slack
    for c, cf in enumerate(sorted(trace.coflows, key=lambda c: c.cid)):
        lb = cf.bottleneck_bytes(PORTS) / PARAMS.port_bw
        assert t.cct[c] >= lb - 2 * PARAMS.delta - 1e-9
        # FCTs lie within [arrival, makespan]
        lo, hi = t.flow_lo[c], t.flow_hi[c]
        assert (t.fct[lo:hi] >= t.arrival[c] - 1e-9).all()


@given(traces())
@settings(max_examples=40, deadline=None)
def test_queue_index_monotone_without_dynamics(trace):
    params = SchedulerParams(port_bw=1.0, delta=1e-2, start_threshold=4.0,
                             growth=4.0, num_queues=5,
                             dynamics_requeue=False)
    table = FlowTable.from_trace(trace, params.port_bw)
    pol = make_policy("saath", params)

    seen = {}

    orig = pol._assign_queues

    def spy(table, now):
        q = orig(table, now)
        for c in np.nonzero(table.active)[0]:
            if c in seen:
                assert q[c] >= seen[c], "queue moved up without dynamics"
            seen[c] = q[c]
        return q

    pol._assign_queues = spy
    Simulator(params).run(table, pol)


@given(traces())
@settings(max_examples=40, deadline=None)
def test_greedy_alloc_matches_sequential(trace):
    """Round-based vectorized greedy == the one-at-a-time reference."""
    t = mid_state(trace)
    live = t.flow_live()
    order = np.argsort(t.size, kind="stable")

    fast = greedy_flow_alloc(t, order, live)

    rates = np.zeros(t.size.shape[0])
    avail_s = t.bw_send.copy()
    avail_r = t.bw_recv.copy()
    for f in order:
        if not live[f]:
            continue
        r = min(avail_s[t.src[f]], avail_r[t.dst[f]])
        if r <= 0:
            continue
        rates[f] = r
        avail_s[t.src[f]] -= r
        avail_r[t.dst[f]] -= r
    np.testing.assert_allclose(fast, rates, rtol=1e-12)
