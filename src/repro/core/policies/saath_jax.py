"""Simulator wrapper around the jitted JAX coordinator (core.jax_coordinator).

Full-fidelity agreement with the numpy Saath: the all-or-none admission
is exact (property-tested), work conservation runs per-flow through the
coordinator's FlowView path (same greedy order as
``policies.base.greedy_flow_alloc``), and the §4.3 dynamics re-queue is
fed the same finished-flow-median remaining-length estimate the numpy
reference computes.
"""
from __future__ import annotations

import numpy as np

from repro.core import jax_coordinator as jc
from repro.core.params import SchedulerParams
from repro.core.policies.base import Policy
from repro.fabric.state import FlowTable


class SaathJax(Policy):
    name = "saath-jax"

    def __init__(self, params: SchedulerParams, *, kernel: str | None = None,
                 work_conservation: bool | None = None):
        super().__init__(params)
        cp = jc.CoordParams.from_params(params)
        if work_conservation is not None:
            cp = cp._replace(work_conservation=work_conservation)
        self.cp = cp
        self.kernel = kernel

    def reset(self, table: FlowTable) -> None:
        # pad the coflow/flow axes to limit jit recompiles across traces
        self._C = -(-table.num_coflows // 64) * 64
        self._F = -(-table.size.shape[0] // 256) * 256
        self._state = jc.init_state(self._C)

    def _dynamics(self, table: FlowTable, live: np.ndarray):
        """§4.3 inputs, mirroring Saath._assign_queues: which coflows are
        mixed done/live, and their median-estimated remaining length."""
        C = table.num_coflows
        mixed = np.zeros(C, bool)
        m_dyn = np.zeros(C)
        if not self.cp.dynamics_requeue:
            return mixed, m_dyn
        done_f = table.done & table.active[table.cid]
        has_done = np.bincount(table.cid[done_f], minlength=C) > 0
        has_live = np.bincount(table.cid[live], minlength=C) > 0
        mixed = has_done & has_live & table.active
        for c in np.nonzero(mixed)[0]:
            lo, hi = table.flow_lo[c], table.flow_hi[c]
            fdone = table.done[lo:hi]
            f_e = float(np.median(table.size[lo:hi][fdone]))
            rem = np.maximum(f_e - table.sent[lo:hi][~fdone], 0.0)
            m_dyn[c] = float(rem.max()) if rem.size else 0.0
        return mixed, m_dyn

    def _views(self, table: FlowTable):
        import jax.numpy as jnp

        live = table.flow_live()
        cnt_s, cnt_r = table.flow_counts(live)
        C, Cp = table.num_coflows, self._C
        F, Fp = table.size.shape[0], self._F

        def pad(x, fill=0, n=None):
            n = Cp if n is None else n
            out = np.full((n,) + x.shape[1:], fill, x.dtype)
            out[:x.shape[0]] = x
            return jnp.asarray(out)

        rank = np.argsort(np.argsort(table.arrival, kind="stable"),
                          kind="stable").astype(np.int32)
        mixed, m_dyn = self._dynamics(table, live)
        batch = jc.CoflowBatch(
            active=pad(table.active),
            arrival=pad(rank, 2 ** 30),
            m=pad(table.coflow_max_flow_sent().astype(np.float32)),
            width=pad(table.width.astype(np.int32), 1),
            cnt_s=pad(cnt_s.astype(np.float32)),
            cnt_r=pad(cnt_r.astype(np.float32)),
            bw_s=jnp.asarray(table.bw_send, jnp.float32),
            bw_r=jnp.asarray(table.bw_recv, jnp.float32),
            total=pad(table.coflow_sent_total().astype(np.float32)),
            mixed=pad(mixed),
            m_dyn=pad(m_dyn.astype(np.float32)),
        )
        flows = jc.FlowView(
            cid=pad(table.cid, 0, Fp),
            src=pad(table.src, 0, Fp), dst=pad(table.dst, 0, Fp),
            live=pad(live, False, Fp))
        return batch, flows

    def schedule(self, table: FlowTable, now: float) -> np.ndarray:
        import jax.numpy as jnp

        batch, flows = self._views(table)
        self._state, out = jc.schedule_tick(
            self._state, batch, jnp.float32(now),
            cp=self.cp, kernel=self.kernel, flows=flows)
        F = table.size.shape[0]
        r_c = np.asarray(out["rate"], np.float64)[:table.num_coflows]
        rates = r_c[table.cid]
        rates[~table.flow_live()] = 0.0
        rates += np.asarray(out["wc_flow"], np.float64)[:F]
        self._last_out = out
        return rates

    def progress_events(self, table: FlowTable, now: float,
                        rates: np.ndarray) -> float:
        # same per-flow-threshold / deadline events as the numpy Saath
        p = self.params
        th = np.array(p.thresholds())
        q = np.asarray(self._state.queue)
        q = np.where(q < 0, 0, q)[table.cid]
        lim = th[q] / np.maximum(table.width[table.cid], 1)
        live = table.flow_live()
        with np.errstate(divide="ignore", invalid="ignore"):
            dt = np.where(live & (rates > 0) & np.isfinite(lim),
                          (lim - table.sent) / rates, np.inf)
        dt = dt[dt > 1e-12]
        t = now + float(dt.min()) if dt.size else float("inf")
        dl = np.asarray(self._state.deadline)[:table.num_coflows]
        dl = dl[table.active & (dl > now + 1e-12)]
        if dl.size:
            t = min(t, float(dl.min()))
        return t
