"""Simulator wrapper around the jitted JAX coordinator (core.jax_coordinator).

Agreement with the numpy Saath is exact for the all-or-none admission
(property-tested); work conservation is coflow-granular here (see the
jax_coordinator docstring).
"""
from __future__ import annotations

import numpy as np

from repro.core import jax_coordinator as jc
from repro.core.params import SchedulerParams
from repro.core.policies.base import Policy
from repro.fabric.state import FlowTable


class SaathJax(Policy):
    name = "saath-jax"

    def __init__(self, params: SchedulerParams, *, kernel: str | None = None,
                 work_conservation: bool = True):
        super().__init__(params)
        self.cp = jc.CoordParams.from_params(params)
        self.kernel = kernel
        self.work_conservation = work_conservation

    def reset(self, table: FlowTable) -> None:
        # pad the coflow axis to limit jit recompiles across traces
        self._C = -(-table.num_coflows // 64) * 64
        self._state = jc.init_state(self._C)

    def _batch(self, table: FlowTable) -> jc.CoflowBatch:
        import jax.numpy as jnp

        live = table.flow_live()
        cnt_s, cnt_r = table.flow_counts(live)
        C, Cp = table.num_coflows, self._C

        def pad(x, fill=0):
            out = np.full((Cp,) + x.shape[1:], fill, x.dtype)
            out[:C] = x
            return jnp.asarray(out)

        rank = np.argsort(np.argsort(table.arrival, kind="stable"),
                          kind="stable").astype(np.int32)
        return jc.CoflowBatch(
            active=pad(table.active),
            arrival=pad(rank, 2 ** 30),
            m=pad(table.coflow_max_flow_sent().astype(np.float32)),
            width=pad(table.width.astype(np.int32), 1),
            cnt_s=pad(cnt_s.astype(np.float32)),
            cnt_r=pad(cnt_r.astype(np.float32)),
            bw_s=jnp.asarray(table.bw_send, jnp.float32),
            bw_r=jnp.asarray(table.bw_recv, jnp.float32),
        )

    def schedule(self, table: FlowTable, now: float) -> np.ndarray:
        import jax.numpy as jnp

        self._state, out = jc.schedule_tick(
            self._state, self._batch(table), jnp.float32(now),
            cp=self.cp, kernel=self.kernel)
        r_c = np.asarray(out["rate"], np.float64)[:table.num_coflows]
        if self.work_conservation:
            r_c = r_c + np.asarray(
                out["wc_rate"], np.float64)[:table.num_coflows]
        rates = r_c[table.cid]
        rates[~table.flow_live()] = 0.0
        self._last_out = out
        return rates

    def progress_events(self, table: FlowTable, now: float,
                        rates: np.ndarray) -> float:
        # same per-flow-threshold / deadline events as the numpy Saath
        p = self.params
        th = np.array(p.thresholds())
        q = np.asarray(self._state.queue)
        q = np.where(q < 0, 0, q)[table.cid]
        lim = th[q] / np.maximum(table.width[table.cid], 1)
        live = table.flow_live()
        with np.errstate(divide="ignore", invalid="ignore"):
            dt = np.where(live & (rates > 0) & np.isfinite(lim),
                          (lim - table.sent) / rates, np.inf)
        dt = dt[dt > 1e-12]
        t = now + float(dt.min()) if dt.size else float("inf")
        dl = np.asarray(self._state.deadline)[:table.num_coflows]
        dl = dl[table.active & (dl > now + 1e-12)]
        if dl.size:
            t = min(t, float(dl.min()))
        return t
