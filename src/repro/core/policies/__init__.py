from repro.core.policies.aalo import Aalo, CoordinatedFifo
from repro.core.policies.base import Policy
from repro.core.policies.offline import LWTF, SCF, SRTF, VarysSEBF
from repro.core.policies.saath import Saath
from repro.core.policies.saath_jax import SaathJax
from repro.core.policies.uctcp import UCTCP

REGISTRY = {
    "saath": Saath,
    "saath-jax": SaathJax,
    "aalo": Aalo,
    "fifo": CoordinatedFifo,
    "scf": SCF,
    "srtf": SRTF,
    "lwtf": LWTF,
    "varys-sebf": VarysSEBF,
    "uc-tcp": UCTCP,
}


def make_policy(name: str, params, **kw) -> Policy:
    return REGISTRY[name](params, **kw)

__all__ = ["Policy", "Saath", "SaathJax", "Aalo", "CoordinatedFifo", "SCF",
           "SRTF", "LWTF", "VarysSEBF", "UCTCP", "REGISTRY", "make_policy"]
