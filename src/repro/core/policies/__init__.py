from repro.core.policies.aalo import Aalo, CoordinatedFifo
from repro.core.policies.base import Policy
from repro.core.policies.offline import LWTF, SCF, SRTF, VarysSEBF
from repro.core.policies.saath import Saath
from repro.core.policies.saath_jax import SaathJax
from repro.core.policies.uctcp import UCTCP

REGISTRY = {
    "saath": Saath,
    "saath-jax": SaathJax,
    "aalo": Aalo,
    "fifo": CoordinatedFifo,
    "scf": SCF,
    "srtf": SRTF,
    "lwtf": LWTF,
    "varys-sebf": VarysSEBF,
    "uc-tcp": UCTCP,
}

# Policies whose Fig. 7 tick also exists as the jitted XLA plane
# (core.jax_coordinator / fabric.jax_engine): "saath" and its
# tick-at-a-time wrapper resolve to the SAME algorithm on both engines,
# so `repro.api.Scenario(policy="saath")` is engine-portable; every
# other registry entry is host-only.
JAX_ENGINE_POLICIES = frozenset({"saath", "saath-jax"})


def available(engine: str = "numpy") -> list:
    """Policy names runnable on `engine` ('numpy' = host reference
    simulator, 'jax' = batched XLA fleet engine), sorted."""
    names = REGISTRY if engine == "numpy" else JAX_ENGINE_POLICIES
    return sorted(names)


def make_policy(name: str, params, **kw) -> Policy:
    """Instantiate a registered policy; unknown names raise with the
    available list (the single name registry both planes resolve
    through)."""
    try:
        cls = REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; available: "
            f"{', '.join(sorted(REGISTRY))}") from None
    return cls(params, **kw)


def resolve_policy(name: str, engine: str) -> str:
    """Validate `name` for `engine` and return its canonical name.

    Both planes resolve through the one REGISTRY: on the jax engine the
    saath family maps onto the jitted coordinator (canonically "saath");
    host-only policies raise with the jax-capable list, unknown names
    raise with the full list.
    """
    if name not in REGISTRY:
        raise ValueError(
            f"unknown policy {name!r}; available: "
            f"{', '.join(sorted(REGISTRY))}")
    if engine == "jax":
        if name not in JAX_ENGINE_POLICIES:
            raise ValueError(
                f"policy {name!r} has no jitted implementation; "
                f"engine='jax' supports: "
                f"{', '.join(sorted(JAX_ENGINE_POLICIES))} "
                f"(use engine='numpy' for the host reference)")
        return "saath"
    return name


__all__ = ["Policy", "Saath", "SaathJax", "Aalo", "CoordinatedFifo", "SCF",
           "SRTF", "LWTF", "VarysSEBF", "UCTCP", "REGISTRY", "make_policy",
           "JAX_ENGINE_POLICIES", "available", "resolve_policy"]
