"""SAATH (the paper's contribution): all-or-none + per-flow queue
thresholds + LCoF + work conservation + starvation deadlines + §4.3
cluster-dynamics (approximate-SRTF) re-queueing.

This is the numpy reference coordinator; `repro.core.jax_coordinator`
is the jitted in-framework version (property-tested to agree).
"""
from __future__ import annotations

import numpy as np

from repro.core import queues
from repro.core.contention import contention
from repro.core.params import SchedulerParams
from repro.core.sampling import SizeEstimator
from repro.core.policies.base import (Policy, greedy_flow_alloc,
                                      maxmin_waterfill)
from repro.fabric.state import FlowTable


class Saath(Policy):
    name = "saath"

    def __init__(self, params: SchedulerParams, *, all_or_none: bool = True,
                 per_flow_threshold: bool = True, lcof: bool = True,
                 work_conservation: bool | None = None):
        super().__init__(params)
        # ablation switches (Fig. 10: A/N, A/N+PF, full SAATH);
        # work_conservation defaults to the SchedulerParams field so the
        # numpy reference and the jitted planes read one knob
        self.all_or_none = all_or_none
        self.per_flow_threshold = per_flow_threshold
        self.lcof = lcof
        self.work_conservation = (params.work_conservation
                                  if work_conservation is None
                                  else work_conservation)
        # non-clairvoyant mode: pilot-flow size learning (sampling.py)
        self.estimator = (None if params.clairvoyant
                          else SizeEstimator(params))

    def reset(self, table: FlowTable) -> None:
        C = table.num_coflows
        self._queue = np.full(C, -1, np.int32)     # -1 = not yet seen
        self._deadline = np.full(C, np.inf)
        self._running = np.zeros(C, bool)  # admitted in the last schedule
        self.stats_deadline_hits = 0
        self.stats_admitted = 0
        self.stats_wc_flows = 0

    # ---- queue assignment (D3 + §4.3) -----------------------------------
    def _assign_queues(self, table: FlowTable, now: float) -> np.ndarray:
        p = self.params
        if self.per_flow_threshold:
            q_new = queues.saath_queue(table.coflow_max_flow_sent(),
                                       table.width, p)
        else:
            q_new = queues.aalo_queue(table.coflow_sent_total(), p)

        if p.dynamics_requeue and p.clairvoyant:
            # §4.3: once some flows finished, estimate remaining length from
            # the median finished-flow length and re-queue by Eq. 1 — this can
            # move a coflow back UP the queues (approximate SRTF).
            live = table.flow_live()
            done_f = table.done & table.active[table.cid]
            has_done = np.bincount(table.cid[done_f],
                                   minlength=table.num_coflows) > 0
            has_live = np.bincount(table.cid[live],
                                   minlength=table.num_coflows) > 0
            mixed = has_done & has_live & table.active
            if mixed.any():
                for c in np.nonzero(mixed)[0]:
                    lo, hi = table.flow_lo[c], table.flow_hi[c]
                    fdone = table.done[lo:hi]
                    f_e = float(np.median(table.size[lo:hi][fdone]))
                    rem = np.maximum(f_e - table.sent[lo:hi][~fdone], 0.0)
                    m_hat = float(rem.max()) if rem.size else 0.0
                    q_new[c] = queues.saath_queue(
                        np.array([m_hat]), table.width[c:c + 1], p)[0]
        elif p.dynamics_requeue:
            # non-clairvoyant §4.3: the re-queue runs off the pilot-flow
            # estimate (mean finished-pilot size) instead of the exact
            # finished-flow median; coflows whose pilots are all still in
            # flight keep their bytes-sent Eq. 1 placement above.
            live = table.flow_live()
            est_flow, _, learned = self.estimator.estimates(table)
            has_live = np.bincount(table.cid[live],
                                   minlength=table.num_coflows) > 0
            mixed = learned & has_live & table.active
            if mixed.any():
                for c in np.nonzero(mixed)[0]:
                    lo, hi = table.flow_lo[c], table.flow_hi[c]
                    fdone = table.done[lo:hi]
                    rem = np.maximum(
                        est_flow[c] - table.sent[lo:hi][~fdone], 0.0)
                    m_hat = float(rem.max()) if rem.size else 0.0
                    q_new[c] = queues.saath_queue(
                        np.array([m_hat]), table.width[c:c + 1], p)[0]
        return q_new

    # ---- deadlines (D5) ---------------------------------------------------
    def _refresh_deadlines(self, table: FlowTable, q_new: np.ndarray,
                           now: float) -> None:
        p = self.params
        entered = table.active & (q_new != self._queue)
        if entered.any():
            cq = np.bincount(q_new[table.active], minlength=p.num_queues)
            t_min = queues.min_queue_residence(q_new, table.width, p)
            for c in np.nonzero(entered)[0]:
                self._deadline[c] = now + (
                    p.deadline_factor * max(cq[q_new[c]], 1) * t_min[c])
        self._queue = np.where(table.active, q_new, self._queue)

    # ---- the Fig. 7 schedule ---------------------------------------------
    def schedule(self, table: FlowTable, now: float) -> np.ndarray:
        p = self.params
        live = table.flow_live()
        rates = np.zeros(table.size.shape[0])
        if not live.any():
            return rates

        q_new = self._assign_queues(table, now)
        self._refresh_deadlines(table, q_new, now)

        active = table.active.copy()
        A_s, A_r = table.incidence(live)
        k = contention(A_s, A_r, active)
        expired = active & (now >= self._deadline)
        self.stats_deadline_hits += int(expired.sum())

        # LCoF order: deadline-expired first (FIFO-by-deadline among them),
        # then (queue, contention, stability, arrival). Fig.7 lines 2-4.
        # 'stability' prefers coflows admitted in the previous schedule on
        # exact (queue, contention) ties — local agents follow the current
        # schedule until told otherwise (§5), so ties do not cause churn.
        # expired deadline TIES break by arrival (then index): same
        # tick + same queue + same width gives exactly equal deadlines,
        # and both planes must resolve them by a layout-independent
        # order — the jitted coordinator's slab position is a session's
        # submission order, not this table's cid order.
        cids = np.nonzero(active)[0]
        if self.lcof:
            key = [(0, self._deadline[c], 0, 0, table.arrival[c], c)
                   if expired[c] else
                   (1, q_new[c], k[c], int(~self._running[c]),
                    table.arrival[c], c) for c in cids]
        else:  # FIFO within queue (the A/N-only ablation)
            key = [(0, self._deadline[c], 0, 0, table.arrival[c], c)
                   if expired[c] else
                   (1, q_new[c], table.arrival[c], 0, 0, c) for c in cids]
        order = cids[sorted(range(len(cids)), key=lambda i: key[i])]

        cnt_s, cnt_r = table.flow_counts(live)
        avail_s = table.bw_send.copy()
        avail_r = table.bw_recv.copy()
        # fabric model (DESIGN.md §11): on a leaf-spine topology the
        # MADD rate is also capped by the coflow's per-uplink/downlink
        # flow counts against residual link capacity; `extra is None`
        # (big switch) keeps every line below bitwise pre-refactor
        extra = self.fabric_binding(table)
        avail_x = cnt_x = None
        if extra is not None:
            avail_x = extra.cap.copy()
            cnt_x = np.zeros((table.num_coflows, avail_x.shape[0]),
                             np.int64)
            lf = live & (extra.up >= 0)
            np.add.at(cnt_x, (table.cid[lf], extra.up[lf]), 1)
            np.add.at(cnt_x, (table.cid[lf], extra.dn[lf]), 1)
        admitted = np.zeros(table.num_coflows, bool)
        missed = []
        for c in order:
            cs, cr = cnt_s[c], cnt_r[c]
            ps, pr = cs > 0, cr > 0
            if not ps.any() and not pr.any():
                continue
            # MADD equal rate (D2): slowest-port rate for every flow
            r = np.inf
            if ps.any():
                r = min(r, (avail_s[ps] / cs[ps]).min())
            if pr.any():
                r = min(r, (avail_r[pr] / cr[pr]).min())
            if extra is not None:
                cx = cnt_x[c]
                px = cx > 0
                if px.any():
                    r = min(r, (avail_x[px] / cx[px]).min())
            if self.all_or_none and r < p.min_rate:
                missed.append(c)
                continue
            if r <= 0.0:
                missed.append(c)
                continue
            lo, hi = table.flow_lo[c], table.flow_hi[c]
            seg = rates[lo:hi]
            seg[live[lo:hi]] = r
            avail_s -= r * cs
            avail_r -= r * cr
            if extra is not None:
                avail_x -= r * cnt_x[c]
            admitted[c] = True
            self.stats_admitted += 1

        if self.work_conservation and missed:
            # D4 lines 18-23: per-flow greedy fill of leftover bandwidth, in
            # the missed-coflow order (the 'ordered list of the un-scheduled
            # CoFlows'). A LeafSpine(wc_fill="maxmin") topology fills the
            # leftovers by max-min water-filling instead — the allocation
            # family of the in-network papers.
            wc_order = np.concatenate(
                [np.arange(table.flow_lo[c], table.flow_hi[c])
                 for c in missed])
            before = rates > 0
            if extra is not None and \
                    getattr(self.topology, "wc_fill", "greedy") == "maxmin":
                cand = np.zeros(live.shape, bool)
                cand[wc_order] = True
                cand &= live
                rates += maxmin_waterfill(
                    table, cand, extra=extra, avail_s=avail_s,
                    avail_r=avail_r, avail_x=avail_x)
            else:
                greedy_flow_alloc(table, wc_order, live, avail_s, avail_r,
                                  rates, extra=extra, avail_x=avail_x)
            self.stats_wc_flows += int(((rates > 0) & ~before).sum())

        if p.wc_admitted_round:
            # beyond-paper: raise the equal rate of admitted coflows when all
            # of their ports still have slack (keeps MADD equal-rate shape).
            for c in order:
                cs, cr = cnt_s[c], cnt_r[c]
                ps, pr = cs > 0, cr > 0
                if not (ps.any() or pr.any()) or c in missed:
                    continue
                r = np.inf
                if ps.any():
                    r = min(r, (avail_s[ps] / cs[ps]).min())
                if pr.any():
                    r = min(r, (avail_r[pr] / cr[pr]).min())
                if extra is not None:
                    cx = cnt_x[c]
                    px = cx > 0
                    if px.any():
                        r = min(r, (avail_x[px] / cx[px]).min())
                if not np.isfinite(r) or r <= 0.0:
                    continue
                sel = live & (table.cid == c)
                rates[sel] += r
                avail_s -= r * cs
                avail_r -= r * cr
                if extra is not None:
                    avail_x -= r * cnt_x[c]

        self._running = admitted
        return rates

    # ---- simulator event hook ---------------------------------------------
    def progress_events(self, table: FlowTable, now: float,
                        rates: np.ndarray) -> float:
        """Earliest of (a) a per-flow queue-threshold crossing, (b) a
        starvation-deadline expiry, under constant `rates`."""
        p = self.params
        live = table.flow_live()
        t = float("inf")
        th = np.array(p.thresholds())
        if self.per_flow_threshold:
            # flow f of coflow c crosses when sent_f reaches Q_q^hi / N_c
            q = self._queue[table.cid]
            q = np.where(q < 0, 0, q)
            lim = th[q] / np.maximum(table.width[table.cid], 1)
            with np.errstate(divide="ignore", invalid="ignore"):
                dt = np.where(live & (rates > 0) & np.isfinite(lim),
                              (lim - table.sent) / rates, np.inf)
            dt = dt[dt > 1e-12]
            if dt.size:
                t = min(t, now + float(dt.min()))
        else:
            R = np.bincount(table.cid, weights=rates,
                            minlength=table.num_coflows)
            total = table.coflow_sent_total()
            q = np.where(self._queue < 0, 0, self._queue)
            nxt = th[q]
            with np.errstate(divide="ignore", invalid="ignore"):
                dt = np.where((R > 0) & np.isfinite(nxt) & table.active,
                              (nxt - total) / R, np.inf)
            dt = dt[dt > 1e-12]
            if dt.size:
                t = min(t, now + float(dt.min()))
        dl = self._deadline[table.active & (self._deadline > now + 1e-12)]
        if dl.size:
            t = min(t, float(dl.min()))
        return t
