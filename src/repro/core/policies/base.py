"""Policy interface + shared allocation primitives."""
from __future__ import annotations

import numpy as np

from repro.core.params import SchedulerParams
from repro.fabric.state import FlowTable


class Policy:
    """A scheduling policy: maps fabric state -> per-flow rates (bytes/s).

    The simulator calls `schedule(table, now)` at every scheduling instant
    (δ-grid aligned). Policies may keep internal per-coflow bookkeeping
    (queues, deadlines); they must tolerate coflows finishing between calls.
    """

    name = "base"
    clairvoyant = False  # True => allowed to read flow sizes (offline)

    def __init__(self, params: SchedulerParams):
        self.params = params

    def reset(self, table: FlowTable) -> None:  # pragma: no cover - trivial
        pass

    def schedule(self, table: FlowTable, now: float) -> np.ndarray:
        raise NotImplementedError

    def progress_events(self, table: FlowTable, now: float,
                        rates: np.ndarray) -> float:
        """Earliest future instant at which this policy's *internal* state
        (queue assignment, deadline expiry) would change the schedule given
        constant `rates`. The simulator re-invokes the coordinator then.
        inf = no internal events (completions/arrivals still trigger)."""
        return float("inf")


def greedy_flow_alloc(table: FlowTable, flow_order: np.ndarray,
                      live: np.ndarray,
                      avail_s: np.ndarray | None = None,
                      avail_r: np.ndarray | None = None,
                      rates: np.ndarray | None = None) -> np.ndarray:
    """Allocate each live flow min(avail_src, avail_dst) in the given order.

    This is the per-port 'strict priority + FIFO within queue' allocation
    used by Aalo/SCF/SRTF/LWTF-style policies (no coflow coordination) and
    by Saath's work-conservation backfill (avail_s/avail_r passed in and
    updated in place).

    Exact round-based vectorization of the sequential greedy: in each round
    every candidate flow that is the FIRST (in priority order) to touch both
    its sender and receiver port is allocated min(avail) — identical to the
    one-at-a-time result because no earlier flow shares its ports. Each
    round saturates >=1 port per allocated flow, so rounds are few.
    """
    F = table.size.shape[0]
    rates = np.zeros(F) if rates is None else rates
    avail_s = table.bw_send.copy() if avail_s is None else avail_s
    avail_r = table.bw_recv.copy() if avail_r is None else avail_r
    src, dst = table.src, table.dst
    ordered = flow_order[live[flow_order]]
    for _ in range(2 * table.num_ports + 2):
        if ordered.size == 0:
            break
        cand = ordered[(avail_s[src[ordered]] > 0.0)
                       & (avail_r[dst[ordered]] > 0.0)]
        if cand.size == 0:
            break
        # first occurrence of each port, in priority order
        _, first_s = np.unique(src[cand], return_index=True)
        _, first_r = np.unique(dst[cand], return_index=True)
        is_first_s = np.zeros(cand.size, bool)
        is_first_r = np.zeros(cand.size, bool)
        is_first_s[first_s] = True
        is_first_r[first_r] = True
        take = cand[is_first_s & is_first_r]
        r = np.minimum(avail_s[src[take]], avail_r[dst[take]])
        rates[take] = r
        # 'take' flows have unique src and dst among themselves
        avail_s[src[take]] -= r
        avail_r[dst[take]] -= r
        ordered = cand[~(is_first_s & is_first_r)]
    return rates


def coflow_flow_order(table: FlowTable, coflow_rank: np.ndarray) -> np.ndarray:
    """Flow order induced by a per-coflow rank (ties by flow id)."""
    return np.lexsort((np.arange(table.size.shape[0]),
                       coflow_rank[table.cid]))


def maxmin_waterfill(table: FlowTable, live: np.ndarray,
                     max_iter: int | None = None) -> np.ndarray:
    """Exact bipartite max-min fair rates (progressive filling).

    Models the steady-state throughput of per-flow TCP fair sharing —
    the UC-TCP baseline (§6.1).
    """
    F = table.size.shape[0]
    rates = np.zeros(F)
    frozen = ~live
    avail_s = table.bw_send.copy()
    avail_r = table.bw_recv.copy()
    it = 0
    limit = max_iter or 2 * table.num_ports + 2
    while not frozen.all() and it < limit:
        it += 1
        act = ~frozen
        cnt_s = np.bincount(table.src[act], minlength=table.num_ports)
        cnt_r = np.bincount(table.dst[act], minlength=table.num_ports)
        with np.errstate(divide="ignore", invalid="ignore"):
            lvl_s = np.where(cnt_s > 0, avail_s / np.maximum(cnt_s, 1), np.inf)
            lvl_r = np.where(cnt_r > 0, avail_r / np.maximum(cnt_r, 1), np.inf)
        lvl = min(lvl_s.min(), lvl_r.min())
        if not np.isfinite(lvl):
            break
        # freeze flows incident to saturated ports at `lvl`
        sat_s = (lvl_s <= lvl + 1e-12) & (cnt_s > 0)
        sat_r = (lvl_r <= lvl + 1e-12) & (cnt_r > 0)
        hit = act & (sat_s[table.src] | sat_r[table.dst])
        if not hit.any():
            break
        rates[hit] = lvl
        np.subtract.at(avail_s, table.src[hit], lvl)
        np.subtract.at(avail_r, table.dst[hit], lvl)
        avail_s = np.maximum(avail_s, 0.0)
        avail_r = np.maximum(avail_r, 0.0)
        frozen = frozen | hit
    return rates
