"""Policy interface + shared allocation primitives."""
from __future__ import annotations

import numpy as np

from repro.core.params import SchedulerParams
from repro.fabric.state import FlowTable


class Policy:
    """A scheduling policy: maps fabric state -> per-flow rates (bytes/s).

    The simulator calls `schedule(table, now)` at every scheduling instant
    (δ-grid aligned). Policies may keep internal per-coflow bookkeeping
    (queues, deadlines); they must tolerate coflows finishing between calls.

    `topology` is the fabric model the policy allocates against
    (`fabric.topology`): None/BigSwitch keeps the pre-refactor per-port
    arithmetic bitwise; `Simulator(topology=...)` installs it before
    `reset`.
    """

    name = "base"
    clairvoyant = False  # True => allowed to read flow sizes (offline)

    def __init__(self, params: SchedulerParams):
        self.params = params
        self.topology = None

    def fabric_binding(self, table: FlowTable):
        """The table-bound `ExtraLinks` of this policy's topology — None
        for the big switch, so allocation code gates all link handling
        on `extra is not None` (the bitwise-preservation pattern)."""
        from repro.fabric.topology import bind_table

        return bind_table(self.topology, table)

    def reset(self, table: FlowTable) -> None:  # pragma: no cover - trivial
        pass

    def schedule(self, table: FlowTable, now: float) -> np.ndarray:
        raise NotImplementedError

    def progress_events(self, table: FlowTable, now: float,
                        rates: np.ndarray) -> float:
        """Earliest future instant at which this policy's *internal* state
        (queue assignment, deadline expiry) would change the schedule given
        constant `rates`. The simulator re-invokes the coordinator then.
        inf = no internal events (completions/arrivals still trigger)."""
        return float("inf")


def greedy_flow_alloc(table: FlowTable, flow_order: np.ndarray,
                      live: np.ndarray,
                      avail_s: np.ndarray | None = None,
                      avail_r: np.ndarray | None = None,
                      rates: np.ndarray | None = None, *,
                      extra=None,
                      avail_x: np.ndarray | None = None) -> np.ndarray:
    """Allocate each live flow min(avail_src, avail_dst) in the given order.

    This is the per-port 'strict priority + FIFO within queue' allocation
    used by Aalo/SCF/SRTF/LWTF-style policies (no coflow coordination) and
    by Saath's work-conservation backfill (avail_s/avail_r passed in and
    updated in place).

    Exact round-based vectorization of the sequential greedy: in each round
    every candidate flow that is the FIRST (in priority order) to touch both
    its sender and receiver port is allocated min(avail) — identical to the
    one-at-a-time result because no earlier flow shares its ports. Each
    round saturates >=1 port per allocated flow, so rounds are few.

    `extra` (a `fabric.topology.ExtraLinks`) extends the walk to a
    leaf-spine fabric: candidates must also see residual capacity on
    their uplink/downlink, the first-toucher rule covers those links,
    and the allocation is the min over all four resources. With
    `extra=None` the pre-refactor body runs unchanged (bitwise — the
    regression guard in tests/test_fabric_regression.py).
    """
    F = table.size.shape[0]
    rates = np.zeros(F) if rates is None else rates
    avail_s = table.bw_send.copy() if avail_s is None else avail_s
    avail_r = table.bw_recv.copy() if avail_r is None else avail_r
    src, dst = table.src, table.dst
    ordered = flow_order[live[flow_order]]
    if extra is None:
        for _ in range(2 * table.num_ports + 2):
            if ordered.size == 0:
                break
            cand = ordered[(avail_s[src[ordered]] > 0.0)
                           & (avail_r[dst[ordered]] > 0.0)]
            if cand.size == 0:
                break
            # first occurrence of each port, in priority order
            _, first_s = np.unique(src[cand], return_index=True)
            _, first_r = np.unique(dst[cand], return_index=True)
            is_first_s = np.zeros(cand.size, bool)
            is_first_r = np.zeros(cand.size, bool)
            is_first_s[first_s] = True
            is_first_r[first_r] = True
            take = cand[is_first_s & is_first_r]
            r = np.minimum(avail_s[src[take]], avail_r[dst[take]])
            rates[take] = r
            # 'take' flows have unique src and dst among themselves
            avail_s[src[take]] -= r
            avail_r[dst[take]] -= r
            ordered = cand[~(is_first_s & is_first_r)]
        return rates
    up, dn = extra.up, extra.dn
    avail_x = extra.cap.copy() if avail_x is None else avail_x
    Lx = avail_x.shape[0]
    for _ in range(2 * (table.num_ports + Lx) + 2):
        if ordered.size == 0:
            break
        u, d = up[ordered], dn[ordered]
        ok = (avail_s[src[ordered]] > 0.0) & (avail_r[dst[ordered]] > 0.0)
        ok &= (u < 0) | (avail_x[np.maximum(u, 0)] > 0.0)
        ok &= (d < 0) | (avail_x[np.maximum(d, 0)] > 0.0)
        cand = ordered[ok]
        if cand.size == 0:
            break
        _, first_s = np.unique(src[cand], return_index=True)
        _, first_r = np.unique(dst[cand], return_index=True)
        # intra-leaf flows (no extra link) get unique pseudo-ids so the
        # first-toucher dedup never groups them
        fresh = Lx + np.arange(cand.size, dtype=np.int64)
        uu = np.where(up[cand] >= 0, up[cand], fresh)
        dd = np.where(dn[cand] >= 0, dn[cand], fresh)
        _, first_u = np.unique(uu, return_index=True)
        _, first_d = np.unique(dd, return_index=True)
        is_first = np.zeros((4, cand.size), bool)
        is_first[0, first_s] = True
        is_first[1, first_r] = True
        is_first[2, first_u] = True
        is_first[3, first_d] = True
        takeable = is_first.all(axis=0)
        take = cand[takeable]
        r = np.minimum(avail_s[src[take]], avail_r[dst[take]])
        tu, td = up[take], dn[take]
        mu, md = tu >= 0, td >= 0
        r = np.minimum(r, np.where(mu, avail_x[np.maximum(tu, 0)],
                                   np.inf))
        r = np.minimum(r, np.where(md, avail_x[np.maximum(td, 0)],
                                   np.inf))
        rates[take] = r
        # 'take' flows have unique ports and links among themselves
        avail_s[src[take]] -= r
        avail_r[dst[take]] -= r
        avail_x[tu[mu]] -= r[mu]
        avail_x[td[md]] -= r[md]
        ordered = cand[~takeable]
    return rates


def coflow_flow_order(table: FlowTable, coflow_rank: np.ndarray) -> np.ndarray:
    """Flow order induced by a per-coflow rank (ties by flow id)."""
    return np.lexsort((np.arange(table.size.shape[0]),
                       coflow_rank[table.cid]))


def maxmin_waterfill(table: FlowTable, live: np.ndarray,
                     max_iter: int | None = None, *,
                     extra=None,
                     avail_s: np.ndarray | None = None,
                     avail_r: np.ndarray | None = None,
                     avail_x: np.ndarray | None = None) -> np.ndarray:
    """Exact bipartite max-min fair rates (progressive filling).

    Models the steady-state throughput of per-flow TCP fair sharing —
    the UC-TCP baseline (§6.1). With `extra` (`fabric.topology
    .ExtraLinks`) the filling also levels across leaf uplinks/downlinks
    — the leaf-spine allocation the in-network papers assume, and the
    loop `kernels/maxmin.py` accelerates on the jitted plane. Residual
    `avail_*` vectors (updated in place) let Saath's `wc_fill="maxmin"`
    water-fill only the leftover capacity of a partly-admitted fabric;
    by default the walk starts from the full port bandwidth, bitwise
    the pre-refactor behavior when `extra is None`.
    """
    F = table.size.shape[0]
    rates = np.zeros(F)
    frozen = ~live
    avail_s = table.bw_send.copy() if avail_s is None else avail_s
    avail_r = table.bw_recv.copy() if avail_r is None else avail_r
    if extra is not None:
        avail_x = extra.cap.copy() if avail_x is None else avail_x
        Lx = avail_x.shape[0]
        up, dn = extra.up, extra.dn
        up_ok, dn_ok = up >= 0, dn >= 0
    else:
        Lx = 0
    it = 0
    limit = max_iter or 2 * (table.num_ports + Lx) + 2
    while not frozen.all() and it < limit:
        it += 1
        act = ~frozen
        cnt_s = np.bincount(table.src[act], minlength=table.num_ports)
        cnt_r = np.bincount(table.dst[act], minlength=table.num_ports)
        with np.errstate(divide="ignore", invalid="ignore"):
            lvl_s = np.where(cnt_s > 0, avail_s / np.maximum(cnt_s, 1), np.inf)
            lvl_r = np.where(cnt_r > 0, avail_r / np.maximum(cnt_r, 1), np.inf)
        lvl = min(lvl_s.min(), lvl_r.min())
        if extra is not None:
            cnt_x = (np.bincount(up[act & up_ok], minlength=Lx)
                     + np.bincount(dn[act & dn_ok], minlength=Lx))
            with np.errstate(divide="ignore", invalid="ignore"):
                lvl_x = np.where(cnt_x > 0,
                                 avail_x / np.maximum(cnt_x, 1), np.inf)
            lvl = min(lvl, lvl_x.min())
        if not np.isfinite(lvl):
            break
        # freeze flows incident to saturated ports (or links) at `lvl`
        sat_s = (lvl_s <= lvl + 1e-12) & (cnt_s > 0)
        sat_r = (lvl_r <= lvl + 1e-12) & (cnt_r > 0)
        hit = act & (sat_s[table.src] | sat_r[table.dst])
        if extra is not None:
            sat_x = (lvl_x <= lvl + 1e-12) & (cnt_x > 0)
            hit |= act & ((up_ok & sat_x[np.maximum(up, 0)])
                          | (dn_ok & sat_x[np.maximum(dn, 0)]))
        if not hit.any():
            break
        rates[hit] = lvl
        np.subtract.at(avail_s, table.src[hit], lvl)
        np.subtract.at(avail_r, table.dst[hit], lvl)
        avail_s = np.maximum(avail_s, 0.0, out=avail_s)
        avail_r = np.maximum(avail_r, 0.0, out=avail_r)
        if extra is not None:
            np.subtract.at(avail_x, up[hit & up_ok], lvl)
            np.subtract.at(avail_x, dn[hit & dn_ok], lvl)
            avail_x = np.maximum(avail_x, 0.0, out=avail_x)
        frozen = frozen | hit
    return rates
