"""Aalo (Chowdhury & Stoica, SIGCOMM'15) — the paper's main baseline.

Global coordinator assigns coflows to exponential priority queues by
TOTAL bytes sent; each port schedules its local flows strict-priority
across queues, FIFO (coflow arrival order) within a queue (§2.2).
"""
from __future__ import annotations

import numpy as np

from repro.core import queues
from repro.core.policies.base import Policy, greedy_flow_alloc
from repro.fabric.state import FlowTable


class Aalo(Policy):
    name = "aalo"

    def schedule(self, table: FlowTable, now: float) -> np.ndarray:
        live = table.flow_live()
        if not live.any():
            return np.zeros(table.size.shape[0])
        q = queues.aalo_queue(table.coflow_sent_total(), self.params)
        # flow order: (queue, coflow arrival, flow id)
        order = np.lexsort((np.arange(live.shape[0]),
                            table.arrival[table.cid], q[table.cid]))
        return greedy_flow_alloc(table, order, live,
                                 extra=self.fabric_binding(table))

    def progress_events(self, table: FlowTable, now: float,
                        rates: np.ndarray) -> float:
        """Earliest total-bytes queue-threshold crossing under `rates`."""
        R = np.bincount(table.cid, weights=rates,
                        minlength=table.num_coflows)
        total = table.coflow_sent_total()
        th = np.array(self.params.thresholds())
        q = queues.aalo_queue(total, self.params)
        nxt = th[q]  # Q_q^hi; inf in last queue
        with np.errstate(divide="ignore", invalid="ignore"):
            dt = np.where((R > 0) & np.isfinite(nxt) & table.active,
                          (nxt - total) / R, np.inf)
        dt = dt[dt > 1e-12]
        return now + float(dt.min()) if dt.size else float("inf")


class CoordinatedFifo(Policy):
    """Single global FIFO by coflow arrival (no queues) — the ordering D5's
    deadlines are derived from; also a baseline."""

    name = "fifo"

    def schedule(self, table: FlowTable, now: float) -> np.ndarray:
        live = table.flow_live()
        if not live.any():
            return np.zeros(table.size.shape[0])
        order = np.lexsort((np.arange(live.shape[0]),
                            table.arrival[table.cid]))
        return greedy_flow_alloc(table, order, live,
                                 extra=self.fabric_binding(table))
