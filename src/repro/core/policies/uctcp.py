"""UC-TCP: uncoordinated per-flow TCP fair sharing (§6.1) — every live
flow gets its bipartite max-min fair share; no queues, no coordination."""
from __future__ import annotations

import numpy as np

from repro.core.policies.base import Policy, maxmin_waterfill
from repro.fabric.state import FlowTable


class UCTCP(Policy):
    name = "uc-tcp"

    def schedule(self, table: FlowTable, now: float) -> np.ndarray:
        live = table.flow_live()
        if not live.any():
            return np.zeros(table.size.shape[0])
        return maxmin_waterfill(table, live,
                                extra=self.fabric_binding(table))
