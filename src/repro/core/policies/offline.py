"""Clairvoyant baselines (flow sizes known a-priori): SCF, SRTF, LWTF
(§2.4 / Fig. 3) and Varys' SEBF+MADD (§6.1 / Fig. 9)."""
from __future__ import annotations

import numpy as np

from repro.core.contention import contention
from repro.core.policies.base import (Policy, coflow_flow_order,
                                      greedy_flow_alloc)
from repro.fabric.state import FlowTable


def _port_remaining(table: FlowTable, live: np.ndarray):
    """(C,P) remaining bytes at sender / receiver ports."""
    rem = np.where(live, table.size - table.sent, 0.0)
    C, P = table.num_coflows, table.num_ports
    rem_s = np.zeros((C, P))
    rem_r = np.zeros((C, P))
    np.add.at(rem_s, (table.cid, table.src), rem)
    np.add.at(rem_r, (table.cid, table.dst), rem)
    return rem_s, rem_r


def _rank_rates(table: FlowTable, live: np.ndarray, key: np.ndarray,
                extra=None):
    rank = np.argsort(np.argsort(key, kind="stable"), kind="stable")
    order = coflow_flow_order(table, rank)
    return greedy_flow_alloc(table, order, live, extra=extra)


class SCF(Policy):
    """Shortest-CoFlow-First by static total size."""

    name = "scf"
    clairvoyant = True

    def schedule(self, table: FlowTable, now: float) -> np.ndarray:
        live = table.flow_live()
        if not live.any():
            return np.zeros(table.size.shape[0])
        total = np.bincount(table.cid, weights=table.size,
                            minlength=table.num_coflows)
        key = np.where(table.active, total, np.inf)
        return _rank_rates(table, live, key,
                           extra=self.fabric_binding(table))


class SRTF(Policy):
    """Shortest-Remaining-Time-First by total remaining bytes."""

    name = "srtf"
    clairvoyant = True

    def schedule(self, table: FlowTable, now: float) -> np.ndarray:
        live = table.flow_live()
        if not live.any():
            return np.zeros(table.size.shape[0])
        rem = np.bincount(table.cid, weights=np.where(live, table.size -
                                                      table.sent, 0.0),
                          minlength=table.num_coflows)
        key = np.where(table.active, rem, np.inf)
        return _rank_rates(table, live, key,
                           extra=self.fabric_binding(table))


class LWTF(Policy):
    """Least-Waiting-Time-First: order by t_c * k_c (§2.4) where t_c is the
    remaining bottleneck time and k_c the current contention."""

    name = "lwtf"
    clairvoyant = True

    def schedule(self, table: FlowTable, now: float) -> np.ndarray:
        live = table.flow_live()
        if not live.any():
            return np.zeros(table.size.shape[0])
        rem_s, rem_r = _port_remaining(table, live)
        t_c = np.maximum(rem_s.max(1), rem_r.max(1)) / self.params.port_bw
        A_s, A_r = table.incidence(live)
        k = contention(A_s, A_r, table.active)
        key = np.where(table.active, t_c * np.maximum(k, 1), np.inf)
        return _rank_rates(table, live, key,
                           extra=self.fabric_binding(table))


class VarysSEBF(Policy):
    """Varys: Smallest-Effective-Bottleneck-First ordering + MADD rates
    (all flows of a coflow finish together at its bottleneck time), then
    greedy backfill for work conservation."""

    name = "varys-sebf"
    clairvoyant = True

    def schedule(self, table: FlowTable, now: float) -> np.ndarray:
        live = table.flow_live()
        rates = np.zeros(table.size.shape[0])
        if not live.any():
            return rates
        rem_s, rem_r = _port_remaining(table, live)
        gamma = np.maximum(rem_s.max(1), rem_r.max(1)) / self.params.port_bw
        order = np.argsort(np.where(table.active, gamma, np.inf),
                           kind="stable")
        avail_s = table.bw_send.copy()
        avail_r = table.bw_recv.copy()
        extra = self.fabric_binding(table)
        avail_x = rem_x = None
        if extra is not None:
            # (C, Lx) remaining bytes crossing each extra link
            avail_x = extra.cap.copy()
            rem = np.where(live, table.size - table.sent, 0.0)
            rem_x = np.zeros((table.num_coflows, avail_x.shape[0]))
            m = extra.up >= 0
            np.add.at(rem_x, (table.cid[m], extra.up[m]), rem[m])
            np.add.at(rem_x, (table.cid[m], extra.dn[m]), rem[m])
        rem_f = np.where(live, table.size - table.sent, 0.0)
        for c in order:
            if not table.active[c] or gamma[c] <= 0:
                continue
            ps = rem_s[c] > 0
            pr = rem_r[c] > 0
            # effective bottleneck against CURRENT available bandwidth
            with np.errstate(divide="ignore"):
                g = max(
                    (rem_s[c][ps] / np.maximum(avail_s[ps], 1e-12)).max()
                    if ps.any() else 0.0,
                    (rem_r[c][pr] / np.maximum(avail_r[pr], 1e-12)).max()
                    if pr.any() else 0.0)
            if extra is not None:
                px = rem_x[c] > 0
                if px.any():
                    g = max(g, (rem_x[c][px]
                                / np.maximum(avail_x[px], 1e-12)).max())
            if g <= 0 or not np.isfinite(g):
                continue
            lo, hi = table.flow_lo[c], table.flow_hi[c]
            fr = rem_f[lo:hi] / g  # MADD: finish together at time g
            rates[lo:hi] = fr
            np.subtract.at(avail_s, table.src[lo:hi], fr)
            np.subtract.at(avail_r, table.dst[lo:hi], fr)
            avail_s = np.maximum(avail_s, 0.0)
            avail_r = np.maximum(avail_r, 0.0)
            if extra is not None:
                mu = extra.up[lo:hi] >= 0
                np.subtract.at(avail_x, extra.up[lo:hi][mu], fr[mu])
                np.subtract.at(avail_x, extra.dn[lo:hi][mu], fr[mu])
                avail_x = np.maximum(avail_x, 0.0)
        # work-conserving backfill in the same order (only flows that did not
        # get a MADD rate; greedy fill of leftover bandwidth)
        bf_order = np.concatenate(
            [np.arange(table.flow_lo[c], table.flow_hi[c])
             for c in order if table.active[c]]) if order.size else order
        if bf_order.size:
            greedy_flow_alloc(table, bf_order, live & (rates <= 0),
                              avail_s, avail_r, rates,
                              extra=extra, avail_x=avail_x)
        return rates
