"""Priority-queue threshold logic (Aalo-style exponential queues).

Aalo assigns a coflow to queue q when its TOTAL bytes sent lies in
[Q_{q-1}^hi, Q_q^hi).  Saath (Eq. 1) divides the threshold by the flow
count N_c and compares against the MAX bytes sent by any single flow,
which is the per-flow-threshold fast transition.
"""
from __future__ import annotations

import numpy as np

from repro.core.params import SchedulerParams


# Relative crossing tolerance: the event-driven simulator lands a flow
# EXACTLY on its queue threshold (the crossing instant is an event), so
# an exact `value < Q_q^hi` comparison is a coin flip on the last float
# ulp — and the f64 reference and the f32 jitted coordinator can flip
# differently, forking otherwise-identical replays. Counting a value
# within this relative band below the threshold as crossed (consistently
# here and in jax_coordinator._queue_of) makes the decision deterministic
# across precisions; the transition moves <= 0.001% early.
CROSS_EPS = 1e-5


def queue_of(value: np.ndarray, params: SchedulerParams) -> np.ndarray:
    """Queue index for a 'progress' value against exponential thresholds.

    q = smallest q with value < Q_q^hi; values below Q_0^hi land in queue 0.

    Implemented as a searchsorted over ``params.thresholds()`` — the SAME
    rule (same array, same side) as ``jax_coordinator._queue_of`` — so the
    two planes cannot disagree near an E^k boundary. The previous
    ``floor(log(ratio)/log(E))`` form could land one queue off from the
    threshold array at exact powers of E (log rounding), despite
    CROSS_EPS.
    """
    th = np.asarray(params.thresholds(), dtype=np.float64)
    value = np.asarray(value, dtype=np.float64) * (1.0 + CROSS_EPS)
    q = np.searchsorted(th, value, side="right")
    return np.clip(q, 0, params.num_queues - 1).astype(np.int32)


def aalo_queue(total_sent: np.ndarray, params: SchedulerParams) -> np.ndarray:
    """Aalo: queue from TOTAL bytes sent by the coflow."""
    return queue_of(total_sent, params)


def saath_queue(max_flow_sent: np.ndarray, width: np.ndarray,
                params: SchedulerParams) -> np.ndarray:
    """Saath Eq. 1: per-flow thresholds — compare m_c against Q_q^hi/N_c,
    i.e. m_c * N_c against Q_q^hi."""
    return queue_of(np.asarray(max_flow_sent) * np.asarray(width), params)


def min_queue_residence(queue: np.ndarray, width: np.ndarray,
                        params: SchedulerParams) -> np.ndarray:
    """t in the deadline formula d*C_q*t (§4.2 D5): the minimum time a
    coflow must spend in queue q — the per-flow span of the queue sent at
    full port rate."""
    th = params.thresholds()
    lo = np.array([0.0] + th[:-1])
    hi = np.array(th)
    # last queue is unbounded; use one growth step beyond its lower bound
    hi[-1] = lo[-1] * params.growth if len(th) > 1 else params.start_threshold
    span = (hi - lo)[queue]
    return span / (np.maximum(width, 1) * params.port_bw)
