"""Sampling-based coflow size learning (non-clairvoyant mode).

Pilot-flow estimation in the style of the authors' follow-up sampling
paper (arxiv 2108.11255): a small, deterministic subset of each
coflow's flows — the *pilots* — is observed, and once pilots finish
their exact sizes are known (bytes delivered == size). The mean
finished-pilot size becomes the coflow's per-flow size estimate; the
§4.3 SRTF re-queue then runs off this estimate instead of the
clairvoyant finished-flow median. Before the first pilot completes
there is no estimate, and the scheduler falls back to what it can
observe: bytes sent so far (the plain Eq. 1 placement).

The pilot layout rule is shared by BOTH planes (and by
`traces.batch.pack_row`, which bakes it into the slab as a mask):

    K_c = min(width_c, max(1, ceil(pilot_frac * width_c)))
    pilots of coflow c = its first K_c flows in table/slab layout order

Layout order is the submission order inside the contiguous
[flow_lo_c, flow_hi_c) segment, identical in the numpy FlowTable and
the packed TraceBatch row, so the two planes tag the same flows.
"""
from __future__ import annotations

import numpy as np

from repro.core.params import SchedulerParams


def pilot_count(width: np.ndarray, pilot_frac: float) -> np.ndarray:
    """K_c per coflow: at least one pilot, at most every flow."""
    w = np.asarray(width, np.int64)
    k = np.ceil(pilot_frac * w).astype(np.int64)
    return np.minimum(np.maximum(k, 1), np.maximum(w, 1))


def pilot_mask(cid: np.ndarray, flow_lo: np.ndarray, width: np.ndarray,
               pilot_frac: float) -> np.ndarray:
    """Bool mask over the flow axis: the first K_c flows of each coflow
    (in layout order) are pilots. `flow_lo`/`width` are per-coflow."""
    cid = np.asarray(cid, np.int64)
    pos = np.arange(cid.size, dtype=np.int64) - np.asarray(flow_lo)[cid]
    return pos < pilot_count(width, pilot_frac)[cid]


class SizeEstimator:
    """Numpy-plane size estimator (stateless recompute per call).

    `estimates(table)` returns per-coflow arrays
    ``(est_flow, est_total, learned)``:

    * ``learned[c]`` — at least one pilot of c has finished;
    * ``est_flow[c]`` — estimated max-flow bytes: the mean finished
      pilot size when learned, else the max bytes SENT by any flow of
      c so far (the observable fallback);
    * ``est_total[c]`` — estimated total bytes: ``est_flow * width``
      when learned, else total bytes sent so far.

    The estimate is a pure function of the flow table, so session
    rebuilds / epoch rebases need no estimator state migration.
    """

    def __init__(self, params: SchedulerParams):
        self.params = params

    def pilot_mask(self, table) -> np.ndarray:
        return pilot_mask(table.cid, table.flow_lo, table.width,
                          self.params.pilot_frac)

    def estimates(self, table):
        C = table.num_coflows
        pm = self.pilot_mask(table)
        pdone = pm & table.done
        n = np.bincount(table.cid[pdone], minlength=C).astype(np.float64)
        s = np.bincount(table.cid[pdone], weights=table.size[pdone],
                        minlength=C)
        learned = n > 0
        f_hat = s / np.maximum(n, 1.0)
        sent_max = np.zeros(C)
        np.maximum.at(sent_max, table.cid, table.sent)
        sent_tot = np.bincount(table.cid, weights=table.sent, minlength=C)
        est_flow = np.where(learned, f_hat, sent_max)
        est_total = np.where(learned, f_hat * np.maximum(table.width, 1),
                             sent_tot)
        return est_flow, est_total, learned
