"""Jitted Saath coordinator — the in-framework scheduler.

The numpy Saath in ``core.policies.saath`` is the trace-replay reference;
this module is the same Fig. 7 algorithm vectorized over fixed-size padded
arrays so one coordinator tick is a single XLA computation (with the LCoF
contention as the ``kernels.contention`` Pallas kernel on TPU). It is used

* by the framework plane: between train steps the coordinator re-plans
  the issue order of collective coflows (gradient buckets, MoE a2a waves,
  checkpoint uploads, KV migrations) — ``runtime.coflow_bridge``;
* by ``benchmarks/table2_coordinator_latency.py`` to reproduce the
  paper's coordinator-cost table at 512-port x 4k-coflow scale.

Granularity: one row per COFLOW with per-port live-flow counts
(cnt_s/cnt_r) drives queue assignment, LCoF ordering, deadlines and the
all-or-none admission. Work conservation runs at FLOW granularity when
the caller supplies a ``FlowView`` (the reference's ``greedy_flow_alloc``
semantics: a strict subset of a missed coflow's flows can be rescued);
without one it falls back to the coflow-granular equal-rate fill, which
is the faithful mapping for collective coflows where a partial issue is
meaningless (DESIGN.md §2). The §4.3 cluster-dynamics re-queue is driven
by the caller-computed finished-flow median estimate (``batch.mixed`` /
``batch.m_dyn``) and gated by ``DynCoordParams.requeue``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.queues import CROSS_EPS
from repro.kernels import ops

BIG = jnp.float32(1e30)


class CoordParams(NamedTuple):
    """Static coordinator parameters (see core.params.SchedulerParams)."""
    thresholds: tuple          # (K,) Q_q^hi, last = +inf
    deadline_factor: float = 2.0
    min_rate_frac: float = 1e-3
    bw_ref: float = 1.0        # reference port bandwidth for t_min
    growth: float = 0.0        # E; 0 = infer from thresholds (legacy)
    # mechanism switches (traced 0/1 scalars in DynCoordParams, so a
    # parameter sweep can vmap over them instead of recompiling)
    work_conservation: bool = True   # D4 leftover-bandwidth fill
    dynamics_requeue: bool = True    # §4.3 median-based re-queue
    lcof: bool = True                # LCoF contention ordering (Fig. 10)
    per_flow_threshold: bool = True  # Eq. 1 vs Aalo total-bytes queues
    clairvoyant: bool = True         # False = pilot-sampling estimates

    @staticmethod
    def from_params(p) -> "CoordParams":
        return CoordParams(
            tuple(p.thresholds()), p.deadline_factor,
            p.min_rate_frac, p.port_bw, p.growth,
            work_conservation=getattr(p, "work_conservation", True),
            dynamics_requeue=getattr(p, "dynamics_requeue", True),
            clairvoyant=getattr(p, "clairvoyant", True))


def _queue_spans(thresholds, growth: float = 0.0) -> list:
    """Per-queue residence spans (matches core.queues.min_queue_residence):
    span_q = Q_q^hi - Q_q^lo; the unbounded last queue uses one growth
    step beyond its lower bound. `growth` must be passed explicitly for
    K == 2, where thresholds[1] is +inf and cannot be used to infer E."""
    K = len(thresholds)
    los = (0.0,) + tuple(thresholds[:-1])
    if not growth:
        growth = (thresholds[1] / thresholds[0]) if K > 2 else 2.0
    spans = [h - l for h, l in zip(thresholds, los)]
    spans[K - 1] = (los[K - 1] * growth - los[K - 1]) if K > 1 \
        else thresholds[0]
    return spans


class DynCoordParams(NamedTuple):
    """Coordinator parameters as traced arrays.

    Same knobs as CoordParams but every leaf is a jax array, so a
    parameter sweep can be vmapped (stack a leading axis on each leaf)
    instead of recompiling per setting. K = len(thresholds) stays a
    static shape. Built host-side: spans are precomputed with plain
    python so the traced tick never sees the +inf arithmetic.
    """
    thresholds: jax.Array       # (K,) f32, last = +inf
    span: jax.Array             # (K,) f32 queue residence spans
    deadline_factor: jax.Array  # () f32
    min_rate_frac: jax.Array    # () f32
    bw_ref: jax.Array           # () f32
    wc: jax.Array               # () f32 1 = work conservation on
    requeue: jax.Array          # () f32 1 = §4.3 dynamics re-queue on
    lcof: jax.Array             # () f32 1 = LCoF ordering (0 = FIFO-in-q)
    per_flow: jax.Array         # () f32 1 = Eq. 1 per-flow thresholds
    # Non-clairvoyant sampling leaf. None = clairvoyance compiled OUT
    # (an empty pytree subtree — jaxprs bitwise-unchanged from before
    # the mechanism existed). An f32 scalar = vmappable mode switch:
    # 1 = clairvoyant (§4.3 exact-median re-queue), 0 = learned
    # (pilot-sampling re-queue via CoflowBatch.s_mixed/s_m).
    clairvoyant: jax.Array | None = None

    @staticmethod
    def from_params(p) -> "DynCoordParams":
        return DynCoordParams.from_cp(CoordParams.from_params(p))

    @staticmethod
    def from_cp(cp: CoordParams) -> "DynCoordParams":
        return DynCoordParams(
            jnp.asarray(cp.thresholds, jnp.float32),
            jnp.asarray(_queue_spans(cp.thresholds, cp.growth),
                        jnp.float32),
            jnp.float32(cp.deadline_factor),
            jnp.float32(cp.min_rate_frac),
            jnp.float32(cp.bw_ref),
            jnp.float32(1.0 if cp.work_conservation else 0.0),
            jnp.float32(1.0 if cp.dynamics_requeue else 0.0),
            jnp.float32(1.0 if cp.lcof else 0.0),
            jnp.float32(1.0 if cp.per_flow_threshold else 0.0),
            None if cp.clairvoyant else jnp.float32(0.0))


class CoordState(NamedTuple):
    queue: jax.Array     # (C,) int32, -1 = unseen
    deadline: jax.Array  # (C,) f32
    running: jax.Array   # (C,) bool — admitted in previous tick


def init_state(C: int) -> CoordState:
    return CoordState(jnp.full((C,), -1, jnp.int32),
                      jnp.full((C,), jnp.inf, jnp.float32),
                      jnp.zeros((C,), bool))


class CoflowBatch(NamedTuple):
    """One coordinator tick's view of the fabric (padded to C, P)."""
    active: jax.Array    # (C,) bool
    arrival: jax.Array   # (C,) int32 arrival RANK (host-computed, exact
    #                      FIFO order — float arrivals may collide in f32)
    m: jax.Array         # (C,) f32  max bytes sent by any flow (Eq. 1)
    width: jax.Array     # (C,) int32 flow count N_c
    cnt_s: jax.Array     # (C,P) f32 live-flow counts at sender ports
    cnt_r: jax.Array     # (C,P) f32 live-flow counts at receiver ports
    bw_s: jax.Array      # (P,) f32
    bw_r: jax.Array      # (P,) f32
    # optional refinements (None = mechanism unavailable this tick):
    total: jax.Array | None = None  # (C,) f32 total bytes sent (Aalo
    #                      queues for the per_flow_threshold=0 ablation)
    mixed: jax.Array | None = None  # (C,) bool — has BOTH finished and
    #                      live flows (§4.3 re-queue candidates)
    m_dyn: jax.Array | None = None  # (C,) f32 estimated remaining
    #                      length m_hat from the finished-flow median
    # leaf-spine fabric (DESIGN.md §11; None = big switch, the link
    # machinery is compiled out): per-(coflow, extra-link) live counts
    # and link capacities, uplinks stacked before downlinks (Lx = 2*Lf)
    cnt_x: jax.Array | None = None  # (C, Lx) f32
    bw_x: jax.Array | None = None   # (Lx,) f32
    # non-clairvoyant sampling (None = compiled out): pilot-learned
    # re-queue candidates and their estimated remaining length
    s_mixed: jax.Array | None = None  # (C,) bool — >=1 finished pilot
    #                      AND >=1 live flow (learned-mode §4.3)
    s_m: jax.Array | None = None    # (C,) f32 m_hat from the mean
    #                      finished-pilot size estimate


class FlowView(NamedTuple):
    """Per-flow companion to CoflowBatch for flow-granular work
    conservation. Flows are stored contiguous per coflow (the host
    layout shared with traces.batch), so a flow's priority inside the
    missed list is just (coflow priority, flow index) — no per-tick
    gather tables."""
    cid: jax.Array      # (F,) int32 owning coflow
    src: jax.Array      # (F,) int32 sender port
    dst: jax.Array      # (F,) int32 receiver port
    live: jax.Array     # (F,) bool
    # leaf-spine link ids (None = big switch): LOCAL leaf index in
    # [0, Lf], with Lf the "touches no shared link" sentinel — exactly
    # the TraceBatch.link_up/link_dn encoding
    up: jax.Array | None = None   # (F,) int32
    dn: jax.Array | None = None   # (F,) int32


def _queue_of(value: jax.Array, th: jax.Array) -> jax.Array:
    """Smallest q with value < Q_q^hi (th sorted, th[-1] = +inf).
    Applies core.queues.CROSS_EPS so exact-on-threshold landings (every
    crossing event lands there) decide identically to the f64 reference.
    """
    return jnp.searchsorted(th, value * (1.0 + CROSS_EPS),
                            side="right").astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("cp", "kernel", "wc_fill"))
def schedule_tick(state: CoordState, batch: CoflowBatch, now: jax.Array,
                  *, cp: CoordParams, kernel: str | None = None,
                  flows: FlowView | None = None,
                  wc_fill: str = "greedy"):
    """One Fig. 7 coordinator tick. Returns (new_state, out) with
    per-coflow equal rates (MADD), admission mask, queue, contention, and
    (when a FlowView is supplied) per-flow work-conservation rates."""
    return tick_core(state, batch, now, DynCoordParams.from_cp(cp),
                     kernel=kernel, flows=flows, wc_fill=wc_fill)


def tick_core(state: CoordState, batch: CoflowBatch, now: jax.Array,
              dp: DynCoordParams, *, kernel: str | None = None,
              flows: FlowView | None = None, wc_fill: str = "greedy"):
    """The Fig. 7 tick with fully traced parameters (un-jitted; callers
    embed it in their own jit/scan/vmap — fabric.jax_engine scans it)."""
    th = dp.thresholds
    C, P = batch.cnt_s.shape
    act = batch.active

    # D3: per-flow thresholds (Eq. 1) — compare m_c * N_c against Q_q^hi;
    # the Fig. 10 A/N ablation (per_flow=0) uses Aalo total-bytes queues
    qval = batch.m * batch.width.astype(jnp.float32)
    if batch.total is not None:
        qval = jnp.where(dp.per_flow > 0, qval, batch.total)
    q = _queue_of(qval, th)
    # §4.3 cluster dynamics: a coflow with both finished and live flows
    # re-queues by its estimated remaining length (the caller-computed
    # finished-flow-median m_hat, Eq. 1 form) — approximate SRTF that can
    # move a coflow back UP the queues, matching Saath._assign_queues.
    if batch.mixed is not None:
        q_dyn = _queue_of(batch.m_dyn * batch.width.astype(jnp.float32),
                          th)
        use_dyn = (dp.requeue > 0) & batch.mixed & act
        if dp.clairvoyant is not None:
            # mixed-mode dispatch: only clairvoyant rows may read the
            # exact-size median estimate
            use_dyn = use_dyn & (dp.clairvoyant > 0)
        q = jnp.where(use_dyn, q_dyn, q)
    if batch.s_mixed is not None:
        # learned-mode §4.3: re-queue from the pilot-sampling estimate.
        # Compiled in only when some row runs non-clairvoyant; the
        # clairvoyant gate keeps known-size rows bit-identical inside a
        # mixed vmap/stacked dispatch.
        q_smp = _queue_of(batch.s_m * batch.width.astype(jnp.float32), th)
        cl = (dp.clairvoyant if dp.clairvoyant is not None
              else jnp.float32(1.0))
        q = jnp.where((cl <= 0) & (dp.requeue > 0) & batch.s_mixed & act,
                      q_smp, q)
    q = jnp.where(act, q, jnp.maximum(state.queue, 0))

    # D5: FIFO-derived deadlines, refreshed on queue entry (spans are
    # precomputed host-side in DynCoordParams, matching
    # core.queues.min_queue_residence).
    entered = act & (q != state.queue)
    K = th.shape[0]
    cq = jnp.zeros((K,), jnp.float32).at[q].add(act.astype(jnp.float32))
    t_min = dp.span[q] / (jnp.maximum(batch.width, 1) * dp.bw_ref)
    deadline = jnp.where(
        entered, now + dp.deadline_factor * jnp.maximum(cq[q], 1.0) * t_min,
        state.deadline)
    expired = act & (now >= deadline)

    # LCoF contention (Pallas kernel on TPU)
    k = ops.contention((batch.cnt_s > 0).astype(jnp.float32),
                       (batch.cnt_r > 0).astype(jnp.float32),
                       act, force=kernel)

    # order: expired first (by deadline — a float lexsort operand, zero
    # for everyone else), then (queue, k, stability, arrival); coflows
    # with no live ports and inactive coflows last, so perm's first
    # `n_live` entries double as the admission processing list.
    # jnp.lexsort: last key is primary.
    hp = act & ((batch.cnt_s > 0).any(axis=1)
                | (batch.cnt_r > 0).any(axis=1))
    arr_rank = batch.arrival
    not_running = (~state.running).astype(jnp.int32)
    primary = jnp.where(~hp, 2, jnp.where(expired, 0, 1))
    dl_key = jnp.where(expired & hp, deadline, 0.0)
    # lcof=0 (Fig. 10 A/N): FIFO within queue — contention and stability
    # keys drop out, leaving (queue, arrival) exactly as the reference
    lc = dp.lcof > 0
    key_q = jnp.where(expired, 0, q)
    key_k = jnp.where(expired | ~lc, 0, k)
    key_st = jnp.where(expired | ~lc, 0, not_running)
    # arr_rank stays a live key for EXPIRED coflows too: exact f32
    # deadline ties (same tick, same queue, same width) must break by a
    # layout-independent total order — the final arange(C) tie-break is
    # the slab POSITION, which differs between an offline pack (cid
    # order) and a session slab (submission order), and would fork an
    # otherwise bitwise-identical incremental replay.
    perm = jnp.lexsort((jnp.arange(C), arr_rank, key_st, key_k, key_q,
                        dl_key, primary))

    # D1/D2: all-or-none admission with MADD equal rates, processed in
    # `perm` priority order. Only a coflow with live ports can change the
    # carry (a missed or port-less coflow leaves `avail` untouched), so
    # the sequential pass runs as a while_loop over the COMPACTED live
    # list: trip count = live coflows, not padded C. Results are
    # identical to a full scan over perm — skipped entries are no-ops —
    # and the fleet engine's per-tick cost drops with occupancy.
    min_rate = dp.min_rate_frac * dp.bw_ref
    cnt = jnp.concatenate([batch.cnt_s, batch.cnt_r], axis=1)   # (C, 2P)
    avail0 = jnp.concatenate([batch.bw_s, batch.bw_r])          # (2P,)
    if batch.cnt_x is not None:
        # leaf-spine: the MADD min also runs over the coflow's
        # uplink/downlink counts — same arithmetic, a wider concat
        cnt = jnp.concatenate([cnt, batch.cnt_x], axis=1)  # (C, 2P+Lx)
        avail0 = jnp.concatenate([avail0, batch.bw_x])
    has = cnt > 0
    inv = jnp.where(has, 1.0 / jnp.maximum(cnt, 1e-9), 0.0)
    bigm = jnp.where(has, 0.0, BIG)
    clist = perm                          # live coflows lead (see above)
    n_live = hp.sum().astype(jnp.int32)
    zC = jnp.zeros((C,), jnp.float32)

    def admit_body(s):
        k, avail, rate_, adm = s
        c = clist[k]
        r = (avail * inv[c] + bigm[c]).min()
        ok = (r >= min_rate) & (r < BIG)
        r = jnp.where(ok, r, 0.0)
        return (k + 1, avail - r * cnt[c], rate_.at[c].set(r),
                adm.at[c].set(ok))

    _, avail, rate, admitted = jax.lax.while_loop(
        lambda s: s[0] < n_live, admit_body,
        (jnp.int32(0), avail0, zC, jnp.zeros((C,), bool)))

    # D4 work conservation over the missed list (lines 18-23), gated by
    # dp.wc via the trip count (zero iterations when the switch is off).
    wc_on = dp.wc > 0
    if flows is None:
        # coflow-granular fallback: one equal rate across all live flows
        # of each missed coflow (the faithful collective-coflow mapping)
        def wc_body(s):
            j, avail_, wc = s
            c = clist[j]
            r = (avail_ * inv[c] + bigm[c]).min()
            ok = ~admitted[c] & (r > 0) & (r < BIG)
            r = jnp.where(ok, r, 0.0)
            return (j + 1, avail_ - r * cnt[c], wc.at[c].set(r))

        _, _, wc_rate = jax.lax.while_loop(
            lambda s: s[0] < jnp.where(wc_on, n_live, 0), wc_body,
            (jnp.int32(0), avail, zC))
        wc_flow = None
    else:
        # per-flow greedy fill, the reference's greedy_flow_alloc: live
        # flows of missed coflows, ordered by (coflow priority, flow
        # index) — exactly the reference's wc_order — each take
        # min(avail_src, avail_dst), so a strict SUBSET of a missed
        # coflow's flows can be rescued. One lexsort compacts the
        # candidates to the front; the while_loop then walks them
        # sequentially (trip count = candidate flows; zero when the wc
        # switch is off). Host-A/B-tested against round-based fills
        # with segmented scans, one-hot reductions and scatter-mins:
        # the compacted sequential walk wins on XLA CPU — the body is
        # two gathers + two scalar updates.
        wc_rate = zC
        avail_s, avail_r = avail[:P], avail[P:2 * P]
        missed_c = hp & ~admitted
        F = flows.src.shape[0]
        cand0 = flows.live & missed_c[flows.cid] & wc_on
        if wc_fill == "maxmin":
            # max-min fair water-filling over the leftover flows (the
            # in-network allocation family), via the shared
            # `kernels.ops.maxmin_rates` backend — Pallas on TPU (or
            # force='interpret'/'pallas' through `kernel`), jnp
            # progressive filling otherwise. Incidence rows stack ports
            # then uplinks/downlinks; the sentinel leaf id Lf one-hots
            # to a zero column, so intra-leaf flows see ports only.
            a_send = jax.nn.one_hot(flows.src, P, axis=0,
                                    dtype=jnp.float32)
            a_recv = jax.nn.one_hot(flows.dst, P, axis=0,
                                    dtype=jnp.float32)
            bw_s_ext, bw_r_ext = avail_s, avail_r
            if flows.up is not None:
                Lf = batch.cnt_x.shape[1] // 2
                a_send = jnp.concatenate(
                    [a_send, jax.nn.one_hot(flows.up, Lf, axis=0,
                                            dtype=jnp.float32)])
                a_recv = jnp.concatenate(
                    [a_recv, jax.nn.one_hot(flows.dn, Lf, axis=0,
                                            dtype=jnp.float32)])
                bw_s_ext = jnp.concatenate([avail_s, avail[2 * P:
                                                           2 * P + Lf]])
                bw_r_ext = jnp.concatenate([avail_r, avail[2 * P + Lf:]])
            wc_flow = ops.maxmin_rates(
                a_send, a_recv, cand0, bw_s_ext, bw_r_ext, force=kernel)
            wc_flow = jnp.where(cand0, wc_flow, 0.0)
        else:
            invp = jnp.argsort(perm)      # priority rank of each coflow
            # three separate sort keys (candidates first, coflow
            # priority, flow index) — a fused invp[cid]*F + i key would
            # overflow int32 near the advertised 4k x 256k scale
            flist = jnp.lexsort((jnp.arange(F), invp[flows.cid],
                                 (~cand0).astype(jnp.int32)))
            n_cand = cand0.sum().astype(jnp.int32)

            if flows.up is None:
                def wc_flow_body(s):
                    i, a_s, a_r, wcf = s
                    f = flist[i]
                    sp, dq = flows.src[f], flows.dst[f]
                    r = jnp.maximum(jnp.minimum(a_s[sp], a_r[dq]), 0.0)
                    return (i + 1, a_s.at[sp].add(-r),
                            a_r.at[dq].add(-r), wcf.at[f].set(r))

                _, _, _, wc_flow = jax.lax.while_loop(
                    lambda s: s[0] < n_cand, wc_flow_body,
                    (jnp.int32(0), avail_s, avail_r,
                     jnp.zeros((F,), jnp.float32)))
            else:
                # leaf-spine: the fill is also capped by the flow's
                # uplink/downlink residuals. Sentinel leaf id Lf
                # indexes a BIG extra slot, so intra-leaf flows are
                # never link-capped (and the slot absorbs their
                # subtracts harmlessly).
                Lf = batch.cnt_x.shape[1] // 2
                a_u0 = jnp.concatenate([avail[2 * P:2 * P + Lf],
                                        BIG[None]])
                a_d0 = jnp.concatenate([avail[2 * P + Lf:], BIG[None]])

                def wc_flow_body(s):
                    i, a_s, a_r, a_u, a_d, wcf = s
                    f = flist[i]
                    sp, dq = flows.src[f], flows.dst[f]
                    u, d = flows.up[f], flows.dn[f]
                    r = jnp.minimum(jnp.minimum(a_s[sp], a_r[dq]),
                                    jnp.minimum(a_u[u], a_d[d]))
                    r = jnp.maximum(r, 0.0)
                    return (i + 1, a_s.at[sp].add(-r),
                            a_r.at[dq].add(-r), a_u.at[u].add(-r),
                            a_d.at[d].add(-r), wcf.at[f].set(r))

                _, _, _, _, _, wc_flow = jax.lax.while_loop(
                    lambda s: s[0] < n_cand, wc_flow_body,
                    (jnp.int32(0), avail_s, avail_r, a_u0, a_d0,
                     jnp.zeros((F,), jnp.float32)))

    new_state = CoordState(queue=jnp.where(act, q, state.queue),
                           deadline=deadline, running=admitted)
    out = {"rate": rate, "wc_rate": wc_rate, "wc_flow": wc_flow,
           "admitted": admitted, "queue": q, "contention": k,
           "expired": expired, "order": perm}
    return new_state, out
