"""Jitted Saath coordinator — the in-framework scheduler.

The numpy Saath in ``core.policies.saath`` is the trace-replay reference;
this module is the same Fig. 7 algorithm vectorized over fixed-size padded
arrays so one coordinator tick is a single XLA computation (with the LCoF
contention as the ``kernels.contention`` Pallas kernel on TPU). It is used

* by the framework plane: between train steps the coordinator re-plans
  the issue order of collective coflows (gradient buckets, MoE a2a waves,
  checkpoint uploads, KV migrations) — ``runtime.coflow_bridge``;
* by ``benchmarks/table2_coordinator_latency.py`` to reproduce the
  paper's coordinator-cost table at 512-port x 4k-coflow scale.

Granularity: one row per COFLOW with per-port live-flow counts
(cnt_s/cnt_r), i.e. the all-or-none admission and the coflow-level work
conservation are exact; per-flow work conservation (rescuing a strict
subset of a missed coflow's flows) is the numpy reference's finer
behaviour — for collective coflows a partial issue is meaningless, so
the coflow granularity is the faithful TPU mapping (DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops

BIG = jnp.float32(1e30)


class CoordParams(NamedTuple):
    """Static coordinator parameters (see core.params.SchedulerParams)."""
    thresholds: tuple          # (K,) Q_q^hi, last = +inf
    deadline_factor: float = 2.0
    min_rate_frac: float = 1e-3
    bw_ref: float = 1.0        # reference port bandwidth for t_min

    @staticmethod
    def from_params(p) -> "CoordParams":
        return CoordParams(tuple(p.thresholds()), p.deadline_factor,
                           p.min_rate_frac, p.port_bw)


class CoordState(NamedTuple):
    queue: jax.Array     # (C,) int32, -1 = unseen
    deadline: jax.Array  # (C,) f32
    running: jax.Array   # (C,) bool — admitted in previous tick


def init_state(C: int) -> CoordState:
    return CoordState(jnp.full((C,), -1, jnp.int32),
                      jnp.full((C,), jnp.inf, jnp.float32),
                      jnp.zeros((C,), bool))


class CoflowBatch(NamedTuple):
    """One coordinator tick's view of the fabric (padded to C, P)."""
    active: jax.Array    # (C,) bool
    arrival: jax.Array   # (C,) int32 arrival RANK (host-computed, exact
    #                      FIFO order — float arrivals may collide in f32)
    m: jax.Array         # (C,) f32  max bytes sent by any flow (Eq. 1)
    width: jax.Array     # (C,) int32 flow count N_c
    cnt_s: jax.Array     # (C,P) f32 live-flow counts at sender ports
    cnt_r: jax.Array     # (C,P) f32 live-flow counts at receiver ports
    bw_s: jax.Array      # (P,) f32
    bw_r: jax.Array      # (P,) f32


def _queue_of(value: jax.Array, th: jax.Array) -> jax.Array:
    """Smallest q with value < Q_q^hi (th sorted, th[-1] = +inf)."""
    return jnp.searchsorted(th, value, side="right").astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("cp", "kernel"))
def schedule_tick(state: CoordState, batch: CoflowBatch, now: jax.Array,
                  *, cp: CoordParams,
                  kernel: str | None = None):
    """One Fig. 7 coordinator tick. Returns (new_state, out) where out has
    per-coflow equal rates (MADD), admission mask, queue, contention."""
    th = jnp.asarray(cp.thresholds, jnp.float32)
    C, P = batch.cnt_s.shape
    act = batch.active

    # D3: per-flow thresholds (Eq. 1) — compare m_c * N_c against Q_q^hi
    q = _queue_of(batch.m * batch.width.astype(jnp.float32), th)
    q = jnp.where(act, q, jnp.maximum(state.queue, 0))

    # D5: FIFO-derived deadlines, refreshed on queue entry. Spans are
    # static python (cp.thresholds is a static tuple); the last queue is
    # unbounded so its span uses one growth step beyond its lower bound
    # (matches core.queues.min_queue_residence).
    entered = act & (q != state.queue)
    K = len(cp.thresholds)
    cq = jnp.zeros((K,), jnp.float32).at[q].add(act.astype(jnp.float32))
    los = (0.0,) + cp.thresholds[:-1]
    growth = (cp.thresholds[1] / cp.thresholds[0]) if K > 1 else 2.0
    spans = [h - l for h, l in zip(cp.thresholds, los)]
    spans[K - 1] = (los[K - 1] * growth - los[K - 1]) if K > 1 \
        else cp.thresholds[0]
    span = jnp.asarray(spans, jnp.float32)
    t_min = span[q] / (jnp.maximum(batch.width, 1) * cp.bw_ref)
    deadline = jnp.where(
        entered, now + cp.deadline_factor * jnp.maximum(cq[q], 1.0) * t_min,
        state.deadline)
    expired = act & (now >= deadline)

    # LCoF contention (Pallas kernel on TPU)
    k = ops.contention((batch.cnt_s > 0).astype(jnp.float32),
                       (batch.cnt_r > 0).astype(jnp.float32),
                       act, force=kernel)

    # order: expired first (by deadline), then (queue, k, stability,
    # arrival); inactive last. jnp.lexsort: last key is primary.
    arr_rank = batch.arrival
    not_running = (~state.running).astype(jnp.int32)
    primary = jnp.where(~act, 2, jnp.where(expired, 0, 1))
    key_q = jnp.where(expired, 0, q)
    key_k = jnp.where(expired, 0, k)
    key_st = jnp.where(expired, 0, not_running)
    key_arr = jnp.where(expired,
                        jnp.argsort(jnp.argsort(deadline)), arr_rank)
    perm = jnp.lexsort((jnp.arange(C), key_arr, key_st, key_k, key_q,
                        primary))

    # D1/D2: all-or-none admission with MADD equal rates, in `perm` order
    min_rate = cp.min_rate_frac * cp.bw_ref

    def admit_step(carry, c):
        avail_s, avail_r = carry
        cs = batch.cnt_s[c]
        cr = batch.cnt_r[c]
        r = jnp.minimum(
            jnp.where(cs > 0, avail_s / jnp.maximum(cs, 1e-9), BIG).min(),
            jnp.where(cr > 0, avail_r / jnp.maximum(cr, 1e-9), BIG).min())
        has_ports = ((cs > 0).any() | (cr > 0).any()) & act[c]
        ok = has_ports & (r >= min_rate) & (r < BIG)
        r = jnp.where(ok, r, 0.0)
        return (avail_s - r * cs, avail_r - r * cr), (r, ok)

    (avail_s, avail_r), (r_perm, ok_perm) = jax.lax.scan(
        admit_step, (batch.bw_s, batch.bw_r), perm)
    rate = jnp.zeros((C,), jnp.float32).at[perm].set(r_perm)
    admitted = jnp.zeros((C,), bool).at[perm].set(ok_perm)

    # D4: coflow-granular work conservation over the missed list
    def wc_step(carry, c):
        avail_s, avail_r = carry
        cs = batch.cnt_s[c]
        cr = batch.cnt_r[c]
        r = jnp.minimum(
            jnp.where(cs > 0, avail_s / jnp.maximum(cs, 1e-9), BIG).min(),
            jnp.where(cr > 0, avail_r / jnp.maximum(cr, 1e-9), BIG).min())
        ok = act[c] & ~admitted[c] & (r > 0) & (r < BIG) \
            & ((cs > 0).any() | (cr > 0).any())
        r = jnp.where(ok, r, 0.0)
        return (avail_s - r * cs, avail_r - r * cr), r

    (_, _), wc_perm = jax.lax.scan(wc_step, (avail_s, avail_r), perm)
    wc_rate = jnp.zeros((C,), jnp.float32).at[perm].set(wc_perm)

    new_state = CoordState(queue=jnp.where(act, q, state.queue),
                           deadline=deadline, running=admitted)
    out = {"rate": rate, "wc_rate": wc_rate, "admitted": admitted,
           "queue": q, "contention": k, "expired": expired,
           "order": perm}
    return new_state, out
