"""Scheduler parameters shared by every policy (paper §6 defaults)."""
from __future__ import annotations

import dataclasses

MB = 1024.0 * 1024.0
GBPS = 1e9 / 8.0  # bytes/sec for a 1 Gbps port (paper's testbed NIC)


@dataclasses.dataclass(frozen=True)
class SchedulerParams:
    """Knobs from the paper (§6 'default parameters')."""

    num_queues: int = 10          # K
    start_threshold: float = 10 * MB  # S = Q_0^hi, bytes
    growth: float = 10.0          # E, exponential threshold factor
    delta: float = 8e-3           # δ, coordinator sync interval (seconds)
    deadline_factor: float = 2.0  # d, starvation deadline multiplier
    port_bw: float = GBPS         # B_p, bytes/sec per port (uniform default)
    min_rate_frac: float = 1e-3   # all-or-none admission floor (fraction of B)
    # D4 work conservation (per-flow greedy fill of leftover bandwidth)
    work_conservation: bool = True
    # §4.3 cluster-dynamics handling (SRTF re-queue from finished-flow median)
    dynamics_requeue: bool = True
    # Beyond-paper option: a second work-conservation round that raises the
    # equal rate of already-admitted coflows when all their ports have slack.
    wc_admitted_round: bool = False
    # Non-clairvoyant mode (arxiv 2108.11255): when False, exact flow
    # sizes are hidden from the scheduler; the §4.3 re-queue runs off a
    # pilot-flow size estimate instead of the finished-flow median, and
    # queue placement falls back to bytes-sent-so-far before the first
    # pilot completes.
    clairvoyant: bool = True
    # Fraction of a coflow's flows tagged as pilots (at least one).
    pilot_frac: float = 0.1

    def thresholds(self) -> list:
        """[Q_0^hi .. Q_{K-1}^hi]; Q_{K-1}^hi is +inf."""
        out = []
        t = self.start_threshold
        for q in range(self.num_queues):
            out.append(float("inf") if q == self.num_queues - 1 else t)
            t *= self.growth
        return out

    def with_mechanisms(self, mechanisms: "dict | None"
                        ) -> "SchedulerParams":
        """A copy with the mechanism switches that live ON the params
        (work_conservation / dynamics_requeue) overridden from a shared
        `repro.api.MECHANISM_KEYS`-style dict; lcof /
        per_flow_threshold are engine/policy arguments, not params
        fields, and are ignored here."""
        mech = dict(mechanisms or {})
        out = self
        if "dynamics_requeue" in mech:
            out = dataclasses.replace(
                out, dynamics_requeue=mech["dynamics_requeue"])
        if "work_conservation" in mech:
            out = dataclasses.replace(
                out, work_conservation=mech["work_conservation"])
        if "clairvoyant" in mech:
            out = dataclasses.replace(out, clairvoyant=mech["clairvoyant"])
        return out

    @property
    def min_rate(self) -> float:
        return self.port_bw * self.min_rate_frac
