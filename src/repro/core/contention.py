"""Coflow contention k_c (numpy reference; the Pallas kernel in
repro.kernels.contention is the TPU fast path and is tested against this).

k_c = number of OTHER active coflows that share at least one (sender or
receiver) port with coflow c — i.e. how many coflows scheduling c would
block (§2.4, §3 idea 3).
"""
from __future__ import annotations

import numpy as np


def contention(A_send: np.ndarray, A_recv: np.ndarray,
               active: np.ndarray) -> np.ndarray:
    """A_send/A_recv: (C, P) bool incidence. active: (C,) bool.

    Returns (C,) int32; inactive coflows get 0.
    """
    A_s = (A_send & active[:, None]).astype(np.float32)
    A_r = (A_recv & active[:, None]).astype(np.float32)
    share = A_s @ A_s.T + A_r @ A_r.T  # BLAS sgemm
    blocks = share > 0.5
    k = blocks.sum(axis=1) - blocks.diagonal()
    return np.where(active, k, 0).astype(np.int32)
