"""Coflow/flow model.

Object form (`Coflow`, `Flow`) is used for traces; the simulator flattens
everything into struct-of-arrays (`fabric.state.FlowTable`) for speed.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Flow:
    fid: int            # global flow id
    src: int            # sender port
    dst: int            # receiver port
    size: float         # bytes


@dataclasses.dataclass
class Coflow:
    cid: int
    arrival: float      # seconds
    flows: List[Flow]
    stage_deps: Optional[List[int]] = None  # DAG: cids this stage waits on

    @property
    def width(self) -> int:
        return len(self.flows)

    @property
    def total_bytes(self) -> float:
        return float(sum(f.size for f in self.flows))

    @property
    def sender_ports(self) -> np.ndarray:
        return np.unique([f.src for f in self.flows])

    @property
    def receiver_ports(self) -> np.ndarray:
        return np.unique([f.dst for f in self.flows])

    def bottleneck_bytes(self, num_ports: int) -> float:
        """Max per-port load (bytes) over senders and receivers (SEBF Γ)."""
        s = np.zeros(num_ports)
        r = np.zeros(num_ports)
        for f in self.flows:
            s[f.src] += f.size
            r[f.dst] += f.size
        return float(max(s.max(), r.max()))


@dataclasses.dataclass
class Trace:
    num_ports: int
    coflows: List[Coflow]

    @property
    def num_flows(self) -> int:
        return sum(c.width for c in self.coflows)

    def validate(self) -> None:
        seen = set()
        for c in self.coflows:
            assert c.cid not in seen, f"duplicate cid {c.cid}"
            seen.add(c.cid)
            assert c.arrival >= 0
            assert c.width >= 1
            for f in c.flows:
                assert 0 <= f.src < self.num_ports
                assert 0 <= f.dst < self.num_ports
                assert f.size > 0
