from repro.optim.optimizers import (adafactor, adamw, clip_by_global_norm,
                                    cosine_schedule, make_optimizer)

__all__ = ["adamw", "adafactor", "make_optimizer", "cosine_schedule",
           "clip_by_global_norm"]
