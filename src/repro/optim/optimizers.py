"""Functional optimizers (AdamW, Adafactor) + schedules + clipping.

State dtype is configurable: >100B configs default to bf16 first/second
moments so the optimizer state fits the per-chip HBM budget (see
DESIGN.md §5 and the dry-run memory analysis).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * (step + 1.0) / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable   # (grads, state, params, step) -> (params, state)


def adamw(lr_fn: Callable, b1=0.9, b2=0.95, eps=1e-8, wd=0.1,
          state_dtype=jnp.float32, clip=1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)  # noqa: E731
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, clip)
        t = jnp.asarray(step, jnp.float32) + 1.0
        lr = lr_fn(step)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            step_ = lr * ((m_new / bc1) /
                          (jnp.sqrt(v_new / bc2) + eps) + wd *
                          p.astype(jnp.float32))
            return ((p.astype(jnp.float32) - step_).astype(p.dtype),
                    m_new.astype(state_dtype), v_new.astype(state_dtype))

        flat_p, td = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        out = [upd(g, m, v, p) for g, m, v, p in
               zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = jax.tree.unflatten(td, [o[0] for o in out])
        new_m = jax.tree.unflatten(td, [o[1] for o in out])
        new_v = jax.tree.unflatten(td, [o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm}

    return Optimizer(init, update)


def adafactor(lr_fn: Callable, eps=1e-30, clip=1.0,
              state_dtype=jnp.float32) -> Optimizer:
    """Factored second moments for >=2D params (memory ~O(n+m) not O(nm))."""
    def init(params):
        def st(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], state_dtype),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        state_dtype)}
            return {"v": jnp.zeros(p.shape, state_dtype)}
        return {"f": jax.tree.map(st, params,
                                  is_leaf=lambda x: hasattr(x, "ndim"))}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, clip)
        t = jnp.asarray(step, jnp.float32) + 1.0
        beta = 1.0 - t ** -0.8
        lr = lr_fn(step)

        def upd(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if p.ndim >= 2:
                vr = beta * s["vr"].astype(jnp.float32) + \
                    (1 - beta) * g2.mean(-1)
                vc = beta * s["vc"].astype(jnp.float32) + \
                    (1 - beta) * g2.mean(-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :]
                    / jnp.maximum(vr.mean(-1, keepdims=True)[..., None],
                                  eps))
                new_s = {"vr": vr.astype(state_dtype),
                         "vc": vc.astype(state_dtype)}
            else:
                v = beta * s["v"].astype(jnp.float32) + (1 - beta) * g2
                denom = jnp.sqrt(v)
                new_s = {"v": v.astype(state_dtype)}
            stp = lr * gf / jnp.maximum(denom, 1e-12)
            return (p.astype(jnp.float32) - stp).astype(p.dtype), new_s

        leaves_p, td = jax.tree.flatten(params)
        leaves_g = jax.tree.leaves(grads)
        leaves_s = jax.tree.flatten(
            state["f"], is_leaf=lambda x: isinstance(x, dict) and (
                "vr" in x or "v" in x))[0]
        out = [upd(g, s, p) for g, s, p in zip(leaves_g, leaves_s,
                                               leaves_p)]
        new_p = jax.tree.unflatten(td, [o[0] for o in out])
        new_s = jax.tree.unflatten(td, [o[1] for o in out])
        return new_p, {"f": new_s}, {"grad_norm": gnorm}

    return Optimizer(init, update)


def make_optimizer(cfg, total_steps: int = 10000,
                   base_lr: float = 3e-4) -> Optimizer:
    lr = cosine_schedule(base_lr, warmup=min(500, total_steps // 10),
                         total=total_steps)
    sdt = jnp.bfloat16 if cfg.opt_state_dtype == "bfloat16" else jnp.float32
    if cfg.optimizer == "adafactor":
        return adafactor(lr, state_dtype=sdt)
    return adamw(lr, state_dtype=sdt)
