"""Sharded checkpointing with atomic commit, async save, and elastic
restore.

Layout: <dir>/step_<N>/ contains arrays.npz (flattened keystr -> array)
plus manifest.json (step, tree structure, shapes/dtypes, user metadata).
Writes go to a tmp dir first and are os.replace'd into place — a crash
mid-save never corrupts the latest checkpoint (restart-safety).

Elastic restore: arrays come back as host numpy; `restore(..., specs=,
mesh=)` re-places them under ANY mesh/sharding (the elastic-rescale
path: a 512-chip checkpoint restores onto 256 chips or onto a single
CPU). The manifest's tree structure must match; shapes are global so
resharding is just a device_put.

The paper's coordinator is stateless (§5 Implementation) and recomputes
deadlines on failover; our CheckpointManager mirrors that: the train
state is the only durable state, everything else is derived.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(kp): np.asarray(leaf)
            for kp, leaf in flat}


def save(directory: str, step: int, tree: Any, *,
         metadata: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_save_")
    try:
        arrays = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in arrays.items()})
        treedef = jax.tree_util.tree_structure(tree)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "keys": sorted(arrays),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as fh:
            json.dump(manifest, fh)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)   # atomic commit
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(directory: str, step: int, tree_like: Any, *,
            mesh=None, specs=None) -> Any:
    """Restore into the structure of `tree_like`. If mesh+specs given,
    leaves are device_put with those shardings (elastic reshard)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}

    flat = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    spec_flat = (jax.tree_util.tree_leaves(
        specs, is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
        if specs is not None else None)
    for i, (kp, leaf) in enumerate(flat[0]):
        key = jax.tree_util.keystr(kp)
        arr = arrays[key]
        if mesh is not None and spec_flat is not None:
            sh = jax.sharding.NamedSharding(mesh, spec_flat[i])
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(flat[1], leaves)


def read_metadata(directory: str, step: int) -> dict:
    path = os.path.join(directory, f"step_{step:08d}", "manifest.json")
    with open(path) as fh:
        return json.load(fh)


class CheckpointManager:
    """Periodic + async checkpointing with bounded retention."""

    def __init__(self, directory: str, *, every: int = 100, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.every = every
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree: Any, *, metadata=None,
                   force: bool = False):
        if not force and (self.every <= 0 or step % self.every != 0):
            return False
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot now
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._save_and_gc,
                args=(step, host_tree, metadata), daemon=True)
            self._thread.start()
        else:
            self._save_and_gc(step, host_tree, metadata)
        return True

    def _save_and_gc(self, step, tree, metadata):
        save(self.dir, step, tree, metadata=metadata)
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
