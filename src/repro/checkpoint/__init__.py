from repro.checkpoint.ckpt import (CheckpointManager, latest_step,
                                   read_metadata, restore, save)

__all__ = ["save", "restore", "latest_step", "read_metadata",
           "CheckpointManager"]
