"""Lint driver: discovery, suppression filtering, reporting.

Usage::

    python -m repro.analysis.lint [paths...]   # default: src tests

Exit status 1 if any finding survives suppression. Suppressions are
in-source comments::

    steps = int(np.asarray(steps).max())  # saath: lint-ok(host-pull-unaccounted): blocking advance must sync the step budget

The rule name is mandatory and must match the finding's rule; the
reason (after the colon) is mandatory too — a bare `lint-ok` is itself
reported (`bad-suppression`). A suppression on a `def` line covers the
whole function body. Cross-file contract rules
(`repro.analysis.contracts`) run once per invocation against the live
`repro` package sources regardless of the paths given.
"""
from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path
from typing import Dict, List, Tuple

from repro.analysis import contracts
from repro.analysis.rules import Finding, lint_module

__all__ = ["lint_paths", "lint_text", "main"]

_SUPPRESS = re.compile(
    r"#\s*saath:\s*lint-ok\(([a-z0-9-]+)\)(?::\s*(\S.*))?")


def _suppressions(src: str, path: str
                  ) -> Tuple[Dict[int, str], List[Finding], int]:
    """Map line -> suppressed rule. A suppression anywhere on a def's
    HEADER — a decorator line, the `def` line, or a continuation line
    of a multi-line signature — covers the def's whole span; one on a
    body line stays line-local. Returns (line map, bad-suppression
    findings, count of suppression comments)."""
    import ast

    lines = src.splitlines()
    # (header_lo, header_hi, end): header runs from the first
    # decorator through the last signature line (the line before the
    # body starts — or the def line itself for one-liners)
    spans: List[Tuple[int, int, int]] = []
    try:
        tree = ast.parse(src, filename=path)
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                header_lo = min([node.lineno]
                                + [d.lineno for d in node.decorator_list])
                header_hi = max(node.lineno,
                                node.body[0].lineno - 1)
                spans.append((header_lo, header_hi,
                              getattr(node, "end_lineno", node.lineno)))
    except SyntaxError:
        pass
    by_line: Dict[int, str] = {}
    bad: List[Finding] = []
    count = 0
    for i, line in enumerate(lines, 1):
        m = _SUPPRESS.search(line)
        if not m:
            continue
        count += 1
        rule, reason = m.group(1), m.group(2)
        if not reason:
            bad.append(Finding(
                "bad-suppression", path, i,
                f"lint-ok({rule}) without a reason — write "
                f"`# saath: lint-ok({rule}): <why>`"))
            continue
        targets = [i]
        # innermost def whose header contains this line wins
        best = None
        for lo, hi, end in spans:
            if lo <= i <= hi and (best is None or lo > best[0]):
                best = (lo, end)
        if best is not None:
            targets = list(range(best[0], best[1] + 1))
        for ln in targets:
            by_line[ln] = rule
    return by_line, bad, count


def lint_text(src: str, path: str = "<string>") -> List[Finding]:
    """Lint one source blob (module-local rules only) with suppression
    filtering applied — the unit the fixture tests drive."""
    findings = lint_module(path, src)
    by_line, bad, _ = _suppressions(src, path)
    kept = [f for f in findings if by_line.get(f.line) != f.rule]
    return kept + bad


def _discover(paths: List[str]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            files.extend(sorted(
                f for f in path.rglob("*.py")
                if "__pycache__" not in f.parts))
        elif path.suffix == ".py":
            files.append(path)
    return files


def lint_paths(paths: List[str], with_contracts: bool = True
               ) -> Tuple[List[Finding], int]:
    """Lint every .py under `paths`. Returns (findings, suppressions
    used across the sweep)."""
    findings: List[Finding] = []
    n_suppressed = 0
    for f in _discover(paths):
        src = f.read_text()
        module_findings = lint_module(str(f), src)
        by_line, bad, _ = _suppressions(src, str(f))
        survived = [x for x in module_findings
                    if by_line.get(x.line) != x.rule]
        n_suppressed += len(module_findings) - len(survived)
        findings.extend(survived)
        findings.extend(bad)
    if with_contracts:
        import repro
        src_root = Path(list(repro.__path__)[0]).resolve().parent
        findings.extend(contracts.check_contracts(src_root))
    findings.sort(key=lambda x: (x.path, x.line, x.rule))
    return findings, n_suppressed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="JAX trace-safety + repo-contract lint")
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files or directories (default: src tests)")
    ap.add_argument("--no-contracts", action="store_true",
                    help="skip the cross-file contract rules")
    args = ap.parse_args(argv)
    findings, n_suppressed = lint_paths(
        list(args.paths), with_contracts=not args.no_contracts)
    for f in findings:
        print(f)
    if n_suppressed:
        print(f"({n_suppressed} finding(s) suppressed via "
              f"`saath: lint-ok`)", file=sys.stderr)
    if findings:
        print(f"{len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("lint clean", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
