"""Recursive jaxpr traversal for the dispatch auditor.

A jitted entrypoint lowers to a closed jaxpr whose equations may hold
sub-jaxprs (pjit bodies, while/scan/cond branches, custom_jvp calls …)
inside `eqn.params`. The helpers here flatten that tree so the auditor
can ask global questions about an entrypoint's whole traced extent:

* `primitive_counts(jaxpr)` — histogram of primitive names, the drift
  signal recorded in ``analysis/dispatch_manifest.json``;
* `callback_primitives(jaxpr)` — occurrences of host-callback
  primitives (`pure_callback`, `debug_callback`, …): a non-empty list
  means the "hot loop never leaves the device" contract is broken;
* `f64_sites(jaxpr)` — equations producing float64 values, including
  `convert_element_type` casts: any hit means weak-type promotion is
  dragging the f32 slab to f64 (the drift class PR 4's epoch rebasing
  exists to avoid).
"""
from __future__ import annotations

from collections import Counter
from typing import Iterator, List

import jax.core as jax_core

# Host-callback primitive names across jax versions. Matched by name so
# the set survives primitive-object churn between releases.
CALLBACK_PRIMITIVES = frozenset({
    "pure_callback", "debug_callback", "callback", "io_callback",
    "host_callback_call", "outside_call",
})


def iter_eqns(jaxpr) -> Iterator:
    """Yield every equation in `jaxpr` and, recursively, in any
    sub-jaxpr reachable through equation params (pjit/scan/while/cond
    bodies, closed and open alike)."""
    if hasattr(jaxpr, "jaxpr"):  # ClosedJaxpr -> Jaxpr
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub)


def _sub_jaxprs(eqn) -> List:
    subs = []
    for val in eqn.params.values():
        subs.extend(_jaxprs_in(val))
    return subs


def _jaxprs_in(val) -> List:
    if isinstance(val, (jax_core.Jaxpr, jax_core.ClosedJaxpr)):
        return [val]
    if isinstance(val, (tuple, list)):
        out = []
        for item in val:
            out.extend(_jaxprs_in(item))
        return out
    return []


def primitive_counts(jaxpr) -> Counter:
    """Histogram of primitive names over the whole (recursive) jaxpr."""
    return Counter(eqn.primitive.name for eqn in iter_eqns(jaxpr))


def callback_primitives(jaxpr) -> List[str]:
    """Names of host-callback equations anywhere in the jaxpr."""
    return [eqn.primitive.name for eqn in iter_eqns(jaxpr)
            if eqn.primitive.name in CALLBACK_PRIMITIVES]


def _is_f64(aval) -> bool:
    dtype = getattr(aval, "dtype", None)
    return dtype is not None and str(dtype) == "float64"


def f64_sites(jaxpr) -> List[str]:
    """Human-readable descriptions of equations that PRODUCE float64:
    explicit f64 `convert_element_type` casts and any other primitive
    with an f64 output aval. Input avals are not reported on their own
    — flagging every consumer of one bad producer would bury the root
    site in noise."""
    sites = []
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name == "convert_element_type":
            new = eqn.params.get("new_dtype")
            if new is not None and str(new) == "float64":
                sites.append(f"{name} -> float64")
                continue
        if any(_is_f64(var.aval) for var in eqn.outvars):
            sites.append(f"{name} (f64 output)")
    return sites


def aval_signature(avals) -> List[str]:
    """Stable string form of a list of abstract values — the jit cache
    signature recorded in the manifest (shape/dtype changes here are
    exactly the changes that trigger fresh compiles)."""
    out = []
    for aval in avals:
        shape = getattr(aval, "shape", None)
        dtype = getattr(aval, "dtype", None)
        if shape is None and dtype is None:
            out.append(repr(aval))
        else:
            out.append(f"{dtype}{list(shape) if shape is not None else ''}")
    return out
