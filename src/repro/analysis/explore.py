"""Runtime interleaving explorer: a race detector for the serving
plane.

The static coherence checker (`repro.analysis.coherence`) proves the
protocol is FOLLOWED; this module probes that the protocol is
SUFFICIENT: it fuzzes deterministic schedules of pool API calls --
admit / release (row recycling) / submit / fleet and per-session
advance / poll / snapshot -- and replays each schedule under every
interesting dispatch configuration (async double-buffering on, 1..N
shards), comparing all host-visible observations against the blocking
1-shard oracle.  Bitwise parity across configurations is an
established pool property (PR 6), so ANY divergence -- a completion
seen earlier/later, a different CCT bit pattern, a snapshot reading a
stale mirror -- is a coherence race.

Observations are taken only at sync-point ops (poll / snapshot /
admit / release / submit returns); clocks and raw tick counters are
deliberately NOT observed, because the async fast path leaves them
stale between sync points by design.

Usage:
    python -m repro.analysis.explore                  # CI smoke
    python -m repro.analysis.explore --schedules 20 --ops 60 --seed 7
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional, Tuple

import numpy as np

from repro.core.coflow import Coflow, Flow
from repro.core.params import SchedulerParams

PORTS = 6
MAX_SESSIONS = 4
PARAMS = SchedulerParams(port_bw=1.0, delta=1e-2, start_threshold=4.0,
                         growth=4.0, num_queues=5)
# fleet-advance quanta: coarse enough to finish small coflows in a
# handful of ops, misaligned enough to exercise partial-tick carry
_DTS = (0.3, 0.7, 1.1)
_DTS_ONE = (0.5, 0.9)


def _coflows(seed: int, n: int, base: int = 0,
             spread: float = 2.0) -> List[Coflow]:
    rng = np.random.default_rng(seed)
    cfs, fid = [], 0
    for c in range(n):
        w = int(rng.integers(1, 5))
        flows = [Flow(fid + i, int(rng.integers(0, PORTS)),
                      int(rng.integers(0, PORTS)),
                      float(rng.uniform(1.0, 15.0)))
                 for i in range(w)]
        fid += w
        cfs.append(Coflow(base + c, float(rng.uniform(0.0, spread)),
                          flows))
    return cfs


# ---- schedule generation -------------------------------------------------


def make_schedule(seed: int, n_ops: int,
                  max_sessions: int = MAX_SESSIONS) -> List[tuple]:
    """A deterministic, always-valid op schedule.  Validity (admission
    cap, live-session targets) depends only on this shadow roster, so
    the same schedule replays against every pool configuration."""
    rng = np.random.default_rng(seed)
    ops: List[tuple] = []
    live: List[int] = []
    next_sid = 0

    def admit():
        nonlocal next_sid
        ops.append(("admit", next_sid))
        live.append(next_sid)
        next_sid += 1

    admit()
    ops.append(("submit", live[0], 3, int(rng.integers(1 << 16)), 0))
    cbase = 100
    while len(ops) < n_ops:
        r = rng.random()
        if r < 0.12 and len(live) < max_sessions:
            admit()
        elif r < 0.18 and len(live) > 1:
            # release a mid-life row so the next admit recycles it
            ops.append(("release",
                        live.pop(int(rng.integers(len(live))))))
        elif r < 0.38:
            sid = live[int(rng.integers(len(live)))]
            ops.append(("submit", sid, int(rng.integers(1, 4)),
                        int(rng.integers(1 << 16)), cbase))
            cbase += 100
        elif r < 0.60:
            ops.append(("advance",
                        float(_DTS[int(rng.integers(len(_DTS)))])))
        elif r < 0.70:
            sid = live[int(rng.integers(len(live)))]
            ops.append(("advance_one", sid,
                        float(_DTS_ONE[int(rng.integers(2))])))
        elif r < 0.84:
            ops.append(("poll",))
        elif r < 0.93:
            ops.append(("poll_one",
                        live[int(rng.integers(len(live)))]))
        else:
            ops.append(("snapshot",
                        live[int(rng.integers(len(live)))]))
    return ops


# ---- schedule execution --------------------------------------------------


def _norm(x):
    """Hashable, exactly-comparable form of an observation value."""
    if isinstance(x, dict):
        return tuple(sorted((k, _norm(v)) for k, v in x.items()))
    if isinstance(x, (list, tuple)):
        return tuple(_norm(v) for v in x)
    if isinstance(x, np.ndarray):
        return tuple(_norm(v) for v in x.tolist())
    if isinstance(x, np.generic):
        x = x.item()
    if isinstance(x, float) and x != x:
        return "nan"
    if isinstance(x, (bool, int, float, str)) or x is None:
        return x
    return repr(x)


def _done(sid_of, pairs):
    return tuple(sorted((sid_of[id(s)], d.handle, _norm(d.cct),
                         _norm(d.fct)) for s, d in pairs))


def run_schedule(ops: List[tuple], *, shards: int = 1,
                 async_dispatch: bool = False,
                 drain_steps: int = 400) -> List[tuple]:
    """Replay a schedule on a fresh pool; return its observations."""
    from repro.api import SessionPool
    pool = SessionPool(PARAMS, num_ports=PORTS,
                       max_sessions=MAX_SESSIONS, shards=shards,
                       async_dispatch=async_dispatch)
    sess: dict = {}
    sid_of: dict = {}
    obs: List[tuple] = []
    for i, op in enumerate(ops):
        kind = op[0]
        if kind == "admit":
            s = pool.session()
            sess[op[1]] = s
            sid_of[id(s)] = op[1]
            obs.append((i, "admit", op[1], pool.num_sessions))
        elif kind == "release":
            pool.release(sess.pop(op[1]))
            obs.append((i, "release", op[1], pool.num_sessions))
        elif kind == "submit":
            sid, n, cseed, base = op[1:]
            handles = sess[sid].submit(
                sorted(_coflows(cseed, n, base=base),
                       key=lambda c: (c.arrival, c.cid)))
            obs.append((i, "submit", sid, tuple(handles)))
        elif kind == "advance":
            pool.advance(op[1])
        elif kind == "advance_one":
            sess[op[1]].advance(op[2])
        elif kind == "poll":
            obs.append((i, "poll", _done(sid_of, pool.poll())))
        elif kind == "poll_one":
            done = sess[op[1]].poll()
            obs.append((i, "poll_one", op[1],
                        tuple(sorted((d.handle, _norm(d.cct),
                                      _norm(d.fct)) for d in done))))
        elif kind == "snapshot":
            obs.append((i, "snapshot", op[1],
                        _norm(sess[op[1]].snapshot())))
        else:
            raise ValueError(f"unknown op {op!r}")
    for step in range(drain_steps):
        if not any(s.num_live for s in sess.values()):
            break
        pool.advance(2.0)
        done = pool.poll()
        if done:
            obs.append(("drain", step, _done(sid_of, done)))
    else:
        raise RuntimeError(
            f"schedule failed to drain in {drain_steps} steps")
    obs.append(("final",
                tuple(sorted((sid, s.num_live)
                             for sid, s in sess.items()))))
    return obs


def first_divergence(oracle: List[tuple], got: List[tuple]
                     ) -> Optional[Tuple[int, object, object]]:
    for i, (a, b) in enumerate(zip(oracle, got)):
        if a != b:
            return (i, a, b)
    if len(oracle) != len(got):
        i = min(len(oracle), len(got))
        return (i, oracle[i] if i < len(oracle) else "<end>",
                got[i] if i < len(got) else "<end>")
    return None


# ---- the explorer --------------------------------------------------------


def _configs() -> List[Tuple[int, bool]]:
    """(shards, async) variants to race against the blocking 1-shard
    oracle, capped by the devices actually visible."""
    out = [(1, True)]
    try:
        import jax
        ndev = jax.local_device_count()
    except Exception:                                    # noqa: BLE001
        ndev = 1
    for s in (2, 4):
        if ndev >= s and MAX_SESSIONS % s == 0:
            out.append((s, True))
    return out


def explore(schedules: int = 3, n_ops: int = 24, seed: int = 0,
            out=sys.stdout) -> int:
    configs = _configs()
    print(f"explore: {schedules} schedule(s) x {n_ops} ops, "
          f"oracle=(shards=1, async=off), candidates="
          f"{['(shards=%d, async=%s)' % c for c in configs]}",
          file=out)
    failures = 0
    for k in range(schedules):
        ops = make_schedule(seed + k, n_ops)
        oracle = run_schedule(ops, shards=1, async_dispatch=False)
        for shards, async_d in configs:
            got = run_schedule(ops, shards=shards,
                               async_dispatch=async_d)
            div = first_divergence(oracle, got)
            tag = (f"schedule {seed + k} vs (shards={shards}, "
                   f"async={async_d})")
            if div is None:
                print(f"explore: ok   {tag} -- "
                      f"{len(oracle)} observations match", file=out)
            else:
                failures += 1
                i, a, b = div
                print(f"explore: RACE {tag} at observation {i}:\n"
                      f"  oracle: {a}\n"
                      f"  got:    {b}", file=out)
    if failures:
        print(f"explore: {failures} divergence(s) from the blocking "
              f"oracle -- the coherence protocol is NOT sufficient "
              f"for this interleaving", file=out)
    else:
        print("explore: no divergences -- all configurations match "
              "the blocking oracle bitwise", file=out)
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.explore",
        description="pool interleaving race detector")
    ap.add_argument("--schedules", type=int, default=3)
    ap.add_argument("--ops", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    return explore(args.schedules, args.ops, args.seed)


if __name__ == "__main__":
    sys.exit(main())
