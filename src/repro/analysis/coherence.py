"""Slab coherence checker: effect extraction + typestate rules.

The serving plane's host<->device coherence protocol (DESIGN S9) lives
in three files -- `api/pool.py` (the device-resident slab),
`api/session.py` (the row view), `launch/serve.py` (the tenant front
door) -- and until now only in docstrings.  This module makes it
machine-checked, in three layers:

1. **Protocol declaration** (`PROTOCOL`): the tracked state variables
   of `SessionPool` / `SaathSession` / `CoflowServer` and what each
   one means.  The extractor only reasons about these names.

2. **Effect extraction** (`extract_effects`): a stdlib-AST walk over
   the three files that infers, per method, its read / write /
   invalidate / entry-write / call / transfer effect sets.  The
   result is pinned as a committed golden manifest
   (`analysis/coherence_manifest.json`, same drift model as the
   dispatch auditor's `dispatch_manifest.json`): effect drift is
   surfaced as a structured diff and blessed with `--update`.

3. **Typestate rules** (`check_protocol`): a path-sensitive must-facts
   walk enforcing the protocol:

   - `coh-dirty-on-write`    every coflow-membership / entry mutation
                             sets its dirty flag on all exit paths
   - `coh-sync-before-mirror` every ctl-mirror access is dominated by
                             `_sync_ctl()` (directly or via a callee
                             that provides it on every exit)
   - `coh-stale-folded-cache` every `_tb` / `_ep_stack` rewrite also
                             touches its folded dispatch cache
   - `coh-ctl-consume-once`  the deferred async ctl handle is armed in
                             one place, consumed exactly once
   - `coh-unaccounted-transfer` no public pool method reaches a
                             host<->device transfer outside an
                             `@_io_accounted` frame
   - `coh-fresh-index`       `_new_done` flips keep the `_fresh`
                             completion index in step, per block
   - `coh-harvest-before-read` server reads of `_pending` follow a
                             `_harvest()` in the same method

Known-good deviations are waived in `WAIVERS` with a reason; waivers
are part of the manifest so edits to them are reviewed like any other
drift.  `--selftest` runs the seeded-mutation harness: six single-site
coherence bugs are injected into in-memory copies of the sources and
the checker must flag each one with the expected rule.

Usage:
    python -m repro.analysis.coherence             # gate vs manifest
    python -m repro.analysis.coherence --update    # re-pin manifest
    python -m repro.analysis.coherence --selftest  # mutation harness
"""
from __future__ import annotations

import argparse
import ast
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.analysis.rules import Finding

# ---- rule ids ------------------------------------------------------------

R_DIRTY = "coh-dirty-on-write"
R_SYNC = "coh-sync-before-mirror"
R_CACHE = "coh-stale-folded-cache"
R_HANDLE = "coh-ctl-consume-once"
R_IO = "coh-unaccounted-transfer"
R_FRESH = "coh-fresh-index"
R_HARVEST = "coh-harvest-before-read"

RULES = {
    R_DIRTY: "membership/entry mutations set their dirty flag on "
             "every exit path",
    R_SYNC: "ctl-mirror accesses are dominated by _sync_ctl()",
    R_CACHE: "slab/epoch-stack rewrites invalidate the folded "
             "dispatch caches",
    R_HANDLE: "the deferred ctl handle is armed once, consumed "
              "exactly once",
    R_IO: "public pool surface never reaches a transfer outside "
          "@_io_accounted",
    R_FRESH: "_new_done flips update the _fresh completion index in "
             "the same block",
    R_HARVEST: "server _pending reads follow _harvest() in the same "
               "method",
}

# ---- the protocol declaration -------------------------------------------

PROTOCOL: Dict[str, Dict[str, str]] = {
    "SessionPool": {
        "_tb": "device TraceBatch slab (row-major, padded)",
        "_state": "device EngineState/CoordState slab (folded when "
                  "sharded)",
        "_tb_disp": "folded per-shard dispatch view of _tb; None "
                    "means stale",
        "_ep_disp": "folded per-shard dispatch view of the "
                    "EngineParams stack; None means stale",
        "_ep_stack": "stacked per-row EngineParams; None means stale",
        "_ticks": "lazy host mirror of per-row device tick counters",
        "_fin": "lazy host mirror of the per-row completion bitmap",
        "_ctl": "deferred async ctl handle: (tick, finished) device "
                "arrays parked by _dispatch_async, consumed once by "
                "_sync_ctl",
        "_pend_rows": "rows with an in-flight async horizon "
                      "(row -> (session, n_end))",
        "_fresh": "sessions whose completion bitmap changed since "
                  "last gather (poll fast path)",
        "_blank_rows": "rows needing a blank-row scatter before next "
                       "dispatch",
        "_sessions": "row -> live SaathSession (None = free)",
        "_free": "sorted free-row list",
        "_scratch": "reusable host staging row",
        "io": "host<->device byte / dispatch accounting",
    },
    "SaathSession": {
        "_live": "handle -> live coflow entry (the membership set)",
        "_slots": "submission-ordered entry list, row-pack order",
        "_table": "numpy-backend staged FlowTable",
        "_policy": "numpy-backend coordinator instance",
        "_tb_dirty": "membership changed since last pack: row "
                     "re-pack required",
        "_state_dirty": "entry dynamic state diverged from the "
                        "packed row: state re-scatter required",
        "_host_stale": "device row advanced past the host entries",
        "_new_done": "completion bitmap changed on device; gather "
                     "before poll",
        "_host_done": "a harvested completion is waiting host-side",
        "_pend": "capped schedule interval carried across advances",
        "_pending": "numpy backend's capped interval (or None)",
        "_tick": "session tick in absolute (epoch-based) units",
        "_epoch": "row re-base epoch (f32 resolution guard)",
        "_clock": "wall-clock seconds fed to advance()",
        "_row": "pool row index (None after release)",
        "_pool": "owning SessionPool (None after release)",
        "_seq": "monotonic handle counter",
    },
    "CoflowServer": {
        "pool": "the shared SessionPool slab",
        "_tenants": "tenant -> SaathSession row view",
        "_pending": "tenant -> harvested-but-unpolled completions",
        "_deferred": "tenant -> quota-deferred submissions",
        "_agg": "tenant -> incremental TenantAggregates",
        "_quota": "tenant -> TenantQuota (None = unthrottled)",
        "_live_bytes": "tenant -> admitted-but-unfinished bytes",
    },
}

ENTRY_FIELDS = frozenset({
    "sent", "done", "fct", "rate", "pend_sent", "finished", "cct",
    "queue", "deadline", "running",
})
ENTRY_RECEIVERS = frozenset({"e", "entry"})

# ctl-mirror state: reads/writes require a dominating _sync_ctl()
SYNC_VARS = frozenset({"_ticks", "_fin", "_fresh", "_new_done"})
# membership vars whose mutation requires _tb_dirty on every exit
MEMBERSHIP_VARS = frozenset({"_live", "_slots"})
# slab source -> folded dispatch cache it must invalidate
CACHE_OF = {"_tb": "_tb_disp", "_ep_stack": "_ep_disp"}

_MUTATORS = frozenset({
    "add", "append", "clear", "discard", "extend", "insert", "pop",
    "popitem", "remove", "setdefault", "update",
})
_TRANSFER_LEAVES = frozenset({
    "scatter_rows", "gather_rows", "session_advance",
    "session_plan_tick", "device_put",
})

FILES: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
    ("api/pool.py", ("SessionPool",)),
    ("api/session.py", ("SaathSession",)),
    ("launch/serve.py", ("CoflowServer",)),
)

# (qualified method, rule) -> reason.  Waivers ship in the manifest so
# edits to this table show up as reviewed drift.
WAIVERS: Dict[Tuple[str, str], str] = {
    ("SessionPool._dispatch_async", R_SYNC):
        "async fast path reads the stale tick mirror by design -- a "
        "stale mirror can only under-ask the device horizon",
    ("SessionPool.release", R_SYNC):
        "the row-identity check in _sync_ctl disarms the parked ctl "
        "for released rows",
    ("SaathSession.poll", R_DIRTY):
        "lazy slot reclaim: finished coflows stay packed as masked "
        "no-op rows until the next re-pack",
    ("SaathSession.close", R_DIRTY):
        "releases the row itself; clearing _live on a dead session "
        "needs no re-pack",
    ("CoflowServer.stats", R_HARVEST):
        "monitoring snapshot may lag one harvest by design",
}

# methods allowed to write entry fields / membership without dirtying:
# they sync FROM the authoritative copy, so flagging would be wrong
LEGAL_SYNC_WRITERS = frozenset({
    "SessionPool._sync_row",
    "SaathSession._rebuild_table",
    "SaathSession._sync_from_table",
})

# internal pool methods that session/server code calls directly --
# they are public surface for rule purposes
CROSS_CLASS_ENTRIES = (
    "SessionPool._adopt",
    "SessionPool._advance",
    "SessionPool._materialize",
    "SessionPool._plan_tick",
)

MANIFEST_VERSION = 1


def default_manifest_path() -> Path:
    return Path(__file__).resolve().parents[3] / "analysis" \
        / "coherence_manifest.json"


# ---- event extraction ----------------------------------------------------
# An event is (kind, name, hint, lineno):
#   kind: "r" read | "w" write | "ew" entry-field write |
#         "call" self-method call | "pcall" pool-method call |
#         "xfer" host<->device transfer
#   hint: for writes, the stored value's shape: "None" | "True" |
#         "False" | "elem" (container element) | "expr"


def _leaf(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _hint_of(value: ast.AST) -> str:
    if isinstance(value, ast.Constant):
        if value.value is None:
            return "None"
        if value.value is True:
            return "True"
        if value.value is False:
            return "False"
    return "expr"


def _is_np_pull(func: ast.AST) -> bool:
    return (isinstance(func, ast.Attribute)
            and func.attr in ("array", "asarray")
            and isinstance(func.value, ast.Name)
            and func.value.id in ("np", "numpy"))


def _expr_events(node, vars_, out) -> None:
    if node is None:
        return
    if isinstance(node, ast.Call):
        f = node.func
        leaf = _leaf(f)
        if leaf in _TRANSFER_LEAVES:
            out.append(("xfer", leaf, None, node.lineno))
        elif _is_np_pull(f):
            out.append(("xfer", "np." + f.attr, None, node.lineno))
        elif leaf == "tree_map" and node.args \
                and _is_np_pull(node.args[0]):
            out.append(("xfer", "tree_map(np.asarray)", None,
                        node.lineno))
        if isinstance(f, ast.Attribute):
            recv = f.value
            if isinstance(recv, ast.Name) and recv.id == "self":
                for a in node.args:
                    _expr_events(a, vars_, out)
                for kw in node.keywords:
                    _expr_events(kw.value, vars_, out)
                out.append(("call", f.attr, None, node.lineno))
                return
            if isinstance(recv, ast.Attribute) \
                    and recv.attr in ("_pool", "pool") \
                    and isinstance(recv.value, ast.Name) \
                    and recv.value.id == "self":
                for a in node.args:
                    _expr_events(a, vars_, out)
                for kw in node.keywords:
                    _expr_events(kw.value, vars_, out)
                out.append(("pcall", f.attr, None, node.lineno))
                return
            if f.attr in _MUTATORS:
                base = recv
                if isinstance(base, ast.Subscript):
                    _expr_events(base.slice, vars_, out)
                    base = base.value
                if isinstance(base, ast.Attribute) \
                        and base.attr in vars_:
                    for a in node.args:
                        _expr_events(a, vars_, out)
                    for kw in node.keywords:
                        _expr_events(kw.value, vars_, out)
                    _expr_events(base.value, vars_, out)
                    out.append(("w", base.attr, "elem", node.lineno))
                    return
        for c in ast.iter_child_nodes(node):
            _expr_events(c, vars_, out)
        return
    if isinstance(node, ast.Attribute):
        _expr_events(node.value, vars_, out)
        if node.attr in vars_ and isinstance(node.ctx, ast.Load):
            out.append(("r", node.attr, None, node.lineno))
        return
    for c in ast.iter_child_nodes(node):
        _expr_events(c, vars_, out)


def _target_events(tgt, vars_, hint, out) -> None:
    if isinstance(tgt, ast.Attribute):
        _expr_events(tgt.value, vars_, out)
        if tgt.attr in vars_:
            out.append(("w", tgt.attr, hint, tgt.lineno))
        elif tgt.attr in ENTRY_FIELDS \
                and isinstance(tgt.value, ast.Name) \
                and tgt.value.id in ENTRY_RECEIVERS:
            out.append(("ew", tgt.attr, hint, tgt.lineno))
    elif isinstance(tgt, ast.Subscript):
        _expr_events(tgt.slice, vars_, out)
        base = tgt.value
        if isinstance(base, ast.Attribute):
            _expr_events(base.value, vars_, out)
            if base.attr in vars_:
                out.append(("w", base.attr, "elem", tgt.lineno))
            elif base.attr in ENTRY_FIELDS \
                    and isinstance(base.value, ast.Name) \
                    and base.value.id in ENTRY_RECEIVERS:
                out.append(("ew", base.attr, "elem", tgt.lineno))
        else:
            _expr_events(base, vars_, out)
    elif isinstance(tgt, (ast.Tuple, ast.List)):
        for el in tgt.elts:
            _target_events(el, vars_, hint, out)
    elif isinstance(tgt, ast.Starred):
        _target_events(tgt.value, vars_, hint, out)
    # bare Name targets carry no tracked effect


def _aug_read(tgt, vars_, out) -> None:
    base = tgt
    if isinstance(base, ast.Subscript):
        base = base.value
    if isinstance(base, ast.Attribute) and base.attr in vars_:
        out.append(("r", base.attr, None, tgt.lineno))


def _stmt_header_events(stmt, vars_, out) -> None:
    """Events of a statement's own expressions (compound statements
    contribute only their header; bodies are walked separately)."""
    if isinstance(stmt, ast.Assign):
        _expr_events(stmt.value, vars_, out)
        tgts = stmt.targets
        if (len(tgts) == 1 and isinstance(tgts[0], (ast.Tuple, ast.List))
                and isinstance(stmt.value, ast.Tuple)
                and len(stmt.value.elts) == len(tgts[0].elts)):
            for el, v in zip(tgts[0].elts, stmt.value.elts):
                _target_events(el, vars_, _hint_of(v), out)
        else:
            hint = _hint_of(stmt.value)
            for tgt in tgts:
                _target_events(tgt, vars_, hint, out)
    elif isinstance(stmt, ast.AugAssign):
        _expr_events(stmt.value, vars_, out)
        _aug_read(stmt.target, vars_, out)
        _target_events(stmt.target, vars_, "expr", out)
    elif isinstance(stmt, ast.AnnAssign):
        if stmt.value is not None:
            _expr_events(stmt.value, vars_, out)
            _target_events(stmt.target, vars_, _hint_of(stmt.value),
                           out)
    elif isinstance(stmt, ast.Delete):
        for tgt in stmt.targets:
            _target_events(tgt, vars_, "elem", out)
    elif isinstance(stmt, ast.Expr):
        _expr_events(stmt.value, vars_, out)
    elif isinstance(stmt, ast.Assert):
        _expr_events(stmt.test, vars_, out)
        if stmt.msg is not None:
            _expr_events(stmt.msg, vars_, out)
    elif isinstance(stmt, ast.Return):
        if stmt.value is not None:
            _expr_events(stmt.value, vars_, out)
    elif isinstance(stmt, ast.Raise):
        if stmt.exc is not None:
            _expr_events(stmt.exc, vars_, out)
        if stmt.cause is not None:
            _expr_events(stmt.cause, vars_, out)
    elif isinstance(stmt, (ast.If, ast.While)):
        _expr_events(stmt.test, vars_, out)
    elif isinstance(stmt, ast.For):
        _expr_events(stmt.iter, vars_, out)
        _target_events(stmt.target, vars_, "expr", out)
    elif isinstance(stmt, ast.With):
        for item in stmt.items:
            _expr_events(item.context_expr, vars_, out)
            if item.optional_vars is not None:
                _target_events(item.optional_vars, vars_, "expr", out)
    # Pass/Break/Continue/Global/Import/Try headers: no expressions


def _iter_stmts(body):
    for stmt in body:
        yield stmt
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        for attr in ("body", "orelse", "finalbody"):
            sub = getattr(stmt, attr, None)
            if sub:
                yield from _iter_stmts(sub)
        for h in getattr(stmt, "handlers", ()):
            yield from _iter_stmts(h.body)


class _Method:
    """One extracted method: flat effect events + summary bits."""

    def __init__(self, cls: str, name: str, path: str,
                 node: ast.FunctionDef, vars_) -> None:
        self.cls = cls
        self.name = name
        self.qual = f"{cls}.{name}"
        self.path = path
        self.node = node
        self.vars = vars_
        self.accounted = any(_leaf(d) == "_io_accounted"
                             for d in node.decorator_list)
        self.events: List[tuple] = []
        for stmt in _iter_stmts(node.body):
            _stmt_header_events(stmt, vars_, self.events)

    def writes_of(self, name: str):
        return [e for e in self.events if e[0] == "w" and e[1] == name]

    @property
    def xfers(self):
        return [e for e in self.events if e[0] == "xfer"]

    def summary(self) -> dict:
        reads, writes, inval, ew = set(), set(), set(), set()
        calls = set()
        for kind, name, hint, _line in self.events:
            if kind == "r":
                reads.add(name)
            elif kind == "w":
                (inval if hint == "None" else writes).add(name)
            elif kind == "ew":
                ew.add(name)
            elif kind == "call":
                calls.add("self." + name)
            elif kind == "pcall":
                calls.add("pool." + name)
        return {
            "reads": sorted(reads),
            "writes": sorted(writes),
            "invalidates": sorted(inval),
            "entry_writes": sorted(ew),
            "calls": sorted(calls),
            "transfers": bool(self.xfers),
            "accounted": self.accounted,
        }


def _load_sources(sources: Optional[Dict[str, str]] = None
                  ) -> Dict[str, str]:
    if sources is not None:
        return sources
    root = Path(__file__).resolve().parents[1]
    return {rel: (root / rel).read_text() for rel, _cls in FILES}


def extract_methods(sources: Optional[Dict[str, str]] = None
                    ) -> Dict[str, _Method]:
    src = _load_sources(sources)
    tracked_pool = (frozenset(PROTOCOL["SessionPool"])
                    | frozenset(PROTOCOL["SaathSession"]))
    methods: Dict[str, _Method] = {}
    for rel, classes in FILES:
        vars_ = (frozenset(PROTOCOL["CoflowServer"])
                 if rel == "launch/serve.py" else tracked_pool)
        tree = ast.parse(src[rel], filename=rel)
        for node in tree.body:
            if not isinstance(node, ast.ClassDef) \
                    or node.name not in classes:
                continue
            for item in node.body:
                if isinstance(item, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    m = _Method(node.name, item.name, rel, item, vars_)
                    methods[m.qual] = m
    return methods


# ---- the typestate walk --------------------------------------------------


class _State:
    __slots__ = ("facts", "may", "term")

    def __init__(self, facts=(), may=()):
        self.facts = set(facts)
        self.may = set(may)
        self.term = False

    def copy(self) -> "_State":
        s = _State(self.facts, self.may)
        s.term = self.term
        return s


def _join(st: "_State", a: "_State", b: "_State") -> None:
    st.may |= a.may | b.may
    if a.term and b.term:
        st.term = True
    elif a.term:
        st.facts = set(b.facts)
    elif b.term:
        st.facts = set(a.facts)
    else:
        st.facts = a.facts & b.facts


def _is_none_guard(test: ast.AST) -> bool:
    """`if self.X is None:` -- a degenerate-state early-out whose bare
    return does not count against provides_sync."""
    return (isinstance(test, ast.Compare)
            and len(test.ops) == 1
            and isinstance(test.ops[0], ast.Is)
            and isinstance(test.comparators[0], ast.Constant)
            and test.comparators[0].value is None
            and isinstance(test.left, ast.Attribute))


class _Checker:
    """Fixpoint driver: repeats the per-method walk until the
    provides_sync / requires_sync / may_arm summaries stabilize, then
    one reporting pass emits findings."""

    def __init__(self, methods: Dict[str, _Method]) -> None:
        self.methods = methods
        self.provides: set = set()
        self.requires: Dict[str, tuple] = {}   # qual -> (line, why)
        self.may_arm = self._arm_closure()
        self.findings: List[Finding] = []

    # -- summary-level: which methods can (re-)arm the ctl handle
    def _arm_closure(self) -> set:
        armers = {q for q, m in self.methods.items()
                  if any(h not in ("None",)
                         for _k, n, h, _l in m.events
                         if _k == "w" and n == "_ctl")}
        changed = True
        while changed:
            changed = False
            for q, m in self.methods.items():
                if q in armers:
                    continue
                for kind, name, _h, _l in m.events:
                    callee = self._resolve(m, kind, name)
                    if callee in armers:
                        armers.add(q)
                        changed = True
                        break
        return armers

    def _resolve(self, m: _Method, kind: str, name: str
                 ) -> Optional[str]:
        if kind == "call":
            q = f"{m.cls}.{name}"
        elif kind == "pcall":
            q = f"SessionPool.{name}"
        else:
            return None
        return q if q in self.methods else None

    # -- the per-method path walk
    def run(self) -> List[Finding]:
        for _pass in range(10):
            before = (frozenset(self.provides),
                      frozenset(self.requires))
            self.requires = {}
            for m in self.methods.values():
                self._walk(m, report=False)
            if (frozenset(self.provides),
                    frozenset(self.requires)) == before:
                break
        self.findings = []
        for m in self.methods.values():
            self._walk(m, report=True)
        self._summary_rules()
        self._report_sync_entries()
        seen, out = set(), []
        for f in sorted(self.findings,
                        key=lambda f: (f.path, f.line, f.rule)):
            key = (f.rule, f.path, f.line, f.msg)
            if key not in seen:
                seen.add(key)
                out.append(f)
        return out

    def _waived(self, m: _Method, rule: str) -> bool:
        return (m.qual, rule) in WAIVERS

    def _walk(self, m: _Method, report: bool) -> None:
        self._m = m
        self._report = report
        self._exits: List[bool] = []
        self._guard = 0
        self._r1_hit: set = set()
        self._r7_hit = False
        st = _State()
        self._block(m.node.body, st)
        if not st.term:
            self._exit(st, m.node.body[-1].lineno if m.node.body
                       else m.node.lineno)
        provides = (m.qual == "SessionPool._sync_ctl"
                    or (bool(self._exits) and all(self._exits)))
        if provides:
            self.provides.add(m.qual)
        else:
            self.provides.discard(m.qual)

    def _block(self, body, st: "_State") -> None:
        due = None
        for stmt in body:
            if st.term:
                break
            due = self._stmt(stmt, st, due)
        if due is not None and self._report \
                and self._m.name != "__init__" \
                and not self._waived(self._m, R_FRESH):
            self.findings.append(Finding(
                R_FRESH, self._m.path, due,
                f"{self._m.qual}: _new_done updated without a "
                f"matching _fresh update in the same block"))

    def _stmt(self, stmt, st: "_State", due):
        ev: List[tuple] = []
        _stmt_header_events(stmt, self._m.vars, ev)
        due = self._events(ev, st, due)
        t = type(stmt)
        if t is ast.Return:
            self._exit(st, stmt.lineno)
            st.term = True
        elif t in (ast.Raise, ast.Break, ast.Continue):
            st.term = True
        elif t is ast.If:
            guarded = _is_none_guard(stmt.test)
            a = st.copy()
            if guarded:
                self._guard += 1
            self._block(stmt.body, a)
            if guarded:
                self._guard -= 1
            b = st.copy()
            self._block(stmt.orelse, b)
            _join(st, a, b)
        elif t in (ast.For, ast.While):
            body = st.copy()
            self._block(stmt.body, body)
            st.may |= body.may
            if stmt.orelse:
                self._block(stmt.orelse, st)
        elif t is ast.With:
            self._block(stmt.body, st)
        elif t is ast.Try:
            body = st.copy()
            self._block(stmt.body, body)
            st.may |= body.may
            for h in stmt.handlers:
                hs = st.copy()
                self._block(h.body, hs)
                st.may |= hs.may
            if stmt.orelse:
                self._block(stmt.orelse, st)
            if stmt.finalbody:
                self._block(stmt.finalbody, st)
        return due

    def _events(self, ev, st: "_State", due):
        m = self._m
        for kind, name, hint, line in ev:
            if kind in ("r", "w") and name in SYNC_VARS:
                self._need_sync(st, line, f"touches `{name}`")
            if kind == "w":
                if name in MEMBERSHIP_VARS:
                    st.may.add("w:mem")
                elif name in ("_tb_dirty", "_state_dirty"):
                    if hint == "True":
                        st.facts.add("f:" + name)
                elif name == "_new_done":
                    due = line
                elif name == "_fresh":
                    due = None
                elif name == "_ctl" and hint != "None":
                    st.facts.discard("synced")
            elif kind == "r":
                if (name == "_pending" and m.cls == "CoflowServer"
                        and "harvested" not in st.facts
                        and m.name not in ("_harvest", "__init__")
                        and not self._waived(m, R_HARVEST)
                        and self._report and not self._r7_hit):
                    self._r7_hit = True
                    self.findings.append(Finding(
                        R_HARVEST, m.path, line,
                        f"{m.qual}: reads _pending without a "
                        f"preceding _harvest() in this method"))
            elif kind == "ew":
                if m.qual not in LEGAL_SYNC_WRITERS:
                    st.may.add("w:entry")
            elif kind in ("call", "pcall"):
                callee = self._resolve(m, kind, name)
                if callee == "SessionPool._sync_ctl":
                    st.facts.add("synced")
                    continue
                if m.cls == "CoflowServer" and kind == "call" \
                        and name == "_harvest":
                    st.facts.add("harvested")
                if callee is None:
                    continue
                if callee in self.may_arm:
                    st.facts.discard("synced")
                if callee in self.provides:
                    st.facts.add("synced")
                elif callee in self.requires \
                        and "synced" not in st.facts:
                    cl, why = self.requires[callee]
                    self._need_sync(
                        st, line, f"calls {callee} which {why} "
                        f"({self.methods[callee].path}:{cl})")
        return due

    def _need_sync(self, st: "_State", line: int, why: str) -> None:
        m = self._m
        if "synced" in st.facts or m.name == "__init__" \
                or m.qual == "SessionPool._sync_ctl" \
                or m.qual in LEGAL_SYNC_WRITERS \
                or self._waived(m, R_SYNC):
            return
        if m.qual not in self.requires:
            self.requires[m.qual] = (line, why)

    def _exit(self, st: "_State", line: int) -> None:
        if self._guard == 0:
            self._exits.append("synced" in st.facts)
        if not self._report:
            return
        m = self._m
        if m.name == "__init__" or m.qual in LEGAL_SYNC_WRITERS \
                or self._waived(m, R_DIRTY):
            return
        for tag, flag in (("w:mem", "_tb_dirty"),
                          ("w:entry", "_state_dirty")):
            if tag in st.may and "f:" + flag not in st.facts \
                    and (tag, line) not in self._r1_hit:
                self._r1_hit.add((tag, line))
                self.findings.append(Finding(
                    R_DIRTY, m.path, line,
                    f"{m.qual}: exits after a "
                    f"{'membership' if tag == 'w:mem' else 'entry'} "
                    f"mutation without setting {flag}"))

    # -- method-summary rules (path-insensitive)
    def _summary_rules(self) -> None:
        self._rule_cache()
        self._rule_handle()
        self._rule_io()

    def _rule_cache(self) -> None:
        for m in self.methods.values():
            if m.cls != "SessionPool" or m.name == "__init__":
                continue
            for src_var, cache in CACHE_OF.items():
                real = [e for e in m.writes_of(src_var)
                        if e[2] != "None"]
                if real and not m.writes_of(cache) \
                        and not self._waived(m, R_CACHE):
                    self.findings.append(Finding(
                        R_CACHE, m.path, real[0][3],
                        f"{m.qual}: rewrites {src_var} without "
                        f"invalidating or refreshing {cache}"))

    def _rule_handle(self) -> None:
        allowed = {"SessionPool.__init__",
                   "SessionPool._dispatch_async",
                   "SessionPool._sync_ctl"}
        for m in self.methods.values():
            touches = [e for e in m.events
                       if e[0] in ("r", "w") and e[1] == "_ctl"]
            if touches and m.qual not in allowed:
                self.findings.append(Finding(
                    R_HANDLE, m.path, touches[0][3],
                    f"{m.qual}: touches the deferred ctl handle; "
                    f"only _dispatch_async may arm it and only "
                    f"_sync_ctl may consume it"))
        consumer = self.methods.get("SessionPool._sync_ctl")
        if consumer is not None:
            reads = [e for e in consumer.events
                     if e[0] == "r" and e[1] == "_ctl"]
            resets = [e for e in consumer.writes_of("_ctl")
                      if e[2] == "None"]
            if reads and not resets:
                self.findings.append(Finding(
                    R_HANDLE, consumer.path, reads[0][3],
                    "SessionPool._sync_ctl: consumes the ctl handle "
                    "without resetting it to None -- a second sync "
                    "would double-consume the download"))

    def _rule_io(self) -> None:
        pool = {q: m for q, m in self.methods.items()
                if m.cls == "SessionPool"}
        entries = [q for q, m in pool.items()
                   if not m.name.startswith("_")]
        entries += [q for q in CROSS_CLASS_ENTRIES if q in pool]
        reported = set()
        for entry in entries:
            hit = self._find_unaccounted(pool, entry, set())
            if hit is not None and hit not in reported:
                reported.add(hit)
                q, line, desc = hit[0], hit[1], hit[2]
                self.findings.append(Finding(
                    R_IO, pool[q].path, line,
                    f"{q}: reachable from public surface "
                    f"({entry.split('.')[1]}) and performs `{desc}` "
                    f"outside an @_io_accounted frame"))

    def _find_unaccounted(self, pool, qual, seen):
        m = pool.get(qual)
        if m is None or m.accounted or qual in seen:
            return None
        seen.add(qual)
        if m.xfers:
            _k, desc, _h, line = m.xfers[0]
            return (qual, line, desc)
        for kind, name, _h, _l in m.events:
            if kind != "call":
                continue
            hit = self._find_unaccounted(
                pool, f"SessionPool.{name}", seen)
            if hit is not None:
                return hit
        return None

    def _report_sync_entries(self) -> None:
        entries = {q for q, m in self.methods.items()
                   if not m.name.startswith("_")}
        entries.update(CROSS_CLASS_ENTRIES)
        for q in sorted(entries & set(self.requires)):
            line, why = self.requires[q]
            m = self.methods[q]
            self.findings.append(Finding(
                R_SYNC, m.path, line,
                f"{q}: {why} with no dominating _sync_ctl()"))


# ---- public API ----------------------------------------------------------


def check_protocol(sources: Optional[Dict[str, str]] = None
                   ) -> List[Finding]:
    """Run every coherence rule; return surviving findings."""
    return _Checker(extract_methods(sources)).run()


def build_manifest(sources: Optional[Dict[str, str]] = None) -> dict:
    methods = extract_methods(sources)
    checker = _Checker(methods)
    checker.run()
    entries = {}
    for qual in sorted(methods):
        m = methods[qual]
        s = m.summary()
        s["file"] = m.path
        s["provides_sync"] = qual in checker.provides
        entries[qual] = s
    return {
        "protocol_version": MANIFEST_VERSION,
        "protocol": PROTOCOL,
        "rules": RULES,
        "waivers": {f"{q}::{r}": why
                    for (q, r), why in sorted(WAIVERS.items())},
        "methods": entries,
    }


def check_manifest(manifest: dict,
                   sources: Optional[Dict[str, str]] = None
                   ) -> List[str]:
    """Structured drift report between the committed manifest and a
    fresh extraction.  Empty list == no drift."""
    cur = build_manifest(sources)
    problems: List[str] = []
    if manifest.get("protocol_version") != MANIFEST_VERSION:
        problems.append(
            f"manifest protocol_version "
            f"{manifest.get('protocol_version')} != "
            f"{MANIFEST_VERSION}")
        return problems
    for section in ("protocol", "waivers"):
        if manifest.get(section) != cur[section]:
            problems.append(
                f"{section} declaration drifted from the committed "
                f"manifest -- re-pin with --update after review")
    old_m = manifest.get("methods", {})
    new_m = cur["methods"]
    for q in sorted(set(old_m) - set(new_m)):
        problems.append(f"{q}: in the manifest but no longer "
                        f"extracted (removed or renamed)")
    for q in sorted(set(new_m) - set(old_m)):
        problems.append(f"{q}: new method, not in the manifest")
    for q in sorted(set(new_m) & set(old_m)):
        diff = _method_diff(old_m[q], new_m[q])
        if diff:
            problems.append(f"{q}: effect drift\n" + "\n".join(diff))
    return problems


def _method_diff(old: dict, new: dict) -> List[str]:
    out = []
    for field in ("reads", "writes", "invalidates", "entry_writes",
                  "calls"):
        o, n = set(old.get(field, ())), set(new.get(field, ()))
        for name in sorted(n - o):
            out.append(f"  + {field[:-1]}: {name}")
        for name in sorted(o - n):
            out.append(f"  - {field[:-1]}: {name}")
    for field in ("transfers", "accounted", "provides_sync", "file"):
        o, n = old.get(field), new.get(field)
        if o != n:
            out.append(f"  {field}: {o} -> {n}")
    return out


# ---- seeded-mutation selftest -------------------------------------------

SEEDED_MUTATIONS: Tuple[Tuple[str, str, str, str, str], ...] = (
    ("dropped-dirty-flag-set", "api/session.py",
     "        self._tb_dirty = True\n        return handles",
     "        return handles",
     R_DIRTY),
    ("skipped-sync-ctl", "api/pool.py",
     "        self._sync_ctl()\n"
     "        if completions_only and not self._fresh:",
     "        if completions_only and not self._fresh:",
     R_SYNC),
    ("stale-folded-cache", "api/pool.py",
     "            self._tb = self._place(self._tb)\n"
     "            self._tb_disp = None",
     "            self._tb = self._place(self._tb)",
     R_CACHE),
    ("double-consumed-ctl-handle", "api/pool.py",
     "        tick_dev, fin_dev = self._ctl\n"
     "        self._ctl = None",
     "        tick_dev, fin_dev = self._ctl",
     R_HANDLE),
    ("unaccounted-transfer", "api/pool.py",
     "    @_io_accounted\n    def host_view",
     "    def host_view",
     R_IO),
    ("unflagged-fresh-set-update", "api/pool.py",
     "                s._new_done = True   "
     "# poll must gather this row\n"
     "                self._fresh.add(s)",
     "                s._new_done = True   "
     "# poll must gather this row",
     R_FRESH),
)


def run_selftest(out=sys.stdout) -> int:
    """Inject each seeded coherence bug into an in-memory copy of the
    sources and assert the checker flags it with the expected rule."""
    clean = _load_sources()
    base = check_protocol(clean)
    if base:
        print("selftest: checker is not clean on the pristine "
              "sources:", file=out)
        for f in base:
            print(f"  {f}", file=out)
        return 1
    failures = 0
    for name, rel, old, new, rule in SEEDED_MUTATIONS:
        src = dict(clean)
        if src[rel].count(old) != 1:
            print(f"selftest: FAIL {name}: mutation anchor occurs "
                  f"{src[rel].count(old)}x in {rel} (want 1) -- "
                  f"update SEEDED_MUTATIONS", file=out)
            failures += 1
            continue
        src[rel] = src[rel].replace(old, new)
        found = {f.rule for f in check_protocol(src)}
        if rule in found:
            print(f"selftest: ok   {name} -> [{rule}]", file=out)
        else:
            print(f"selftest: FAIL {name}: expected [{rule}], "
                  f"checker reported {sorted(found) or 'nothing'}",
                  file=out)
            failures += 1
    n = len(SEEDED_MUTATIONS)
    print(f"selftest: {n - failures}/{n} seeded coherence bugs "
          f"caught", file=out)
    return 1 if failures else 0


# ---- CLI -----------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.coherence",
        description="slab coherence checker (DESIGN S9)")
    ap.add_argument("--update", action="store_true",
                    help="re-extract effects and rewrite the golden "
                         "manifest")
    ap.add_argument("--manifest", type=Path,
                    default=default_manifest_path())
    ap.add_argument("--selftest", action="store_true",
                    help="run the seeded-mutation harness")
    args = ap.parse_args(argv)

    if args.selftest:
        return run_selftest()

    findings = check_protocol()
    for f in findings:
        print(f"coherence: {f}")
    if findings:
        print(f"coherence: {len(findings)} protocol violation(s) -- "
              f"fix the site or add a reasoned WAIVERS entry",
              file=sys.stderr)
        # rule findings are a hard gate: --update must not bless them
        return 1

    if args.update:
        manifest = build_manifest()
        args.manifest.parent.mkdir(parents=True, exist_ok=True)
        args.manifest.write_text(json.dumps(manifest, indent=1,
                                            sort_keys=True) + "\n")
        print(f"coherence: wrote {args.manifest} "
              f"({len(manifest['methods'])} methods)")
        return 0

    if not args.manifest.exists():
        print(f"coherence: no manifest at {args.manifest} -- run "
              f"`python -m repro.analysis.coherence --update` "
              f"(make coherence-update) to pin one", file=sys.stderr)
        return 1
    problems = check_manifest(json.loads(args.manifest.read_text()))
    for p in problems:
        print(f"coherence: {p}")
    if problems:
        print(f"coherence: {len(problems)} effect drift(s) vs "
              f"{args.manifest.name} -- review the diff above, then "
              f"bless with `python -m repro.analysis.coherence "
              f"--update` (make coherence-update)", file=sys.stderr)
        return 1
    print(f"coherence: ok -- {len(json.loads(args.manifest.read_text())['methods'])} "
          f"methods match the pinned protocol")
    return 0


if __name__ == "__main__":
    sys.exit(main())
