"""Dispatch auditor: jaxpr-level gate on the hot entrypoints.

Traces each hot entrypoint (the session while_loop block, the planning
tick, the offline chunk scan, the slab scatter/gather) on a tiny
canonical slab and checks:

* HARD invariants (always enforced, even on `--update`): zero host
  callback primitives and zero float64 sites anywhere in the traced
  extent — a `pure_callback`/`debug_callback` or an f64
  `convert_element_type` in the hot loop means a host round-trip or a
  dtype drift shipped;
* DRIFT against the committed golden ``analysis/dispatch_manifest.json``:
  input avals (the jit cache signature — changes here are exactly the
  changes that trigger fresh compiles for existing callers) are
  compared always; primitive counts are compared exactly only when the
  manifest was generated under the SAME jax version (across versions
  they are reported as warnings — lowering details move between
  releases).

Usage::

    python -m repro.analysis.audit            # gate (CI)
    python -m repro.analysis.audit --update   # refresh the manifest

`make audit` / `make audit-update` wrap these. Keep manifest diffs in
review: a new primitive in `session_advance` is a reviewable artifact,
not a silent recompile trigger.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.jaxpr_scan import (aval_signature, callback_primitives,
                                       f64_sites, primitive_counts)

__all__ = ["ENTRYPOINTS", "build_manifest", "check_manifest",
           "default_manifest_path", "main"]

# canonical slab: tiny on purpose — the auditor only traces (no
# compile, no execution), so shapes just need to exercise the real
# code paths (B>1 rows, padding present)
B, F, C, P = 2, 8, 4, 4
CHUNK = 4
FEATURES = (True, True, False, False)
# leaf-spine canonical slab: P ports over Lf leaves (2 hosts per leaf)
LF = 2


def _canonical_slab(leaf_links: int = 0, sampling: bool = False):
    from repro.core import jax_coordinator as jc
    from repro.core.params import SchedulerParams
    from repro.fabric.jax_engine import EngineParams, EngineState
    from repro.traces.batch import empty_batch

    tb = empty_batch(B, flow_capacity=F, coflow_capacity=C,
                     port_capacity=P, leaf_links=leaf_links,
                     sampling=sampling)
    # the sampling slab carries the pilot leaf and a CONCRETE traced
    # clairvoyant scalar (learned row); the default slab compiles both
    # out (empty subtrees — the pre-ISSUE-10 structure, bit for bit)
    ep1 = EngineParams.from_scheduler(
        SchedulerParams(dynamics_requeue=True, clairvoyant=False)
        if sampling else SchedulerParams())
    ep_rows = jax.tree_util.tree_map(
        lambda x: jnp.stack([x] * B), ep1)
    coord = jc.CoordState(np.full((B, C), -1, np.int32),
                          np.full((B, C), np.inf, np.float32),
                          np.zeros((B, C), bool))
    state = EngineState(
        coord=coord,
        sent=np.zeros((B, F), np.float32),
        done=np.ones((B, F), bool),
        fct=np.zeros((B, F), np.float32),
        finished=np.ones((B, C), bool),
        cct=np.full((B, C), np.nan, np.float32),
        t0=np.zeros((B,), np.float32),
        tick=np.zeros((B,), np.int32),
        rate=np.zeros((B, F), np.float32),
        pend_sent=np.zeros((B, F), np.float32),
        pend_tick=np.zeros((B,), np.float32),
        pend_next=np.zeros((B,), np.float32))
    return tb, ep1, ep_rows, state


def _entry_session_advance():
    """The while_loop block `session_advance` dispatches (the pool's
    one-dispatch-per-fleet-advance hot path)."""
    from repro.fabric.jax_engine import _run_session_block

    tb, _, ep_rows, state = _canonical_slab()
    ne = np.full((B,), 4.0, np.float32)
    return jax.make_jaxpr(
        lambda s, t, e, n, m: _run_session_block(
            s, t, e, n, m, kernel=None, features=FEATURES))(
        state, tb, ep_rows, ne, np.int32(64))


def _entry_session_plan_tick():
    from repro.fabric.jax_engine import session_plan_tick

    tb, _, ep_rows, state = _canonical_slab()
    mask = np.zeros((B,), bool)
    mask[0] = True
    return jax.make_jaxpr(
        lambda s, t, e, m: session_plan_tick(
            s, t, e, kernel=None, features=(True, False, False, False),
            row_mask=m))(state, tb, ep_rows, mask)


def _entry_simulate_sweep():
    """The offline chunk scan both `simulate_batch` and
    `simulate_sweep` drive (`sweep=False` — the sweep axis only adds a
    vmap in_axes, not structure)."""
    from repro.fabric.jax_engine import _run_chunk

    tb, ep1, _, state = _canonical_slab()
    offline = state._replace(rate=None, pend_sent=None,
                             pend_tick=None, pend_next=None)
    return jax.make_jaxpr(
        lambda s, t, e: _run_chunk(
            s, t, e, chunk=CHUNK, kernel=None, sweep=False,
            features=FEATURES))(offline, tb, ep1)


def _entry_session_advance_leafspine():
    """The same while_loop block on a leaf-spine slab (Lf link leaves
    present, the link admission/WC machinery compiled in) — the
    topology-pinned pool's hot path."""
    from repro.fabric.jax_engine import _run_session_block

    tb, _, ep_rows, state = _canonical_slab(leaf_links=LF)
    ne = np.full((B,), 4.0, np.float32)
    return jax.make_jaxpr(
        lambda s, t, e, n, m: _run_session_block(
            s, t, e, n, m, kernel=None, features=FEATURES))(
        state, tb, ep_rows, ne, np.int32(64))


def _entry_session_advance_sampling():
    """The while_loop block with the non-clairvoyant machinery compiled
    in (pilot leaf + traced clairvoyant switch) — the sampling-pinned
    pool's hot path. The clairvoyant entrypoints above never contain
    these leaves: their manifests staying fixed is the bitwise proof
    that sampling is free when compiled out."""
    from repro.fabric.jax_engine import _run_session_block

    tb, _, ep_rows, state = _canonical_slab(sampling=True)
    ne = np.full((B,), 4.0, np.float32)
    return jax.make_jaxpr(
        lambda s, t, e, n, m: _run_session_block(
            s, t, e, n, m, kernel=None,
            features=FEATURES + (True,)))(
        state, tb, ep_rows, ne, np.int32(64))


def _entry_scatter_rows():
    """The dirty-row upload: one row scattered into the state slab."""
    from repro.fabric.jax_engine import scatter_rows

    _, _, _, state = _canonical_slab()
    idx = np.zeros((1,), np.int32)
    rows = jax.tree_util.tree_map(lambda a: a[:1], state)
    return jax.make_jaxpr(scatter_rows)(state, idx, rows)


def _entry_gather_rows():
    from repro.fabric.jax_engine import gather_rows

    _, _, _, state = _canonical_slab()
    idx = np.zeros((1,), np.int32)
    return jax.make_jaxpr(gather_rows)(state, idx)


ENTRYPOINTS: Dict[str, Callable] = {
    "session_advance": _entry_session_advance,
    "session_advance_leafspine": _entry_session_advance_leafspine,
    "session_advance_sampling": _entry_session_advance_sampling,
    "session_plan_tick": _entry_session_plan_tick,
    "simulate_sweep": _entry_simulate_sweep,
    "scatter_rows": _entry_scatter_rows,
    "gather_rows": _entry_gather_rows,
}


def default_manifest_path() -> Path:
    """`analysis/dispatch_manifest.json` at the repo root (resolved
    relative to the live package so it works from any cwd)."""
    import repro
    src_root = Path(list(repro.__path__)[0]).resolve().parent
    return src_root.parent / "analysis" / "dispatch_manifest.json"


def build_manifest(entrypoints: Optional[Dict[str, Callable]] = None
                   ) -> dict:
    entrypoints = ENTRYPOINTS if entrypoints is None else entrypoints
    entries = {}
    for name, build in sorted(entrypoints.items()):
        jaxpr = build()
        entries[name] = {
            "in_avals": aval_signature(jaxpr.in_avals),
            "primitives": dict(sorted(primitive_counts(jaxpr).items())),
            "callbacks": callback_primitives(jaxpr),
            "f64_sites": f64_sites(jaxpr),
        }
    return {"jax_version": jax.__version__, "entrypoints": entries}


def check_manifest(manifest: dict,
                   entrypoints: Optional[Dict[str, Callable]] = None
                   ) -> List[str]:
    """Gate the CURRENT entrypoints against a committed manifest.
    Returns hard failures; version-mismatched primitive drift is
    reported to stderr as a warning instead."""
    fresh = build_manifest(entrypoints)
    problems: List[str] = []
    same_jax = manifest.get("jax_version") == fresh["jax_version"]
    old_entries = manifest.get("entrypoints", {})
    for name, cur in fresh["entrypoints"].items():
        # hard invariants on the LIVE code, independent of the manifest
        if cur["callbacks"]:
            problems.append(
                f"{name}: host callback primitive(s) in the hot loop: "
                f"{cur['callbacks']}")
        if cur["f64_sites"]:
            problems.append(
                f"{name}: float64 site(s) in the hot loop: "
                f"{cur['f64_sites']}")
        old = old_entries.get(name)
        if old is None:
            problems.append(
                f"{name}: not in the manifest — run `make audit-update` "
                f"and review the diff")
            continue
        if old["in_avals"] != cur["in_avals"]:
            problems.append(
                f"{name}: input signature drift (recompile trigger for "
                f"existing callers)\n"
                + "\n".join(_aval_diff(old["in_avals"],
                                       cur["in_avals"])))
        if old["primitives"] != cur["primitives"]:
            diff = _prim_diff(old["primitives"], cur["primitives"])
            if same_jax:
                problems.append(
                    f"{name}: primitive-count drift\n{diff}")
            else:
                print(f"audit: {name}: primitive counts differ from "
                      f"manifest but jax version changed "
                      f"({manifest.get('jax_version')} -> "
                      f"{fresh['jax_version']}): {diff}",
                      file=sys.stderr)
    for name in old_entries:
        if name not in fresh["entrypoints"]:
            problems.append(
                f"{name}: in the manifest but no longer audited — run "
                f"`make audit-update`")
    return problems


def _prim_diff(old: dict, new: dict) -> str:
    """Per-entrypoint primitive delta, grouped into added / removed /
    count-changed so a reviewer sees WHAT entered the hot loop, not a
    raw manifest dump."""
    added, removed, changed = [], [], []
    for k in sorted(set(old) | set(new)):
        a, b = old.get(k, 0), new.get(k, 0)
        if a == b:
            continue
        if a == 0:
            added.append(f"{k} x{b}")
        elif b == 0:
            removed.append(f"{k} (was x{a})")
        else:
            changed.append(f"{k}: {a} -> {b}")
    out = []
    if added:
        out.append(f"  added:   {', '.join(added)}")
    if removed:
        out.append(f"  removed: {', '.join(removed)}")
    if changed:
        out.append(f"  changed: {', '.join(changed)}")
    return "\n".join(out)


def _aval_diff(old: list, new: list) -> List[str]:
    """Positional input-signature delta: only the argument slots that
    actually drifted, `<absent>` marking arity changes."""
    out = []
    for i in range(max(len(old), len(new))):
        a = old[i] if i < len(old) else "<absent>"
        b = new[i] if i < len(new) else "<absent>"
        if a != b:
            out.append(f"  [{i}] {a} -> {b}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit",
        description="jaxpr-level dispatch audit of the hot entrypoints")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the golden manifest (hard invariants "
                         "still enforced)")
    ap.add_argument("--manifest", type=Path,
                    default=None, help="manifest path override")
    args = ap.parse_args(argv)
    path = args.manifest or default_manifest_path()
    if args.update:
        manifest = build_manifest()
        hard = [p for name, cur in manifest["entrypoints"].items()
                for p in
                ([f"{name}: callbacks {cur['callbacks']}"]
                 if cur["callbacks"] else []) +
                ([f"{name}: f64 {cur['f64_sites']}"]
                 if cur["f64_sites"] else [])]
        if hard:
            for p in hard:
                print(f"audit: REFUSING to bless: {p}", file=sys.stderr)
            return 1
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(manifest, indent=2, sort_keys=True)
                        + "\n")
        print(f"audit: wrote {path}", file=sys.stderr)
        return 0
    if not path.exists():
        print(f"audit: no manifest at {path} — run `make audit-update` "
              f"and commit it", file=sys.stderr)
        return 1
    manifest = json.loads(path.read_text())
    problems = check_manifest(manifest)
    for p in problems:
        print(f"audit: {p}")
    if problems:
        print(f"audit: {len(problems)} problem(s) — review the diff "
              f"above, then bless intended drift with "
              f"`python -m repro.analysis.audit --update` "
              f"(make audit-update)", file=sys.stderr)
        return 1
    print(f"audit: {len(manifest['entrypoints'])} entrypoints clean "
          f"(jax {jax.__version__})", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
