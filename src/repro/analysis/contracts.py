"""Repo-contract rules: slab schemas vs. the machinery that moves them.

These rules are cross-file — they check that the pack/blank/sync
machinery keeps up with the slab NamedTuple schemas — so they run once
per lint invocation against the live `repro` package sources rather
than per scanned file:

* ``slab-leaf-coverage`` — every `TraceBatch` field must be written by
  `pack_row`, `blank_row`, and `empty_batch` (traces/batch.py), and
  every `EngineState` / `CoordState` leaf must be handled by the
  pool's `_blank_state_row` and `_sync_row` (api/pool.py). Catches
  the "added a field, forgot the scatter" class statically: a new
  slab column that the blank/pack/sync paths silently zero or drop.
  `_SYNC_ALLOW` lists the documented exceptions (`t0` is pinned to 0
  for sessions — epochs are re-based host-side — so `_sync_row`
  intentionally never reads it).
* ``api-simulator-import`` — no MODULE-level import of the numpy
  `Simulator` inside `repro.api`: the front door must stay importable
  (and its jax plane usable) without dragging in the reference
  event-loop engine; the numpy branch imports it lazily.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional

from repro.analysis.rules import Finding

__all__ = ["check_contracts", "slab_leaf_coverage",
           "api_simulator_imports"]

# documented per-function exceptions: {function: {field, ...}}
_SYNC_ALLOW = {"_sync_row": {"t0"}}


def _parse(path: Path) -> ast.Module:
    return ast.parse(path.read_text(), filename=str(path))


def _class_fields(tree: ast.Module, cls_name: str) -> List[str]:
    """Annotated field names of a NamedTuple/dataclass class."""
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == cls_name:
            return [stmt.target.id for stmt in node.body
                    if isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)]
    return []


def _func_node(tree: ast.Module, name: str) -> Optional[ast.AST]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == name:
            return node
    return None


def _func_source(tree: ast.Module, src: str,
                 name: str) -> Optional[str]:
    node = _func_node(tree, name)
    return None if node is None else ast.get_source_segment(src, node)


def _positional_ctors(func: Optional[ast.AST]) -> Dict[str, int]:
    """Class constructors called with ONLY positional args inside
    `func`, mapped to their arg count. A complete positional
    construction covers every field of that class: a newly added field
    turns it into a TypeError at the call site, so nothing can be
    silently dropped."""
    out: Dict[str, int] = {}
    if func is None:
        return out
    for node in ast.walk(func):
        if isinstance(node, ast.Call) and not node.keywords and \
                isinstance(node.func, ast.Name) and \
                node.func.id[:1].isupper():
            out[node.func.id] = max(out.get(node.func.id, 0),
                                    len(node.args))
    return out


def _coverage(fields: List[str], cls_name: str, schema_path: str,
              tree: ast.Module, src: str, path: Path,
              func_names: List[str]) -> List[Finding]:
    findings = []
    for fn in func_names:
        seg = _func_source(tree, src, fn)
        if seg is None:
            findings.append(Finding(
                "slab-leaf-coverage", str(path), 1,
                f"expected slab machinery `{fn}` not found"))
            continue
        allow = _SYNC_ALLOW.get(fn, set())
        for field in fields:
            if field in allow:
                continue
            if not re.search(rf"\b{re.escape(field)}\b", seg):
                findings.append(Finding(
                    "slab-leaf-coverage", str(path), 1,
                    f"{cls_name}.{field} ({schema_path}) is not "
                    f"handled by `{fn}`"))
    return findings


def slab_leaf_coverage(src_root: Path) -> List[Finding]:
    """TraceBatch fields vs traces/batch.py machinery; EngineState +
    CoordState leaves vs the pool's blank/sync row paths."""
    findings: List[Finding] = []
    batch_py = src_root / "repro" / "traces" / "batch.py"
    engine_py = src_root / "repro" / "fabric" / "jax_engine.py"
    coord_py = src_root / "repro" / "core" / "jax_coordinator.py"
    pool_py = src_root / "repro" / "api" / "pool.py"

    b_src = batch_py.read_text()
    b_tree = ast.parse(b_src, filename=str(batch_py))
    tb_fields = _class_fields(b_tree, "TraceBatch")
    if not tb_fields:
        return [Finding("slab-leaf-coverage", str(batch_py), 1,
                        "TraceBatch schema not found")]
    findings += _coverage(tb_fields, "TraceBatch", "traces/batch.py",
                          b_tree, b_src, batch_py,
                          ["pack_row", "blank_row", "empty_batch"])

    schemas = [
        ("EngineState", _class_fields(_parse(engine_py), "EngineState"),
         "fabric/jax_engine.py"),
        ("CoordState", _class_fields(_parse(coord_py), "CoordState"),
         "core/jax_coordinator.py"),
    ]
    if not all(fields for _, fields, _ in schemas):
        return findings + [Finding(
            "slab-leaf-coverage", str(engine_py), 1,
            "EngineState/CoordState schema not found")]
    p_src = pool_py.read_text()
    p_tree = ast.parse(p_src, filename=str(pool_py))
    for fn in ("_blank_state_row", "_sync_row"):
        node = _func_node(p_tree, fn)
        seg = _func_source(p_tree, p_src, fn)
        if seg is None:
            findings.append(Finding(
                "slab-leaf-coverage", str(pool_py), 1,
                f"expected slab machinery `{fn}` not found"))
            continue
        allow = _SYNC_ALLOW.get(fn, set())
        ctors = _positional_ctors(node)
        for cls_name, fields, origin in schemas:
            if ctors.get(cls_name, -1) == len(fields):
                continue  # complete positional construction
            for field in fields:
                if field in allow:
                    continue
                if not re.search(rf"\b{re.escape(field)}\b", seg):
                    findings.append(Finding(
                        "slab-leaf-coverage", str(pool_py), 1,
                        f"{cls_name} leaf `{field}` ({origin}) is not "
                        f"handled by `SessionPool.{fn}`"))
    return findings


def api_simulator_imports(src_root: Path) -> List[Finding]:
    """Module-level Simulator imports under repro/api are forbidden —
    the lazy function-scoped import of the numpy branch is the
    sanctioned pattern."""
    findings = []
    for path in sorted((src_root / "repro" / "api").glob("*.py")):
        tree = ast.parse(path.read_text(), filename=str(path))
        for node in tree.body:  # module level only
            names = []
            if isinstance(node, ast.ImportFrom):
                names = [a.name for a in node.names]
            elif isinstance(node, ast.Import):
                names = [a.name for a in node.names]
            if any(n == "Simulator" or n.endswith(".engine")
                   for n in names):
                findings.append(Finding(
                    "api-simulator-import", str(path), node.lineno,
                    "module-level import of the numpy Simulator in "
                    "repro.api (import it inside the numpy branch)"))
    return findings


def check_contracts(src_root: Path) -> List[Finding]:
    return slab_leaf_coverage(src_root) + api_simulator_imports(src_root)
