"""stdlib-`ast` lint rules for JAX trace safety and repo hygiene.

The rule catalogue (DESIGN.md §9):

* ``traced-np-call``       — `np.*` / `jax.device_get` call inside a
  traced function: a host round-trip (or a silent constant-folding
  surprise) in code that compiles into the hot loop.
* ``cast-in-trace``        — `float()` / `int()` / `bool()` / `.item()`
  inside a traced function: forces a concrete value out of a tracer
  (ConcretizationError at best, a device sync at worst).
* ``branch-on-tracer``     — Python `if`/`while` whose condition
  mentions a value derived from `jnp`/`lax` ops inside a traced
  function: data-dependent Python control flow cannot trace.
* ``implicit-dtype``       — `jnp.array`/`jnp.asarray`/`jnp.full`
  without an explicit dtype (or an `np.float64`/`jnp.float64`
  literal) in the hot modules (`fabric/`, `core/jax_coordinator`):
  the input's dtype leaks into the f32 slab (the PR-4 drift class).
* ``host-pull-unaccounted``— a device value crossing to host (`np.
  asarray`/`np.array`/`jax.device_get`/`float`/`int`/`bool`,
  including via `tree_map(np.asarray, …)`) in a method of an
  io-counted class (`SessionPool`) that never touches `self.io`, or
  in a `session_*` host entrypoint of `fabric.jax_engine`: every
  warm-path transfer must be io-accounted or explicitly suppressed.
* ``unused-import``        — module-level import never referenced.
* ``unused-variable``      — function-local name assigned and never
  read.

Traced scope is computed per module: seeds are functions decorated
with `jit`/`pmap`/`vmap` (bare, called, or via `functools.partial`)
or passed by name to `lax.scan`/`while_loop`/`cond`/`vmap`/`pmap`/…;
lexically nested defs inherit the scope; the set closes over the
intra-module call graph (a function called from traced code is
traced). Cross-module edges are not followed — each hot module's
traced kernels are reached from a jit seed in the same module.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Set

__all__ = ["Finding", "lint_module", "traced_functions"]


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    msg: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.msg}"


TRACE_DECORATORS = {"jit", "pmap", "vmap"}
# callables that trace a function passed to them by name
TRACE_CONSUMERS = {"scan", "while_loop", "cond", "switch", "fori_loop",
                   "associative_scan", "vmap", "pmap", "jit", "grad",
                   "value_and_grad", "checkpoint", "remat",
                   "custom_jvp", "custom_vjp"}
# attribute accesses that yield host metadata, not device values —
# they break the host-pull taint walk (reading .shape is not a pull)
_META_ATTRS = {"shape", "dtype", "ndim", "weak_type", "sharding",
               "aval", "nbytes", "itemsize"}
# device attrs / device-returning calls of the io-counted pool class
_POOL_DEVICE_ATTRS = {"_state", "_tb", "_ctl", "_tb_disp", "_ep_disp",
                      "_ep_stack"}
_POOL_DEVICE_CALLS = {"_state_flat", "_dispatch_slab", "gather_rows",
                      "scatter_rows", "session_advance",
                      "session_plan_tick"}
_ENGINE_DEVICE_CALLS = {"_run_session_block", "_pmapped_session_block"}
_PULL_FUNCS = {"asarray", "array", "device_get"}
_NP_ROOTS = {"np", "numpy"}


def _leaf_name(node) -> Optional[str]:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _root_name(node) -> Optional[str]:
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def _is_np_pull(func) -> bool:
    """`np.asarray` / `np.array` / `jax.device_get` as a callee."""
    leaf, root = _leaf_name(func), _root_name(func)
    if leaf == "device_get":
        return True
    return root in _NP_ROOTS and leaf in _PULL_FUNCS


# ---- traced-scope detection ----------------------------------------------

class _Funcs(ast.NodeVisitor):
    """Collect every function with its enclosing-function chain."""

    def __init__(self):
        self.by_name: Dict[str, List[ast.AST]] = {}
        self.parents: Dict[ast.AST, Optional[ast.AST]] = {}
        self._stack: List[ast.AST] = []

    def _visit_def(self, node):
        self.by_name.setdefault(node.name, []).append(node)
        self.parents[node] = self._stack[-1] if self._stack else None
        self._stack.append(node)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _visit_def
    visit_AsyncFunctionDef = _visit_def


def _decorator_is_traced(dec) -> bool:
    if _leaf_name(dec) in TRACE_DECORATORS:
        return True
    if isinstance(dec, ast.Call):
        f = _leaf_name(dec.func)
        if f in TRACE_DECORATORS:
            return True
        if f == "partial" and dec.args and \
                _leaf_name(dec.args[0]) in TRACE_DECORATORS:
            return True
    return False


def traced_functions(tree: ast.AST) -> Set[ast.AST]:
    """The set of function nodes whose bodies run under a jax trace."""
    funcs = _Funcs()
    funcs.visit(tree)
    traced: Set[ast.AST] = set()
    for name, nodes in funcs.by_name.items():
        for node in nodes:
            if any(_decorator_is_traced(d) for d in node.decorator_list):
                traced.add(node)
    # functions handed by name to scan/while_loop/vmap/... anywhere
    for call in ast.walk(tree):
        if not (isinstance(call, ast.Call)
                and _leaf_name(call.func) in TRACE_CONSUMERS):
            continue
        handed = list(call.args) + [kw.value for kw in call.keywords]
        for arg in handed:
            if isinstance(arg, ast.Name) and arg.id in funcs.by_name:
                traced.update(funcs.by_name[arg.id])
    # fixpoint: lexical nesting + intra-module call graph
    changed = True
    while changed:
        changed = False
        for name, nodes in funcs.by_name.items():
            for node in nodes:
                if node in traced:
                    continue
                parent = funcs.parents[node]
                if parent is not None and parent in traced:
                    traced.add(node)
                    changed = True
        for node in list(traced):
            for call in ast.walk(node):
                if not isinstance(call, ast.Call):
                    continue
                callee = None
                if isinstance(call.func, ast.Name):
                    callee = call.func.id
                elif isinstance(call.func, ast.Attribute) and \
                        _root_name(call.func) in ("self", "cls"):
                    callee = call.func.attr
                if callee in funcs.by_name:
                    for cand in funcs.by_name[callee]:
                        if cand not in traced:
                            traced.add(cand)
                            changed = True
    return traced


def _own_nodes(func: ast.AST):
    """Walk `func`'s body without descending into nested defs."""
    todo = list(ast.iter_child_nodes(func))
    while todo:
        node = todo.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef,
                                 ast.AsyncFunctionDef, ast.Lambda)):
            todo.extend(ast.iter_child_nodes(node))


# ---- taint helpers -------------------------------------------------------

def _mentions(node, tainted: Set[str], device_calls: Set[str]) -> bool:
    """Does this expression reference a tainted name / device attr /
    device-returning call? `.shape`-style metadata reads do not count."""
    if isinstance(node, ast.Attribute):
        if node.attr in _META_ATTRS:
            return False
        if _root_name(node) == "self" and \
                f"self.{node.attr}" in tainted:
            return True
    if isinstance(node, ast.Name) and node.id in tainted:
        return True
    if isinstance(node, ast.Call) and \
            _leaf_name(node.func) in device_calls:
        return True
    return any(_mentions(c, tainted, device_calls)
               for c in ast.iter_child_nodes(node))


def _propagate_taint(func, tainted: Set[str],
                     device_calls: Set[str]) -> Set[str]:
    """Close `tainted` over simple assignments inside `func`."""
    for _ in range(4):  # tiny fixpoint; real chains are 1-2 deep
        grew = False
        for node in ast.walk(func):
            if not isinstance(node, ast.Assign):
                continue
            if not _mentions(node.value, tainted, device_calls):
                continue
            targets = []
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    targets.append(tgt.id)
                elif isinstance(tgt, (ast.Tuple, ast.List)):
                    targets.extend(e.id for e in tgt.elts
                                   if isinstance(e, ast.Name))
            for name in targets:
                if name not in tainted:
                    tainted.add(name)
                    grew = True
        if not grew:
            break
    return tainted


def _pull_sites(func, tainted: Set[str],
                device_calls: Set[str]) -> List[ast.Call]:
    """Calls inside `func` that pull a tainted device value to host."""
    sites = []
    for node in ast.walk(func):
        if not isinstance(node, ast.Call):
            continue
        hit = False
        if _is_np_pull(node.func) or (
                isinstance(node.func, ast.Name)
                and node.func.id in ("float", "int", "bool")):
            hit = any(_mentions(a, tainted, device_calls)
                      for a in node.args)
        elif _leaf_name(node.func) == "tree_map" and any(
                _is_np_pull(a) for a in node.args):
            hit = any(_mentions(a, tainted, device_calls)
                      for a in node.args if not _is_np_pull(a))
        if hit:
            sites.append(node)
    return sites


# ---- per-module rules ----------------------------------------------------

def _check_traced_bodies(tree, path, findings) -> None:
    traced = traced_functions(tree)
    for func in traced:
        # taint for branch-on-tracer: names derived from jnp/lax ops
        tainted: Set[str] = set()

        def from_jnp(node) -> bool:
            return any(isinstance(c, ast.Call)
                       and _root_name(c.func) in ("jnp", "lax")
                       for c in ast.walk(node))

        for _ in range(4):
            grew = False
            for node in _own_nodes(func):
                if not isinstance(node, ast.Assign):
                    continue
                if not (from_jnp(node.value)
                        or _mentions(node.value, tainted, set())):
                    continue
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and \
                            tgt.id not in tainted:
                        tainted.add(tgt.id)
                        grew = True
            if not grew:
                break
        for node in _own_nodes(func):
            if isinstance(node, ast.Call):
                leaf, root = _leaf_name(node.func), _root_name(node.func)
                if root in _NP_ROOTS or leaf == "device_get":
                    findings.append(Finding(
                        "traced-np-call", path, node.lineno,
                        f"host call `{root or ''}.{leaf}` inside traced "
                        f"function `{func.name}`"))
                elif isinstance(node.func, ast.Name) and \
                        node.func.id in ("float", "int", "bool") and \
                        node.args:
                    findings.append(Finding(
                        "cast-in-trace", path, node.lineno,
                        f"`{node.func.id}()` concretizes a value inside "
                        f"traced function `{func.name}`"))
                elif leaf == "item" and not node.args:
                    findings.append(Finding(
                        "cast-in-trace", path, node.lineno,
                        f"`.item()` concretizes a value inside traced "
                        f"function `{func.name}`"))
            elif isinstance(node, (ast.If, ast.While)):
                if _mentions(node.test, tainted, set()):
                    kw = "if" if isinstance(node, ast.If) else "while"
                    findings.append(Finding(
                        "branch-on-tracer", path, node.lineno,
                        f"Python `{kw}` on a jnp-derived value inside "
                        f"traced function `{func.name}`"))


_DTYPE_SCOPED = re.compile(r"(/|^)fabric/|(/|^)core/jax_coordinator\.py$")


def _check_implicit_dtype(tree, path, findings) -> None:
    if not _DTYPE_SCOPED.search(path.replace("\\", "/")):
        return
    # f64 literals are flagged only inside TRACED functions — host
    # result paths deliberately reconstruct absolute times in f64
    # (DESIGN.md §3); inside a trace an f64 request either promotes
    # the slab or silently downgrades, both wrong.
    for func in traced_functions(tree):
        for node in ast.walk(func):
            if isinstance(node, ast.Attribute) and \
                    node.attr == "float64" and \
                    _root_name(node) in ("np", "numpy", "jnp"):
                findings.append(Finding(
                    "implicit-dtype", path, node.lineno,
                    "float64 literal inside a traced function of an "
                    "f32 hot module"))
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _root_name(node.func) == "jnp"):
            continue
        leaf = _leaf_name(node.func)
        has_dtype_kw = any(kw.arg == "dtype" for kw in node.keywords)
        if leaf in ("array", "asarray") and \
                len(node.args) < 2 and not has_dtype_kw:
            findings.append(Finding(
                "implicit-dtype", path, node.lineno,
                f"`jnp.{leaf}` without an explicit dtype lets the "
                f"input's dtype leak into the f32 slab"))
        elif leaf == "full" and len(node.args) < 3 and not has_dtype_kw:
            findings.append(Finding(
                "implicit-dtype", path, node.lineno,
                "`jnp.full` without an explicit dtype"))


def _check_host_pulls(tree, path, findings) -> None:
    posix = path.replace("\\", "/")
    # (a) methods of io-counted classes (SessionPool): any device pull
    # in a method that never references `self.io` is unaccounted.
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        has_io = any(isinstance(n, ast.Attribute) and n.attr == "io"
                     and _root_name(n) == "self"
                     for n in ast.walk(cls))
        if not has_io:
            continue
        for meth in cls.body:
            if not isinstance(meth, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if meth.name == "__init__":
                continue
            accounted = any(
                isinstance(n, ast.Attribute) and n.attr == "io"
                and _root_name(n) == "self"
                for n in ast.walk(meth))
            if accounted:
                continue
            tainted = {f"self.{a}" for a in _POOL_DEVICE_ATTRS}
            tainted = _propagate_taint(meth, tainted,
                                       _POOL_DEVICE_CALLS)
            seen: Set[int] = set()
            for site in _pull_sites(meth, tainted, _POOL_DEVICE_CALLS):
                if site.lineno in seen:
                    continue
                seen.add(site.lineno)
                findings.append(Finding(
                    "host-pull-unaccounted", path, site.lineno,
                    f"device pull in `{cls.name}.{meth.name}` without "
                    f"`self.io` accounting"))
    # (b) the engine's host-side session_* entrypoints: pulls on the
    # jitted block results are the warm serving path's only host syncs
    # and must be suppressed (with a reason) or removed.
    if not posix.endswith("fabric/jax_engine.py"):
        return
    traced = traced_functions(tree)
    for func in ast.walk(tree):
        if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if func in traced or not func.name.startswith("session_"):
            continue
        tainted = {a.arg for a in func.args.args if a.arg == "state"}
        tainted = _propagate_taint(func, tainted, _ENGINE_DEVICE_CALLS)
        seen = set()
        for site in _pull_sites(func, tainted, _ENGINE_DEVICE_CALLS):
            if site.lineno in seen:
                continue
            seen.add(site.lineno)
            findings.append(Finding(
                "host-pull-unaccounted", path, site.lineno,
                f"host sync on a device value in session entrypoint "
                f"`{func.name}`"))


def _check_unused_imports(tree, src, path, findings) -> None:
    import_stmts = [n for n in tree.body
                    if isinstance(n, (ast.Import, ast.ImportFrom))]
    if not import_stmts:
        return
    lines = src.splitlines()
    import_lines = set()
    for node in import_stmts:
        end = getattr(node, "end_lineno", node.lineno)
        import_lines.update(range(node.lineno, end + 1))
    rest = "\n".join(line for i, line in enumerate(lines, 1)
                     if i not in import_lines)
    for node in import_stmts:
        if isinstance(node, ast.ImportFrom) and \
                node.module == "__future__":
            continue
        for alias in node.names:
            if alias.name == "*":
                continue
            bound = alias.asname or alias.name.split(".")[0]
            if not re.search(rf"\b{re.escape(bound)}\b", rest):
                findings.append(Finding(
                    "unused-import", path, node.lineno,
                    f"`{bound}` imported but never used"))


def _check_unused_variables(tree, path, findings) -> None:
    for func in ast.walk(tree):
        if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        declared: Set[str] = set()
        for node in ast.walk(func):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                declared.update(node.names)
        loads = {n.id for n in ast.walk(func)
                 if isinstance(n, ast.Name)
                 and isinstance(n.ctx, (ast.Load, ast.Del))}
        for node in _own_nodes(func):
            if not (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                continue
            name = node.targets[0].id
            if name.startswith("_") or name in declared or \
                    name in loads:
                continue
            findings.append(Finding(
                "unused-variable", path, node.lineno,
                f"`{name}` assigned in `{func.name}` but never read"))


def lint_module(path: str, src: str) -> List[Finding]:
    """All module-local findings for one source file (unsuppressed —
    `repro.analysis.lint` applies the `# saath: lint-ok` filter)."""
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as exc:
        return [Finding("syntax-error", path, exc.lineno or 1,
                        str(exc.msg))]
    findings: List[Finding] = []
    _check_traced_bodies(tree, path, findings)
    _check_implicit_dtype(tree, path, findings)
    _check_host_pulls(tree, path, findings)
    _check_unused_imports(tree, src, path, findings)
    _check_unused_variables(tree, path, findings)
    findings.sort(key=lambda f: (f.line, f.rule))
    return findings
