"""Trace-safety static analysis + dispatch auditing (DESIGN.md §9–§10).

The serving plane's performance story rests on invariants nothing used
to check: one dispatch per fleet advance, zero clean-row uploads, no
host round-trips inside traced scope, no f64 drift into the f32 slab.
This package makes those contracts machine-checked:

* ``repro.analysis.lint``  — stdlib-`ast` lint: JAX trace-safety rules
  (host calls / Python casts / Python branches inside traced scope,
  implicit-dtype conversions), repo-contract rules (TraceBatch /
  EngineState leaf coverage in the pack/scatter machinery, no
  module-level Simulator imports in `repro.api`, unaccounted host
  pulls in the pool), and hygiene rules (unused imports / variables).
  ``python -m repro.analysis.lint src tests``; suppressions are
  ``# saath: lint-ok(rule): reason`` comments.
* ``repro.analysis.audit`` — traces the hot entrypoints to jaxprs,
  asserts zero host callbacks and zero f64 casts in the hot loop, and
  diffs jit signatures + primitive counts against the committed golden
  ``analysis/dispatch_manifest.json`` (``make audit`` /
  ``make audit-update``).
* ``repro.analysis.sanitize`` — runtime sanitizers:
  `assert_no_recompiles` / `assert_no_transfers` context managers
  (jit-cache-miss counting, transfer-guard enforcement with
  `accounted_transfer` carve-outs for the pool's io-counted paths).
* ``repro.analysis.coherence`` — the slab coherence checker: the
  async serving plane's cache protocol (dirty flags, deferred ctl
  handle, host mirrors, folded dispatch caches, the io ledger) as a
  machine-readable declaration, typestate-checked per method against
  the committed golden ``analysis/coherence_manifest.json``
  (``make coherence`` / ``make coherence-update``); ``--selftest``
  re-checks six seeded single-line coherence bugs.
* ``repro.analysis.explore`` — the interleaving race detector:
  deterministic random schedules over the full pool API, replayed on
  async sharded pools against the blocking 1-shard oracle with
  bitwise comparison at sync points; divergences print a reproducer.
"""
from repro.analysis.sanitize import (RecompileError, accounted_transfer,
                                     assert_no_recompiles,
                                     assert_no_transfers)

__all__ = ["assert_no_recompiles", "assert_no_transfers",
           "accounted_transfer", "RecompileError"]
