"""Runtime sanitizers for the serving plane's dispatch contracts.

Two context managers turn benchmark folklore into asserted properties:

* ``assert_no_recompiles()`` — counts jit cache misses inside the
  scope (via ``jax_log_compiles`` + a logging handler on jax's
  compile logger) and raises `RecompileError` when any executable is
  (re)built. This is how "a heterogeneous tenant joining a
  pinned-features pool never recompiles the fleet" is tested
  (tests/test_pool_sharded.py), instead of trusting wall-clock.
* ``assert_no_transfers()`` — arms jax's transfer guard at
  ``disallow_explicit`` for host-to-device transfers, so ANY upload —
  implicit numpy-argument commits and explicit `device_put` /
  `jnp.asarray` alike — raises at the offending call site unless it
  happens inside an `accounted_transfer()` carve-out. The
  `SessionPool` wraps exactly its io-counted paths (dirty-row
  scatters, rebuilds, dispatch argument commits, ctl reads) in
  `accounted_transfer`, which is what upgrades "zero clean-row
  uploads" from a `pool.io` byte-counter claim to a guard-enforced
  invariant: a transfer the pool forgot to account trips the guard.

Device-to-host reads are zero-copy on the CPU backend (the guard
cannot observe them there), so download-side contracts stay on the
`pool.io` counters; the upload side — the expensive direction for the
slab — is guard-enforced everywhere.
"""
from __future__ import annotations

import contextlib
import logging

import jax

__all__ = ["RecompileError", "assert_no_recompiles",
           "assert_no_transfers", "accounted_transfer"]

# jax's compile log line ("Compiling <name> with global shapes ...") is
# emitted on this logger when jax_log_compiles is on; cached dispatches
# emit nothing, so counting these records counts cache misses exactly.
_COMPILE_LOGGERS = ("jax._src.interpreters.pxla",)
_COMPILE_PREFIX = "Compiling "


class RecompileError(AssertionError):
    """An executable was compiled inside an assert_no_recompiles scope."""


class _CompileCounter(logging.Handler):
    def __init__(self):
        super().__init__(level=logging.DEBUG)
        self.compiles: list = []

    def emit(self, record: logging.LogRecord) -> None:
        msg = record.getMessage()
        if msg.startswith(_COMPILE_PREFIX):
            self.compiles.append(msg.split(" with global", 1)[0]
                                 [len(_COMPILE_PREFIX):])


class _RecompileScope:
    """Handle yielded by `assert_no_recompiles`: `.compiles` lists the
    names of executables built so far inside the scope."""

    def __init__(self, handler: _CompileCounter):
        self._handler = handler

    @property
    def compiles(self) -> list:
        return list(self._handler.compiles)


@contextlib.contextmanager
def assert_no_recompiles(allow: int = 0):
    """Fail with `RecompileError` if more than `allow` executables are
    compiled inside the scope. Warm the code path first — the sanitizer
    asserts cache HITS, it does not distinguish first compiles from
    recompiles. Yields a scope whose `.compiles` lists what was built.
    """
    handler = _CompileCounter()
    loggers = [logging.getLogger(name) for name in _COMPILE_LOGGERS]
    old_levels = [lg.level for lg in loggers]
    prev = jax.config.jax_log_compiles
    jax.config.update("jax_log_compiles", True)
    for lg in loggers:
        # compile lines log at WARNING when jax_log_compiles is on;
        # drop the level anyway in case a future jax demotes them
        if lg.level > logging.DEBUG:
            lg.setLevel(logging.DEBUG)
        lg.addHandler(handler)
    try:
        yield _RecompileScope(handler)
        if len(handler.compiles) > allow:
            raise RecompileError(
                f"{len(handler.compiles)} executable(s) compiled inside "
                f"an assert_no_recompiles(allow={allow}) scope: "
                f"{handler.compiles}")
    finally:
        for lg, lv in zip(loggers, old_levels):
            lg.removeHandler(handler)
            lg.setLevel(lv)
        jax.config.update("jax_log_compiles", prev)


@contextlib.contextmanager
def assert_no_transfers():
    """Disallow ALL host-to-device transfers (implicit argument commits
    and explicit device_put/asarray alike) inside the scope, except
    those wrapped in `accounted_transfer()`. Violations raise jax's
    transfer-guard error at the offending call site — the traceback
    names the exact unaccounted upload."""
    with jax.transfer_guard_host_to_device("disallow_explicit"):
        yield


@contextlib.contextmanager
def accounted_transfer():
    """Carve-out for io-accounted host-device crossings: re-allows
    transfers inside an `assert_no_transfers` scope. The `SessionPool`
    wraps exactly the statements its `pool.io` counters cover, so the
    sanitizer's reach is "everything the accounting misses"."""
    with jax.transfer_guard("allow"):
        yield
