"""Mamba-2 (SSD) mixer block — attention-free sequence mixing.

Prefill/train use the chunked SSD algorithm (pure-jnp mirror of
kernels/ssd_scan.py, which is the TPU Pallas fast path); decode is the
O(1) single-step recurrence against a carried (H, Dh, N) state plus a
(k-1)-deep causal-conv window.

Cache layout: {"conv": (B, k-1, C_conv), "ssd": (B, H, Dh, N)}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Parallelism, rms_norm, shard


def ssd_chunked_jnp(x, dt, a, b, c, *, init_state=None, lc: int = 128):
    """Chunked SSD, same contract as kernels.ref.ssd_ref (but O(L/lc)
    sequential steps). x: (B,L,H,Dh); dt: (B,L,H); a: (H,);
    b,c: (B,L,G,N)."""
    B, L, H, Dh = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    pad = (-L) % lc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // lc

    bh = jnp.repeat(b, rep, axis=2)
    ch = jnp.repeat(c, rep, axis=2)

    def chunks(t, shape):  # (B, Lp, ...) -> (nc, B, lc, ...)
        return t.reshape((B, nc, lc) + shape).transpose(
            (1, 0, 2) + tuple(range(3, 3 + len(shape))))

    xs = (chunks(x.astype(jnp.float32), (H, Dh)),
          chunks(dt.astype(jnp.float32), (H,)),
          chunks(bh.astype(jnp.float32), (H, N)),
          chunks(ch.astype(jnp.float32), (H, N)))

    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((B, H, Dh, N), jnp.float32))

    tri = jnp.tril(jnp.ones((lc, lc), bool))

    def step(s, inp):
        xc, dtc, bc, cc = inp          # (B,lc,H,*)
        dta = dtc * a                   # (B,lc,H)
        cum = jnp.cumsum(dta, axis=1)   # inclusive
        diff = cum[:, :, None, :] - cum[:, None, :, :]      # (B,lc,lc,H)
        decay = jnp.where(tri[None, :, :, None],
                          jnp.exp(jnp.where(tri[None, :, :, None], diff,
                                            0.0)), 0.0)
        g = jnp.einsum("bthn,buhn->btuh", cc, bc)
        m = g * decay * dtc[:, None, :, :]
        y = jnp.einsum("btuh,buhd->bthd", m, xc)
        y += jnp.exp(cum)[..., None] * jnp.einsum(
            "bthn,bhdn->bthd", cc, s)
        cl = cum[:, -1]                 # (B,H)
        wgt = jnp.exp(cl[:, None] - cum) * dtc              # (B,lc,H)
        s_new = jnp.exp(cl)[..., None, None] * s + jnp.einsum(
            "bthd,bthn->bhdn", xc * wgt[..., None], bc)
        return s_new, y

    s_fin, ys = jax.lax.scan(step, s0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Lp, H, Dh)[:, :L]
    return y.astype(x.dtype), s_fin


def ssd_decode_step(x, dt, a, b, c, state):
    """One-token recurrence. x: (B,H,Dh); dt: (B,H); b,c: (B,G,N);
    state: (B,H,Dh,N). Returns (y (B,H,Dh), state')."""
    H = x.shape[1]
    rep = H // b.shape[1]
    bh = jnp.repeat(b, rep, axis=1).astype(jnp.float32)
    ch = jnp.repeat(c, rep, axis=1).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * a)[..., None, None]
    state = decay * state + dtf[..., None, None] * (
        xf[..., None] * bh[:, :, None, :])
    y = jnp.einsum("bhdn,bhn->bhd", state, ch)
    return y.astype(x.dtype), state


def mamba_init(pf, cfg, prefix: str, layers: int):
    d = cfg.d_model
    di = cfg.ssm_inner            # usually 2*d
    H, Dh = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    kconv = cfg.ssm_conv
    c_conv = di + 2 * G * N
    proj_out = 2 * di + 2 * G * N + H
    return {
        "in_proj": pf.dense(f"{prefix}.in_proj", (layers, d, proj_out),
                            (None, "embed", "ssm_heads"), fan_in=d),
        "conv_w": pf.dense(f"{prefix}.conv_w", (layers, kconv, c_conv),
                           (None, None, "ssm_heads"), fan_in=kconv),
        "conv_b": pf.zeros(f"{prefix}.conv_b", (layers, c_conv),
                           (None, "ssm_heads")),
        "a_log": pf.zeros(f"{prefix}.a_log", (layers, H), (None,
                                                           "ssm_heads")),
        "dt_bias": pf.zeros(f"{prefix}.dt_bias", (layers, H),
                            (None, "ssm_heads")),
        "d_skip": pf.zeros(f"{prefix}.d_skip", (layers, H),
                           (None, "ssm_heads")),
        "norm": pf.zeros(f"{prefix}.norm", (layers, di),
                         (None, "ssm_heads")),
        "out_proj": pf.dense(f"{prefix}.out_proj", (layers, di, d),
                             (None, "ssm_heads", "embed"), fan_in=di),
    }


def mamba_apply(cfg, w, x, *, cache=None, par=Parallelism(None),
                lc: int = 128):
    """x: (B,S,d). cache (decode, S=1): {"conv","ssd"}; prefill with
    cache=dict(...) template fills it. Returns (out, new_cache)."""
    B, S, d = x.shape
    di, H, Dh = cfg.ssm_inner, cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    kconv = cfg.ssm_conv
    c_conv = di + 2 * G * N

    zxbcdt = jnp.einsum("bsd,dp->bsp", x, w["in_proj"])
    zxbcdt = shard(zxbcdt, ("batch", None, "ssm_heads"), par)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + c_conv]
    dt_raw = zxbcdt[..., di + c_conv:]
    a = -jnp.exp(w["a_log"].astype(jnp.float32))

    if S == 1 and cache is not None and "ssd" in cache:
        window = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,k,C)
        xbc_c = (window * w["conv_w"][None]).sum(1) + w["conv_b"]
        xbc_c = jax.nn.silu(xbc_c)[:, None, :]
        new_conv = window[:, 1:]
        xs = xbc_c[..., :di].reshape(B, H, Dh)
        bs = xbc_c[..., di:di + G * N].reshape(B, G, N)
        cs = xbc_c[..., di + G * N:].reshape(B, G, N)
        dt = jax.nn.softplus(dt_raw[:, 0] + w["dt_bias"])      # (B,H)
        y, s_new = ssd_decode_step(xs, dt, a, bs, cs, cache["ssd"])
        y = y + w["d_skip"][:, None] * xs
        y = y.reshape(B, 1, di)
        y = rms_norm(y, w["norm"]) * jax.nn.silu(z)
        out = jnp.einsum("bsp,pd->bsd", y, w["out_proj"])
        return out, {"conv": new_conv, "ssd": s_new}

    # train / prefill: causal depthwise conv via padded window sum
    pads = jnp.zeros((B, kconv - 1, c_conv), xbc.dtype)
    xp = jnp.concatenate([pads, xbc], axis=1)
    conv = sum(xp[:, i:i + S] * w["conv_w"][i] for i in range(kconv))
    conv = jax.nn.silu(conv + w["conv_b"])
    xs = conv[..., :di].reshape(B, S, H, Dh)
    bs = conv[..., di:di + G * N].reshape(B, S, G, N)
    cs = conv[..., di + G * N:].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt_raw + w["dt_bias"])                # (B,S,H)

    y, s_fin = ssd_chunked_jnp(xs, dt, a, bs, cs, lc=lc)
    y = y + w["d_skip"][None, None, :, None] * xs
    y = y.reshape(B, S, di)
    y = rms_norm(y, w["norm"]) * jax.nn.silu(z)
    y = shard(y, ("batch", None, "ssm_heads"), par)
    out = jnp.einsum("bsp,pd->bsd", y, w["out_proj"])

    new_cache = None
    if cache is not None:
        # last (kconv-1) raw xbc values feed the next decode step's window
        new_cache = {"conv": xp[:, -(kconv - 1):], "ssd": s_fin}
    return out, new_cache
