"""Attention mixers: GQA (+RoPE, qk-norm) and DeepSeek-V2 MLA.

Layout convention: activations (B, S, d); q/k/v (B, S, H, Dh).

The prefill path is a chunked online-softmax (pure jnp lax.scan — the
oracle of kernels/flash_attention.py; on TPU the Pallas kernel is the
fast path via kernels.ops). Chunking bounds the score materialization to
(B, H, S, block) so 32k prefill fits per-device memory.

Decode (S=1) attends the full cache directly; MLA decode uses the
absorbed/latent form so the cache stays compressed (kv_lora + rope dims
per token, the paper's ~8x KV saving).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Parallelism, rms_norm, rope, shard

NEG_INF = -1e30


def _gqa_scores_einsum(q, k):  # q: (B,Sq,Hkv,G,D), k: (B,bk,Hkv,D)
    return jnp.einsum("bshgd,bthd->bhgst", q, k)


def jnp_flash(q, k, v, *, causal: bool, q_offset, block: int = 1024,
              par: Parallelism = Parallelism(None)):
    """q: (B,Sq,H,D); k,v: (B,Skv,Hkv,D). q_offset: absolute position of
    q[0] (int or traced scalar). Returns (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]            # may differ from D (MLA prefill)
    G = H // Hkv
    scale = D ** -0.5
    qg = (q * scale).reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    blocks = -(-Skv // block)
    pad = blocks * block - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, blocks, block, Hkv, k.shape[-1]).transpose(
        1, 0, 2, 3, 4)
    vb = v.reshape(B, blocks, block, Hkv, Dv).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, inp):
        m, l, acc = carry
        idx, k_c, v_c = inp
        s = _gqa_scores_einsum(qg, k_c.astype(jnp.float32))
        k_pos = idx * block + jnp.arange(block)
        mask = (k_pos < Skv)[None, None, None, None, :]
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])[
                None, None, None, :, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = alpha * l + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgst,bthd->bshgd", p, v_c.astype(jnp.float32)
        ).transpose(0, 2, 3, 1, 4)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(blocks), kb, vb))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv)
    return o.astype(q.dtype)


def decode_attend(q, k_cache, v_cache, kv_len=None):
    """q: (B,1,H,D); caches: (B,Smax,Hkv,D). kv_len: valid prefix length
    (static or traced). Full-cache single-step attention."""
    B, _, H, D = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = D ** -0.5
    qg = (q * scale).reshape(B, Hkv, G, D)
    # Multiply-reduce instead of dot: a dot would force XLA to
    # materialize a transposed (and on CPU, f32) copy of the ENTIRE
    # cache per layer (measured: 2x cache bytes of pure copy traffic —
    # §Perf decode iteration). The reductions run over the contiguous
    # trailing dims, stream the cache once, and are VPU work on TPU
    # (decode attention is bandwidth-bound; flash-decoding style).
    kf = k_cache[:, :, :, None, :].astype(jnp.float32)   # (B,T,Hkv,1,D)
    s = (kf * qg[:, None, :, :, :].astype(jnp.float32)).sum(-1)
    s = s.transpose(0, 2, 3, 1)                           # (B,Hkv,G,T)
    if kv_len is not None:
        valid = jnp.arange(Smax) < kv_len
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    pt = p.transpose(0, 3, 1, 2)[..., None]               # (B,T,Hkv,G,1)
    vf = v_cache[:, :, :, None, :].astype(jnp.float32)    # (B,T,Hkv,1,D)
    o = (pt * vf).sum(1)                                  # (B,Hkv,G,D)
    return o.reshape(B, 1, H, D).astype(q.dtype)


# ----------------------------------------------------------------- GQA block
def gqa_init(pf, cfg, prefix: str, layers: int):
    d, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": pf.dense(f"{prefix}.wq", (layers, d, H, Dh),
                       (None, "embed", "heads", None), fan_in=d),
        "wk": pf.dense(f"{prefix}.wk", (layers, d, Hkv, Dh),
                       (None, "embed", "kv_heads", None), fan_in=d),
        "wv": pf.dense(f"{prefix}.wv", (layers, d, Hkv, Dh),
                       (None, "embed", "kv_heads", None), fan_in=d),
        "wo": pf.dense(f"{prefix}.wo", (layers, H, Dh, d),
                       (None, "heads", None, "embed"), fan_in=H * Dh),
    }
    if cfg.qk_norm:
        p["qnorm"] = pf.zeros(f"{prefix}.qnorm", (layers, Dh), (None, None))
        p["knorm"] = pf.zeros(f"{prefix}.knorm", (layers, Dh), (None, None))
    return p


def gqa_apply(cfg, w, x, *, positions, cache=None, causal=True,
              kv_len=None, par=Parallelism(None), cross_kv=None):
    """One attention layer. cache: dict(k,v (B,Smax,Hkv,Dh)) for decode
    (x is (B,1,d)); cross_kv: precomputed (k,v) for cross-attention.
    Returns (out, new_cache)."""
    B, S, d = x.shape
    # TP layout: shard heads when divisible by the model axis; otherwise
    # fall back to context parallelism (shard the query sequence) so GSPMD
    # never pads/all-gathers the padded head dim.
    H = cfg.num_heads
    head_div = par.model_size <= 1 or H % par.model_size == 0
    q_axes = (("batch", None, "heads", None) if head_div
              else ("batch", "seq_tp", None, None))
    q = jnp.einsum("bsd,dhk->bshk", x, w["wq"])
    q = shard(q, q_axes, par)
    if cross_kv is None:
        k = jnp.einsum("bsd,dhk->bshk", x, w["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, w["wv"])
    else:
        k, v = cross_kv
    if cfg.qk_norm:
        q = rms_norm(q, w["qnorm"])
        if cross_kv is None:
            k = rms_norm(k, w["knorm"])
    if cfg.rope_theta and cross_kv is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = cache
    if cache is not None and cross_kv is None:
        # insert new kv at position kv_len (decode) / 0 (prefill)
        at = kv_len if S == 1 else 0
        kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(
            cache["k"].dtype), (0, at, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(
            cache["v"].dtype), (0, at, 0, 0))
        new_cache = {"k": kc, "v": vc}
        if S == 1:
            o = decode_attend(q, kc, vc,
                              kv_len=None if kv_len is None else kv_len + 1)
            return jnp.einsum("bshk,hkd->bsd", o, w["wo"]), new_cache
        k, v = kc[:, :S], vc[:, :S]

    # GQA under head-sharded TP: when the kv heads themselves cannot carry
    # the model axis (Hkv % tp != 0) GSPMD would have to reshuffle the
    # grouped (Hkv, G) reshape; instead broadcast kv to the full H heads
    # (free: kv is replicated in exactly this case) and run flash with
    # G = 1, keeping the head dim cleanly sharded end-to-end.
    Hkv = k.shape[2]
    if (par.model_size > 1 and head_div and Hkv != H
            and Hkv % par.model_size != 0):
        rep = H // Hkv
        k = shard(jnp.repeat(k, rep, axis=2),
                  ("batch", None, "heads", None), par)
        v = shard(jnp.repeat(v, rep, axis=2),
                  ("batch", None, "heads", None), par)

    o = jnp_flash(q, k, v, causal=causal,
                  q_offset=0 if S > 1 else (kv_len or 0), par=par)
    o = shard(o, q_axes, par)
    return jnp.einsum("bshk,hkd->bsd", o, w["wo"]), new_cache


# ----------------------------------------------------------------- MLA block
def mla_init(pf, cfg, prefix: str, layers: int):
    d, H = cfg.d_model, cfg.num_heads
    dn, dr = cfg.head_dim, cfg.rope_head_dim       # nope / rope dims
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    return {
        "wq_a": pf.dense(f"{prefix}.wq_a", (layers, d, r_q),
                         (None, "embed", None), fan_in=d),
        "wq_b": pf.dense(f"{prefix}.wq_b", (layers, r_q, H, dn + dr),
                         (None, None, "heads", None), fan_in=r_q),
        "wkv_a": pf.dense(f"{prefix}.wkv_a", (layers, d, r_kv + dr),
                          (None, "embed", None), fan_in=d),
        "wk_b": pf.dense(f"{prefix}.wk_b", (layers, r_kv, H, dn),
                         (None, None, "heads", None), fan_in=r_kv),
        "wv_b": pf.dense(f"{prefix}.wv_b", (layers, r_kv, H, dn),
                         (None, None, "heads", None), fan_in=r_kv),
        "wo": pf.dense(f"{prefix}.wo", (layers, H, dn, d),
                       (None, "heads", None, "embed"), fan_in=H * dn),
        "kv_norm": pf.zeros(f"{prefix}.kv_norm", (layers, r_kv),
                            (None, None)),
    }


def mla_apply(cfg, w, x, *, positions, cache=None, kv_len=None,
              par=Parallelism(None)):
    """MLA attention. cache: dict(ckv (B,Smax,r_kv), krope (B,Smax,dr)).
    Prefill decompresses K/V (flash over chunks); decode uses the
    absorbed form against the compressed cache."""
    B, S, d = x.shape
    H, dn, dr = cfg.num_heads, cfg.head_dim, cfg.rope_head_dim
    r_kv = cfg.kv_lora_rank
    scale = (dn + dr) ** -0.5

    q = jnp.einsum("bsd,dr->bsr", x, w["wq_a"])
    q = jnp.einsum("bsr,rhk->bshk", q, w["wq_b"])
    q = shard(q, ("batch", None, "heads", None), par)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    kv_a = jnp.einsum("bsd,dr->bsr", x, w["wkv_a"])
    ckv, k_rope = kv_a[..., :r_kv], kv_a[..., r_kv:]
    ckv = rms_norm(ckv, w["kv_norm"])
    k_rope = rope(k_rope[:, :, None, :], positions,
                  cfg.rope_theta)[:, :, 0, :]

    if cache is not None:
        at = kv_len if S == 1 else 0
        ckv_c = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, at, 0))
        kr_c = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope.astype(cache["krope"].dtype), (0, at, 0))
        new_cache = {"ckv": ckv_c, "krope": kr_c}
    else:
        new_cache = None

    if S == 1 and cache is not None:
        # absorbed decode: q_abs = q_nope @ W_kb  -> (B,1,H,r_kv)
        q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, w["wk_b"])
        s = jnp.einsum("bshr,btr->bhst", q_abs.astype(jnp.float32),
                       ckv_c.astype(jnp.float32))
        s += jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                        kr_c.astype(jnp.float32))
        Smax = ckv_c.shape[1]
        valid = jnp.arange(Smax) < (kv_len + 1)
        s = jnp.where(valid[None, None, None, :], s * scale, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", p,
                           ckv_c.astype(jnp.float32))    # (B,1,H,r_kv)
        o = jnp.einsum("bshr,rhk->bshk", o_lat, w["wv_b"].astype(
            jnp.float32)).astype(x.dtype)
    else:
        # prefill/train: decompress K/V then flash
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, w["wk_b"])
        v = jnp.einsum("bsr,rhk->bshk", ckv, w["wv_b"])
        v = shard(v, ("batch", None, "heads", None), par)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, S, H, dr))], axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        # fold the joint scale into q (jnp_flash rescales by D^-0.5)
        qf = qf * (scale / ((dn + dr) ** -0.5))
        o = jnp_flash(qf, k, v, causal=True, q_offset=0, par=par)
    o = shard(o, ("batch", None, "heads", None), par)
    return jnp.einsum("bshk,hkd->bsd", o, w["wo"]), new_cache
