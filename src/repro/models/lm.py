"""Top-level language model: embedding, layer stack (scan over groups),
head; forward for train / prefill / decode; enc-dec variant.

Layer stacking: the layer pattern repeats with period p (p = 1 for pure
stacks; Jamba p = 8: 7 mamba + 1 attention, MoE every other layer).
Parameters are stacked per *offset within the period* with a leading
(num_groups,) dim and the stack runs as one ``lax.scan`` over groups —
constant-size HLO regardless of depth (compile-time control at 94-layer
MoE scale), with remat around the scan body.

Caches ride the same scan as xs/ys: per-offset pytrees with a leading
(num_groups, ...) dim.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models.common import (Parallelism, ParamFactory, glu_ffn,
                                 mlp_ffn, rms_norm, shard)


# ------------------------------------------------------------- layer plan
def layer_plan(cfg: ModelConfig, L: int, decoder: bool):
    """[(mixer, ffn, cross)] per layer. mixer: attn|mla|mamba;
    ffn: dense|moe|none."""
    sigs = []
    for l in range(L):
        if cfg.ssm_inner and not cfg.is_attn_layer(l):
            mixer = "mamba"
        else:
            mixer = "mla" if cfg.mla else "attn"
        if cfg.is_moe_layer(l):
            ffn = "moe"
        elif cfg.d_ff:
            ffn = "dense"
        else:
            ffn = "none"
        cross = cfg.enc_dec and decoder
        sigs.append((mixer, ffn, cross))
    return sigs


def _period(cfg: ModelConfig) -> int:
    p = 1
    if cfg.attn_period:
        p = cfg.attn_period
    if cfg.num_experts and cfg.moe_period > 1:
        p = int(np.lcm(p, cfg.moe_period))
    return p


# ------------------------------------------------------------------- init
def _init_layer(pf: ParamFactory, cfg: ModelConfig, sig, prefix: str,
                groups: int):
    mixer, ffn, cross = sig
    d = cfg.d_model
    w: Dict[str, Any] = {
        "ln1": pf.zeros(f"{prefix}.ln1", (groups, d), (None, None)),
    }
    if mixer == "attn":
        w["attn"] = attn.gqa_init(pf, cfg, f"{prefix}.attn", groups)
    elif mixer == "mla":
        w["attn"] = attn.mla_init(pf, cfg, f"{prefix}.attn", groups)
    else:
        w["mamba"] = mb.mamba_init(pf, cfg, f"{prefix}.mamba", groups)
    if cross:
        w["ln_cross"] = pf.zeros(f"{prefix}.ln_cross", (groups, d),
                                 (None, None))
        w["cross"] = attn.gqa_init(pf, cfg, f"{prefix}.cross", groups)
    if ffn == "dense":
        w["ln2"] = pf.zeros(f"{prefix}.ln2", (groups, d), (None, None))
        if cfg.activation in ("swiglu", "geglu"):
            w["ffn"] = {
                "wi_gate": pf.dense(f"{prefix}.ffn.wi_gate",
                                    (groups, d, cfg.d_ff),
                                    (None, "embed", "ff"), fan_in=d),
                "wi_up": pf.dense(f"{prefix}.ffn.wi_up",
                                  (groups, d, cfg.d_ff),
                                  (None, "embed", "ff"), fan_in=d),
                "wo": pf.dense(f"{prefix}.ffn.wo", (groups, cfg.d_ff, d),
                               (None, "ff", "embed"), fan_in=cfg.d_ff),
            }
        else:  # plain MLP (starcoder2, seamless)
            w["ffn"] = {
                "wi": pf.dense(f"{prefix}.ffn.wi", (groups, d, cfg.d_ff),
                               (None, "embed", "ff"), fan_in=d),
                "wo": pf.dense(f"{prefix}.ffn.wo", (groups, cfg.d_ff, d),
                               (None, "ff", "embed"), fan_in=cfg.d_ff),
            }
    elif ffn == "moe":
        w["ln2"] = pf.zeros(f"{prefix}.ln2", (groups, d), (None, None))
        w["moe"] = moe_mod.moe_init(pf, cfg, f"{prefix}.moe", groups)
    return w


def init_model(cfg: ModelConfig, key: jax.Array, dtype=None):
    """Returns (params, axes_by_path). Layer params are stacked by
    period-offset; see module docstring."""
    dtype = dtype or jnp.float32
    pf = ParamFactory(key, dtype)
    d = cfg.d_model
    params: Dict[str, Any] = {
        "embed": pf.embed("embed", (cfg.vocab_size, d), ("vocab", "embed"),
                          scale=1.0),
        "ln_f": pf.zeros("ln_f", (d,), (None,)),
    }
    if not cfg.tie_embeddings:
        params["head"] = pf.dense("head", (d, cfg.vocab_size),
                                  ("embed", "vocab"), fan_in=d)

    def build_stack(L, decoder, name):
        sigs = layer_plan(cfg, L, decoder)
        p = _period(cfg) if not (cfg.enc_dec and not decoder) else 1
        pre_n = cfg.first_dense_layers
        periodic = L - pre_n
        assert periodic % p == 0, (L, p)
        groups = periodic // p
        stack = {"prefix": [], "offsets": [], "sigs": sigs, "period": p,
                 "groups": groups, "pre_n": pre_n}
        for l in range(pre_n):
            stack["prefix"].append(
                _init_layer(pf, cfg, sigs[l], f"{name}.pre{l}", 1))
        for o in range(p):
            stack["offsets"].append(
                _init_layer(pf, cfg, sigs[pre_n + o], f"{name}.off{o}",
                            groups))
        return stack

    if cfg.enc_dec:
        params["encoder"] = build_stack(cfg.enc_layers, False, "enc")
        params["decoder"] = build_stack(cfg.num_layers, True, "dec")
        params["ln_enc"] = pf.zeros("ln_enc", (d,), (None,))
    else:
        params["decoder"] = build_stack(cfg.num_layers, False, "dec")
    # meta entries (period/groups/sigs) are not arrays; strip them into a
    # static side table
    meta = {}
    for nm in ("encoder", "decoder"):
        if nm in params:
            st = params[nm]
            meta[nm] = {k: st.pop(k) for k in
                        ("sigs", "period", "groups", "pre_n")}
    return params, pf.axes, meta


# ------------------------------------------------------------------ apply
def _apply_layer(cfg, sig, w, x, *, positions, cache, kv_len, par,
                 enc_out=None, causal=True):
    mixer, ffn, cross = sig
    new_cache = dict(cache) if isinstance(cache, dict) else {}
    # norms run in the seq-sharded (SP) region; pinning their bf16 output
    # here makes the Megatron all-gather move bf16, not the f32 internals
    h = shard(rms_norm(x, w["ln1"]), ("batch", "seq_tp", None), par)
    if mixer in ("attn", "mla"):
        sub = None if cache is None else {
            k: cache[k] for k in ("k", "v", "ckv", "krope") if k in cache}
        sub = sub if sub else None
        if mixer == "attn":
            o, upd = attn.gqa_apply(cfg, w["attn"], h, positions=positions,
                                    cache=sub, causal=causal, kv_len=kv_len,
                                    par=par)
        else:
            o, upd = attn.mla_apply(cfg, w["attn"], h, positions=positions,
                                    cache=sub, kv_len=kv_len, par=par)
        if upd:
            new_cache.update(upd)
    else:
        sub = None if cache is None else {
            k: cache[k] for k in ("conv", "ssd") if k in cache}
        sub = sub if sub else None
        o, upd = mb.mamba_apply(cfg, w["mamba"], h, cache=sub, par=par)
        if upd:
            new_cache.update(upd)
    # Megatron-SP residual stream: constraining each block's OUTPUT to the
    # sequence-sharded layout makes GSPMD lower the row-parallel matmul's
    # partial-sum as a reduce-scatter instead of a full all-reduce
    # (halves the per-layer collective bytes; §Perf iteration 1).
    x = x + shard(o, ("batch", "seq_tp", None), par)
    if cross:
        h = shard(rms_norm(x, w["ln_cross"]), ("batch", "seq_tp", None),
                  par)
        ck, cv = cache["cross_k"], cache["cross_v"]
        o, _ = attn.gqa_apply(cfg, w["cross"], h, positions=positions,
                              cache=None, causal=False, kv_len=None,
                              par=par, cross_kv=(ck, cv))
        x = x + shard(o, ("batch", "seq_tp", None), par)
    if ffn == "dense":
        h = shard(rms_norm(x, w["ln2"]), ("batch", "seq_tp", None), par)
        f = w["ffn"]
        if cfg.activation in ("swiglu", "geglu"):
            act = "silu" if cfg.activation == "swiglu" else "gelu"
            o = glu_ffn(h, f["wi_gate"], f["wi_up"], f["wo"], act, par)
        else:
            o = mlp_ffn(h, f["wi"], f["wo"], cfg.activation, par)
        x = x + shard(o, ("batch", "seq_tp", None), par)
    elif ffn == "moe":
        h = shard(rms_norm(x, w["ln2"]), ("batch", "seq_tp", None), par)
        o = moe_mod.moe_apply(cfg, w["moe"], h, par=par)
        x = x + shard(o, ("batch", "seq_tp", None), par)
    return x, (new_cache if cache is not None else None)


def _apply_stack(cfg, stack, meta, x, *, positions, caches, kv_len, par,
                 enc_out=None, remat_policy="dots_no_batch", causal=True):
    sigs, p = meta["sigs"], meta["period"]
    pre_n, groups = meta["pre_n"], meta["groups"]
    new_caches = {"prefix": [], "scan": None}

    for l in range(pre_n):
        w = jax.tree.map(lambda t: t[0], stack["prefix"][l])
        c = None if caches is None else caches["prefix"][l]
        x, nc = _apply_layer(cfg, sigs[l], w, x, positions=positions,
                             cache=c, kv_len=kv_len, par=par,
                             enc_out=enc_out, causal=causal)
        new_caches["prefix"].append(nc)

    offs = stack["offsets"]
    cs_in = None if caches is None else caches["scan"]

    # Decode (S == 1): unroll the group loop in Python. Under lax.scan
    # XLA copies the full stacked KV cache carry every iteration (~2x
    # cache bytes per LAYER of pure copy traffic, measured — §Perf
    # decode iteration); unrolled, each group's cache is updated in
    # place and aliased through donation.
    if caches is not None and x.shape[1] == 1 and groups > 0:
        cs_out = [dict(c) for c in cs_in]
        for g in range(groups):
            for o in range(p):
                w_o = jax.tree.map(lambda t: t[g], offs[o])
                c_o = jax.tree.map(lambda t: t[g], cs_in[o])
                x, nc = _apply_layer(cfg, sigs[pre_n + o], w_o, x,
                                     positions=positions, cache=c_o,
                                     kv_len=kv_len, par=par,
                                     enc_out=enc_out, causal=causal)
                cs_out[o] = jax.tree.map(
                    lambda full, new: jax.lax.dynamic_update_index_in_dim(
                        full, new.astype(full.dtype), g, 0),
                    cs_out[o], nc)
        new_caches["scan"] = cs_out
        return x, new_caches

    def body(carry, xs):
        # caches ride the CARRY (updated in place per group via
        # dynamic_update_index_in_dim) so the while loop aliases one
        # buffer — scanning them as xs/ys would double the KV memory.
        h, cs_all = carry
        ws, g = xs
        ncs = []
        for o in range(p):
            w_o = ws[o]
            c_o = None
            if cs_all is not None:
                c_o = jax.tree.map(
                    lambda t: jax.lax.dynamic_index_in_dim(
                        t, g, 0, keepdims=False), cs_all[o])
            h, nc = _apply_layer(cfg, sigs[pre_n + o], w_o, h,
                                 positions=positions, cache=c_o,
                                 kv_len=kv_len, par=par, enc_out=enc_out,
                                 causal=causal)
            ncs.append(nc)
        if cs_all is not None:
            cs_all = [jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new.astype(full.dtype), g, 0),
                cs_all[o2], ncs[o2]) for o2 in range(p)]
        # sequence-parallel layer boundary: remat-saved per-layer residuals
        # shard over the model axis (Megatron-SP style), which divides the
        # dominant activation-memory term by the TP degree.
        h = shard(h, ("batch", "seq_tp", None), par)
        return (h, cs_all), None

    if remat_policy != "none":
        pol = {"full": None,
               "dots": jax.checkpoint_policies.checkpoint_dots,
               "dots_no_batch":
               jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
               }[remat_policy]
        body = jax.checkpoint(body, policy=pol)

    groups = meta["groups"]
    (x, cs_out), _ = jax.lax.scan(
        body, (x, cs_in), (offs, jnp.arange(groups)))
    new_caches["scan"] = cs_out
    return x, (new_caches if caches is not None else None)


# ------------------------------------------------------------- entry points
def embed_tokens(cfg, params, tokens, par):
    e = params["embed"].astype(_adtype(cfg))
    x = e[tokens]  # gather; vocab-sharded -> XLA collective
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return shard(x, ("batch", "seq_tp", None), par)


def _adtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def cast_params(cfg, params):
    """fp32 master params -> compute dtype (mixed-precision forward)."""
    dt = _adtype(cfg)

    def cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dt)
        return x

    return jax.tree.map(cast, params)


def lm_logits(cfg, params, x, par):
    x = rms_norm(x, params["ln_f"])
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["head"].astype(x.dtype))
    return shard(logits, ("batch", None, "vocab"), par)


def encode(cfg, params, meta, src_embeds, par):
    """Encoder forward (audio frontend stub supplies src_embeds)."""
    x = shard(src_embeds.astype(_adtype(cfg)), ("batch", None, "embed"),
              par)
    pos = jnp.arange(x.shape[1])[None, :]
    x, _ = _apply_stack(cfg, params["encoder"], meta["encoder"], x,
                        positions=pos, caches=None, kv_len=None, par=par,
                        remat_policy=cfg.remat, causal=False)
    return rms_norm(x, params["ln_enc"])


def forward_train(cfg, params, meta, batch, par: Parallelism):
    """batch: {'tokens' (B,S)} (+ 'src_embeds' for enc-dec/frontend).
    Returns logits (B,S,V)."""
    params = cast_params(cfg, params)
    tokens = batch["tokens"]
    B, S = tokens.shape
    pos = jnp.arange(S)[None, :]
    x = embed_tokens(cfg, params, tokens, par)
    enc_out = None
    caches = None
    if cfg.enc_dec:
        enc_out = encode(cfg, params, meta, batch["src_embeds"], par)
        caches = _make_cross_caches(cfg, params, meta, enc_out, par)
    x, _ = _apply_stack(cfg, params["decoder"], meta["decoder"], x,
                        positions=pos, caches=caches, kv_len=None, par=par,
                        enc_out=enc_out, remat_policy=cfg.remat)
    return lm_logits(cfg, params, x, par)


def _make_cross_caches(cfg, params, meta, enc_out, par):
    """Precompute cross-attention K/V per decoder layer (cached once)."""
    md = meta["decoder"]
    pre, p, groups = md["pre_n"], md["period"], md["groups"]

    def kv_for(w_cross):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, w_cross["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, w_cross["wv"])
        return k, v

    caches = {"prefix": [], "scan": None}
    for l in range(pre):
        w = jax.tree.map(lambda t: t[0], params["decoder"]["prefix"][l])
        k, v = kv_for(w["cross"])
        caches["prefix"].append({"cross_k": k, "cross_v": v})
    scan_caches = []
    for o in range(p):
        w = params["decoder"]["offsets"][o]["cross"]
        k = jnp.einsum("bsd,gdhk->gbshk", enc_out, w["wk"])
        v = jnp.einsum("bsd,gdhk->gbshk", enc_out, w["wv"])
        scan_caches.append({"cross_k": k, "cross_v": v})
    caches["scan"] = scan_caches
    return caches


def init_cache(cfg, meta, B, max_len, par, src_len: int = 0):
    """Decode cache pytree (zeros), matching _apply_stack's layout."""
    md = meta["decoder"]
    dt = _adtype(cfg)

    def layer_cache(sig, lead):
        mixer, ffn, cross = sig
        c = {}
        shp = (lead + (B, max_len, cfg.num_kv_heads, cfg.head_dim))
        if mixer == "attn":
            c["k"] = jnp.zeros(shp, dt)
            c["v"] = jnp.zeros(shp, dt)
        elif mixer == "mla":
            c["ckv"] = jnp.zeros(lead + (B, max_len, cfg.kv_lora_rank), dt)
            c["krope"] = jnp.zeros(lead + (B, max_len, cfg.rope_head_dim),
                                   dt)
        else:
            c["conv"] = jnp.zeros(
                lead + (B, cfg.ssm_conv - 1,
                        cfg.ssm_inner + 2 * cfg.ssm_groups * cfg.ssm_state),
                dt)
            c["ssd"] = jnp.zeros(
                lead + (B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32)
        if cross:
            c["cross_k"] = jnp.zeros(
                lead + (B, src_len, cfg.num_kv_heads, cfg.head_dim), dt)
            c["cross_v"] = jnp.zeros(
                lead + (B, src_len, cfg.num_kv_heads, cfg.head_dim), dt)
        return c

    sigs = md["sigs"]
    caches = {"prefix": [layer_cache(sigs[l], ())
                         for l in range(md["pre_n"])],
              "scan": [layer_cache(sigs[md["pre_n"] + o],
                                   (md["groups"],))
                       for o in range(md["period"])]}
    return caches


def forward_prefill(cfg, params, meta, batch, cache, par):
    """Fills `cache` with the prompt; returns (last-token logits, cache)."""
    params = cast_params(cfg, params)
    tokens = batch["tokens"]
    B, S = tokens.shape
    pos = jnp.arange(S)[None, :]
    x = embed_tokens(cfg, params, tokens, par)
    enc_out = None
    if cfg.enc_dec:
        enc_out = encode(cfg, params, meta, batch["src_embeds"], par)
        cache = _fill_cross(cfg, params, meta, enc_out, cache)
    x, cache = _apply_stack(cfg, params["decoder"], meta["decoder"], x,
                            positions=pos, caches=cache, kv_len=None,
                            par=par, enc_out=enc_out,
                            remat_policy=cfg.remat)
    logits = lm_logits(cfg, params, x[:, -1:], par)
    return logits, cache


def _fill_cross(cfg, params, meta, enc_out, cache):
    md = meta["decoder"]
    for l in range(md["pre_n"]):
        w = jax.tree.map(lambda t: t[0],
                         params["decoder"]["prefix"][l])["cross"]
        cache["prefix"][l]["cross_k"] = jnp.einsum(
            "bsd,dhk->bshk", enc_out, w["wk"]).astype(_adtype(cfg))
        cache["prefix"][l]["cross_v"] = jnp.einsum(
            "bsd,dhk->bshk", enc_out, w["wv"]).astype(_adtype(cfg))
    for o in range(md["period"]):
        w = params["decoder"]["offsets"][o]["cross"]
        cache["scan"][o]["cross_k"] = jnp.einsum(
            "bsd,gdhk->gbshk", enc_out, w["wk"]).astype(_adtype(cfg))
        cache["scan"][o]["cross_v"] = jnp.einsum(
            "bsd,gdhk->gbshk", enc_out, w["wv"]).astype(_adtype(cfg))
    return cache


def forward_decode(cfg, params, meta, tokens, cache, kv_len, par):
    """One decode step. tokens: (B,1); kv_len: scalar current length.
    Returns (logits (B,1,V), cache)."""
    params = cast_params(cfg, params)
    pos = jnp.full((tokens.shape[0], 1), kv_len, jnp.int32)
    x = embed_tokens(cfg, params, tokens, par)
    x, cache = _apply_stack(cfg, params["decoder"], meta["decoder"], x,
                            positions=pos, caches=cache, kv_len=kv_len,
                            par=par, remat_policy="none")
    return lm_logits(cfg, params, x, par)[:, -1:], cache


# --------------------------------------------------------------------- loss
def forward_train_loss(cfg, params, meta, batch, par: Parallelism,
                       chunk: int = 512):
    """Fused stack -> chunked head+CE. Never materializes the full (B,S,V)
    f32 logits: the head matmul + softmax-CE run per sequence chunk under
    jax.checkpoint (recomputed in backward). This is what the production
    train step uses; forward_train (full logits) remains for serving and
    tests."""
    params = cast_params(cfg, params)
    tokens = batch["tokens"]
    B, S = tokens.shape
    pos = jnp.arange(S)[None, :]
    x = embed_tokens(cfg, params, tokens, par)
    enc_out = None
    caches = None
    if cfg.enc_dec:
        enc_out = encode(cfg, params, meta, batch["src_embeds"], par)
        caches = _make_cross_caches(cfg, params, meta, enc_out, par)
    x, _ = _apply_stack(cfg, params["decoder"], meta["decoder"], x,
                        positions=pos, caches=caches, kv_len=None, par=par,
                        enc_out=enc_out, remat_policy=cfg.remat)
    x = rms_norm(x, params["ln_f"])
    labels = batch["labels"]

    head = (params["embed"].astype(x.dtype).T if cfg.tie_embeddings
            else params["head"].astype(x.dtype))

    # adaptive chunk: cap the per-device f32 logits buffer at ~256 MB
    # (gemma/seamless have 256k vocabularies)
    dp = tp = 1
    if par.mesh is not None:
        dp = int(np.prod([par.mesh.shape[a] for a in par.data_axes]))
        tp = max(par.model_size, 1)
    per_tok = (B / max(dp, 1)) * (cfg.vocab_size / max(tp, 1)) * 4
    chunk = max(16, min(chunk, int(256e6 // max(per_tok, 1))))
    nchunk = max(1, S // chunk)
    while S % nchunk:
        nchunk += 1
    chunk = S // nchunk
    xc = x.reshape(B, nchunk, chunk, -1).transpose(1, 0, 2, 3)
    yc = labels.reshape(B, nchunk, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        xm, ym = inp
        xm = shard(xm, ("batch", None, None), par)
        logits = jnp.einsum("bsd,dv->bsv", xm, head)
        logits = shard(logits, ("batch", None, "vocab"), par)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ym[..., None], axis=-1)[..., 0]
        nll = (logz - gold).sum()
        zl = (logz ** 2).sum()
        return (carry[0] + nll, carry[1] + zl), None

    (nll, zl), _ = jax.lax.scan(jax.checkpoint(body), (0.0, 0.0), (xc, yc))
    n = B * S
    return nll / n + 1e-4 * zl / n


def lm_loss(cfg, logits, targets, mask=None):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    zloss = 1e-4 * ((logz * mask) ** 2).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + zloss
