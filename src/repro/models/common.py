"""Shared model machinery: named-axis params, norms, RoPE, sharding rules.

Params are plain pytrees of jnp arrays; every array is created through
``param(...)`` with LOGICAL axis names, and ``logical_to_spec`` maps
logical names to mesh axes (the single place the parallelism layout is
decided — see DESIGN.md §5):

    embed   -> FSDP over 'data'      (weights gathered per-layer by XLA)
    heads   -> TP over 'model'       (uneven allowed; GSPMD pads)
    kv_heads-> TP over 'model' only when divisible (GQA kv is small)
    ff / vocab / experts / ssm_heads -> TP over 'model'
    batch   -> DP over ('pod','data')
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


# --------------------------------------------------------------- parallelism
@dataclasses.dataclass(frozen=True)
class Parallelism:
    """Mesh context threaded through model code. mesh=None => single host
    (smoke tests): every spec collapses to fully-replicated."""
    mesh: Optional[object] = None        # jax.sharding.Mesh
    data_axes: tuple = ("data",)         # batch / fsdp axes
    model_axis: Optional[str] = "model"  # tensor/expert axis
    fsdp: bool = True

    @property
    def model_size(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    def batch_spec(self):
        return tuple(self.data_axes) if self.mesh is not None else None


LOGICAL_RULES = {
    "batch": "DATA",       # resolved to parallelism.data_axes
    "embed": "FSDP",       # 'data' when fsdp else None
    "heads": "MODEL_IF_DIV",   # replicate when H % tp != 0 (starcoder 24H,
    #                            deepseek-coder 56H) — attention then runs
    #                            sequence-parallel instead (see attention.py)
    "kv_heads": "MODEL_IF_DIV",
    "seq_tp": "MODEL_IF_DIV",  # context parallelism fallback
    "ff": "MODEL",
    "vocab": "MODEL",
    "experts": "MODEL",
    "ssm_heads": "MODEL",
    "kv_seq": "MODEL",     # decode KV cache sequence dim
    None: None,
}


def logical_to_spec(axes: tuple, shape: tuple, par: Parallelism) -> P:
    """Map logical axis names -> PartitionSpec under `par`.

    Every rule is divisibility-checked: jit in_shardings (unlike
    with_sharding_constraint) reject uneven partitions, and padded
    shards waste memory/compute anyway — an indivisible dim falls back
    to replicated (e.g. mamba2's 50280 vocab on a 16-wide model axis).
    """
    if par.mesh is None:
        return P()

    def _fits(dim, ax_names) -> bool:
        n = 1
        for a in (ax_names if isinstance(ax_names, tuple) else (ax_names,)):
            n *= par.mesh.shape[a]
        return dim % n == 0

    out = []
    for name, dim in zip(axes, shape):
        rule = LOGICAL_RULES.get(name)
        if rule == "DATA":
            ax = tuple(par.data_axes)
            out.append(ax if _fits(dim, ax) else None)
        elif rule == "FSDP":
            fsdp_ax = par.data_axes[-1]  # intra-pod axis only
            ok = par.fsdp and _fits(dim, fsdp_ax)
            out.append(fsdp_ax if ok else None)
        elif rule in ("MODEL", "MODEL_IF_DIV"):
            ok = par.model_axis is not None and _fits(dim, par.model_axis)
            out.append(par.model_axis if ok else None)
        else:
            out.append(None)
    return P(*out)


def shard(x: jax.Array, axes: tuple, par: Parallelism) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op off-mesh).

    Activations never carry the FSDP ('embed') sharding — that axis is
    already used by 'batch'; weights are gathered per-layer instead."""
    if par.mesh is None:
        return x
    axes = tuple(None if a == "embed" else a for a in axes)
    spec = logical_to_spec(axes, x.shape, par)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(par.mesh, spec))


# ------------------------------------------------------------------- params
class ParamFactory:
    """Collects params + their logical axes; init is fan-in scaled."""

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self.key = key
        self.dtype = dtype
        self.axes = {}   # path -> logical axes tuple

    def split(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def dense(self, path: str, shape: tuple, axes: tuple,
              fan_in: Optional[int] = None, scale: float = 1.0):
        assert len(shape) == len(axes), (path, shape, axes)
        fi = fan_in if fan_in is not None else shape[0]
        std = scale / np.sqrt(max(fi, 1))
        self.axes[path] = axes
        return jax.random.normal(self.split(), shape, self.dtype) * std

    def embed(self, path: str, shape: tuple, axes: tuple,
              scale: float = 1.0):
        self.axes[path] = axes
        return jax.random.normal(self.split(), shape, self.dtype) * scale

    def zeros(self, path: str, shape: tuple, axes: tuple):
        self.axes[path] = axes
        return jnp.zeros(shape, self.dtype)

    def ones(self, path: str, shape: tuple, axes: tuple):
        self.axes[path] = axes
        return jnp.ones(shape, self.dtype)

    def const(self, path: str, value: np.ndarray, axes: tuple):
        self.axes[path] = axes
        return jnp.asarray(value, self.dtype)


def param_specs(params, axes_by_path: dict, par: Parallelism):
    """Pytree of PartitionSpec matching `params`.

    ParamFactory paths are creation-site names ('dec.off0.attn.wq');
    pytree paths are placement names (['decoder']['offsets'][0]['attn']
    ['wq']). The two agree on the trailing (module, param) components,
    which is also the granularity at which the logical axes are decided —
    so specs are resolved by suffix. Conflicting suffixes would be a
    modelling bug and raise at build time."""
    suffix_map = {}
    for path, axes in axes_by_path.items():
        comps = tuple(path.split("."))
        key = comps[-2:] if len(comps) >= 2 else comps[-1:]
        prev = suffix_map.get(key)
        if prev is not None and prev != axes:
            raise ValueError(f"ambiguous param suffix {key}: "
                             f"{prev} vs {axes}")
        suffix_map[key] = axes

    def spec_for(kp, leaf):
        comps = tuple(p.key for p in kp if hasattr(p, "key"))
        axes = suffix_map.get(comps[-2:]) or suffix_map.get(comps[-1:])
        if axes is None:
            axes = (None,) * leaf.ndim
        return logical_to_spec(axes, leaf.shape, par)

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    specs = [spec_for(kp, leaf) for kp, leaf in flat]
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(treedef, specs)


# ------------------------------------------------------------------- layers
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))
            ).astype(dt)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(
        jax.nn.gelu, approximate=True), "relu": jax.nn.relu}[name]


def glu_ffn(x, wi_gate, wi_up, wo, act: str, par: Parallelism):
    """SwiGLU / GeGLU: act(x W_g) * (x W_u) W_o. Column-parallel in,
    row-parallel out; the output constraint makes GSPMD lower the
    partial-sum as reduce-scatter to the seq-sharded residual."""
    h = activation(act)(x @ wi_gate) * (x @ wi_up)
    h = shard(h, ("batch", None, "ff"), par)
    return shard(h @ wo, ("batch", "seq_tp", None), par)


def mlp_ffn(x, wi, wo, act: str, par: Parallelism):
    """Plain 2-matrix FFN: act(x W_i) W_o (starcoder2 / seamless)."""
    h = activation(act)(x @ wi)
    h = shard(h, ("batch", None, "ff"), par)
    return shard(h @ wo, ("batch", "seq_tp", None), par)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq   # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
        axis=-1).astype(x.dtype)


def remat(fn, policy: str = "none"):
    if policy == "none":
        return fn
    pol = {
        "full": None,  # save nothing
        "dots": jax.checkpoint_policies.checkpoint_dots,
        "dots_no_batch": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[policy]
    return jax.checkpoint(fn, policy=pol)
