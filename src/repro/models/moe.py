"""Mixture-of-Experts FFN with expert parallelism (GShard/DeepSeek-style).

Top-k routing -> static-capacity pack -> all_to_all over the 'model'
(expert) axis -> per-expert GLU FFN -> reverse all_to_all -> weighted
combine. The a2a pair is the archetypal *wide coflow* the Saath planner
schedules (DESIGN.md §4): runtime.coflow_bridge registers one coflow per
MoE layer wave.

The pack is a sort-free scatter: for every (token, choice) pair the
destination slot is (expert, rank-within-expert) where rank comes from a
cumulative one-hot count; pairs beyond the static capacity are dropped
(standard capacity-factor semantics; shared experts and the residual
keep dropped tokens finite).

Single-device (smoke tests): the same code runs under a 1-chip mesh —
all_to_all over an axis of size 1 is the identity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.common import Parallelism, activation, shard


def moe_init(pf, cfg, prefix: str, layers: int):
    d, E, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    p = {
        "router": pf.dense(f"{prefix}.router", (layers, d, E),
                           (None, "embed", None), fan_in=d),
        "wi_gate": pf.dense(f"{prefix}.wi_gate", (layers, E, d, f),
                            (None, "experts", "embed", None), fan_in=d),
        "wi_up": pf.dense(f"{prefix}.wi_up", (layers, E, d, f),
                          (None, "experts", "embed", None), fan_in=d),
        "wo": pf.dense(f"{prefix}.wo", (layers, E, f, d),
                       (None, "experts", None, "embed"), fan_in=f),
    }
    if cfg.num_shared_experts:
        fs = cfg.moe_d_ff * cfg.num_shared_experts
        p["shared_gate"] = pf.dense(f"{prefix}.shared_gate",
                                    (layers, d, fs), (None, "embed", "ff"),
                                    fan_in=d)
        p["shared_up"] = pf.dense(f"{prefix}.shared_up", (layers, d, fs),
                                  (None, "embed", "ff"), fan_in=d)
        p["shared_down"] = pf.dense(f"{prefix}.shared_down", (layers, fs, d),
                                    (None, "ff", "embed"), fan_in=fs)
    return p


def _pack(pair_expert, pair_weight, pair_tok, E: int, cap: int):
    """(T*k,) expert ids -> slot index into an (E*cap) buffer; drops
    overflow. Returns (slot (T*k,), keep (T*k,))."""
    onehot = jax.nn.one_hot(pair_expert, E, dtype=jnp.int32)  # (TK, E)
    rank = jnp.cumsum(onehot, axis=0) - 1                      # 0-based
    my_rank = jnp.take_along_axis(rank, pair_expert[:, None], 1)[:, 0]
    keep = my_rank < cap
    slot = pair_expert * cap + jnp.minimum(my_rank, cap - 1)
    return slot, keep


def moe_apply(cfg, w, x, *, par: Parallelism,
              capacity_factor: float | None = None):
    """x: (B, S, d) -> (B, S, d). w holds one layer's weights."""
    capacity_factor = (capacity_factor if capacity_factor is not None
                       else cfg.moe_capacity_factor)
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    f = cfg.moe_d_ff
    act = activation("silu" if cfg.activation in ("swiglu",) else "gelu")

    logits = jnp.einsum("bsd,de->bse", x, w["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                 # (B,S,k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    top_w = top_w.astype(x.dtype)

    tp = par.model_size
    E_loc = max(E // tp, 1)

    def local_moe(xt, te, tw, wg, wu, wo):
        """Runs per (data, model)-shard: xt (Tl, d) local tokens; expert
        weights sharded over the model axis (wg: (E_loc, d, f))."""
        Tl = xt.shape[0]
        cap = int(max(4, np.ceil(Tl * k * capacity_factor / E)))
        pe = te.reshape(-1)                                # (Tl*k,)
        pw = tw.reshape(-1)
        ptok = jnp.repeat(jnp.arange(Tl), k)
        slot, keep = _pack(pe, pw, ptok, E, cap)
        buf = jnp.zeros((E * cap, d), xt.dtype)
        buf = buf.at[slot].add(jnp.where(keep[:, None], xt[ptok], 0))
        # ship expert shards to their owners: (tp, E_loc*cap, d)
        buf = buf.reshape(tp, E_loc * cap, d)
        if tp > 1:
            recv = jax.lax.all_to_all(buf, par.model_axis, split_axis=0,
                                      concat_axis=0, tiled=False)
        else:
            recv = buf
        # recv: (tp, E_loc*cap, d) = each source's slice for MY experts
        h = recv.reshape(tp, E_loc, cap, d).transpose(1, 0, 2, 3) \
            .reshape(E_loc, tp * cap, d)
        g = jnp.einsum("ecd,edf->ecf", h, wg)
        u = jnp.einsum("ecd,edf->ecf", h, wu)
        o = jnp.einsum("ecf,efd->ecd", act(g) * u, wo)
        o = o.reshape(E_loc, tp, cap, d).transpose(1, 0, 2, 3) \
            .reshape(tp, E_loc * cap, d)
        if tp > 1:
            back = jax.lax.all_to_all(o, par.model_axis, split_axis=0,
                                      concat_axis=0, tiled=False)
        else:
            back = o
        back = back.reshape(E * cap, d)
        contrib = back[slot] * jnp.where(keep, pw, 0)[:, None]
        out = jnp.zeros_like(xt).at[ptok].add(contrib)
        return out

    if par.mesh is None:
        out = local_moe(x.reshape(B * S, d), top_e.reshape(B * S, k),
                        top_w.reshape(B * S, k), w["wi_gate"], w["wi_up"],
                        w["wo"])
        out = out.reshape(B, S, d)
    else:
        mesh = par.mesh
        # token sharding: batch over the data axes when divisible,
        # sequence over the model axis when divisible (decode keeps S=1
        # replicated across the model axis — the a2a roundtrip still
        # returns identical results on every shard).
        dp = int(np.prod([mesh.shape[a] for a in par.data_axes]))
        bspec = tuple(par.data_axes) if B % dp == 0 else None
        sspec = par.model_axis if S % max(tp, 1) == 0 and S > 1 else None
        tok_spec = P(bspec, sspec, None)
        sm = jax.shard_map(
            functools.partial(_sharded_moe_body, E=E, k=k, d=d, f=f,
                              tp=tp, E_loc=E_loc,
                              capacity_factor=capacity_factor,
                              act=act, model_axis=par.model_axis),
            mesh=mesh,
            in_specs=(tok_spec, tok_spec, tok_spec,
                      P(None, par.model_axis, None, None),
                      P(None, par.model_axis, None, None),
                      P(None, par.model_axis, None, None)),
            out_specs=tok_spec,
            check_vma=False)
        out = sm(x, top_e, top_w, w["wi_gate"][None], w["wi_up"][None],
                 w["wo"][None])

    if cfg.num_shared_experts:
        h = act(x @ w["shared_gate"]) * (x @ w["shared_up"])
        h = shard(h, ("batch", None, "ff"), par)
        out = out + h @ w["shared_down"]
    return out


def _sharded_moe_body(x, te, tw, wg, wu, wo, *, E, k, d, f, tp, E_loc,
                      capacity_factor, act, model_axis):
    """shard_map body: x (Bl, Sl, d); te/tw (Bl, Sl, k); w* (1, E_loc,...)."""
    Bl, Sl, _ = x.shape
    xt = x.reshape(Bl * Sl, d)
    pe = te.reshape(-1)
    pw = tw.reshape(-1)
    Tl = Bl * Sl
    cap = int(max(4, np.ceil(Tl * k * capacity_factor / E)))
    ptok = jnp.repeat(jnp.arange(Tl), k)
    slot, keep = _pack(pe, pw, ptok, E, cap)
    buf = jnp.zeros((E * cap, d), x.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xt[ptok], 0))
    buf = buf.reshape(tp, E_loc * cap, d)
    if tp > 1:
        recv = jax.lax.all_to_all(buf, model_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
    else:
        recv = buf
    h = recv.reshape(tp, E_loc, cap, d).transpose(1, 0, 2, 3) \
        .reshape(E_loc, tp * cap, d)
    g = jnp.einsum("ecd,edf->ecf", h, wg[0])
    u = jnp.einsum("ecd,edf->ecf", h, wu[0])
    o = jnp.einsum("ecf,efd->ecd", act(g) * u, wo[0])
    o = o.reshape(E_loc, tp, cap, d).transpose(1, 0, 2, 3) \
        .reshape(tp, E_loc * cap, d)
    if tp > 1:
        back = jax.lax.all_to_all(o, model_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
    else:
        back = o
    back = back.reshape(E * cap, d)
    contrib = back[slot] * jnp.where(keep, pw, 0)[:, None]
    out = jnp.zeros_like(xt).at[ptok].add(contrib)
    return out.reshape(Bl, Sl, d)
