from repro.models import lm
from repro.models.common import Parallelism

__all__ = ["lm", "Parallelism"]
