"""Mamba2-1.3B [arXiv:2405.21060]: SSD (state-space duality),
attention-free, d_inner=2d, head_dim=64, ssm_state=128."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, d_ff=0, vocab_size=50280,
    rope_theta=0.0,
    ssm_inner=4096, ssm_heads=64, ssm_head_dim=64, ssm_state=128,
    ssm_groups=1, ssm_conv=4,
    subquadratic=True,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, vocab_size=256,
    ssm_inner=128, ssm_heads=8, ssm_head_dim=16, ssm_state=16)
