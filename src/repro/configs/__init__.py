from repro.configs.base import (ARCH_IDS, ARCHS, SHAPES, ModelConfig,
                                ShapeConfig, cell_is_runnable, get_config,
                                get_smoke_config)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCHS", "ARCH_IDS",
           "get_config", "get_smoke_config", "cell_is_runnable"]
