"""Jamba-v0.1 52B [arXiv:2403.19887; hf]: hybrid Mamba+attention 1:7
interleave, MoE 16 experts top-2 every other layer."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=65536,
    activation="swiglu", rope_theta=0.0,   # Jamba: no positional encoding
    num_experts=16, num_experts_per_tok=2, moe_d_ff=14336,
    moe_period=2, moe_offset=1,
    ssm_inner=8192, ssm_heads=128, ssm_head_dim=64, ssm_state=16,
    ssm_groups=1, ssm_conv=4,
    attn_period=8, attn_offset=3,
    subquadratic=True, opt_state_dtype="bfloat16", train_microbatches=8,
)

SMOKE = dataclasses.replace(
    CONFIG, train_microbatches=1, num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, d_ff=128, vocab_size=256, num_experts=4,
    num_experts_per_tok=2, moe_d_ff=128,
    ssm_inner=128, ssm_heads=8, ssm_head_dim=16, ssm_state=16,
    attn_period=8, attn_offset=3)
