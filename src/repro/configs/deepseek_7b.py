"""DeepSeek-7B [arXiv:2401.02954; hf]: llama-arch, MHA (kv=32)."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32,
    head_dim=128, d_ff=11008, vocab_size=102400,
    activation="swiglu", rope_theta=1e4,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=256)
