"""SeamlessM4T-medium [arXiv:2308.11596; hf]: enc-dec, multimodal;
audio frontend = stub (input_specs supplies precomputed frame
embeddings). 12L encoder + 12L decoder."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    head_dim=64, d_ff=4096, vocab_size=256206,
    activation="gelu", rope_theta=1e4,
    enc_dec=True, enc_layers=12, frontend="audio", scale_embed=True,
    train_microbatches=4,
)

SMOKE = dataclasses.replace(
    CONFIG, train_microbatches=1, num_layers=2, enc_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256)
