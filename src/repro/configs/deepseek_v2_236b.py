"""DeepSeek-V2 236B [arXiv:2405.04434; hf]: MLA (kv_lora=512),
2 shared + 160 routed top-6 experts, first layer dense."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    head_dim=128, d_ff=12288, vocab_size=102400,
    activation="swiglu", rope_theta=1e4,
    mla=True, kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
    num_experts=160, num_experts_per_tok=6, num_shared_experts=2,
    moe_d_ff=1536, moe_period=1, first_dense_layers=1,
    opt_state_dtype="bfloat16", train_microbatches=16,
)

SMOKE = dataclasses.replace(
    CONFIG, train_microbatches=1, num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=256, kv_lora_rank=32,
    q_lora_rank=48, rope_head_dim=8, num_experts=8,
    num_experts_per_tok=2, num_shared_experts=1, moe_d_ff=64,
    first_dense_layers=1)
