"""Model / shape / run configuration schema + registry."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | vlm | ssm | hybrid | audio
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    activation: str = "swiglu"   # swiglu | geglu
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    qk_norm: bool = False
    scale_embed: bool = False    # gemma-style sqrt(d) input scaling
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_period: int = 1          # MoE FFN every `period` layers
    moe_offset: int = 0
    first_dense_layers: int = 0
    moe_capacity_factor: float = 1.25
    # --- MLA (deepseek-v2) ---
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    # --- SSM (mamba2 / jamba) ---
    ssm_inner: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_state: int = 128
    ssm_groups: int = 1
    ssm_conv: int = 4
    attn_period: int = 0         # hybrid: attention every `attn_period`
    attn_offset: int = 0         # ... layers, at index `attn_offset`
    # --- enc-dec (seamless) ---
    enc_dec: bool = False
    enc_layers: int = 0
    # --- modality frontend stub ---
    frontend: Optional[str] = None   # 'audio' | 'vision' | None
    # --- numerics / training ---
    dtype: str = "bfloat16"
    remat: str = "full"          # full | dots | dots_no_batch | none
    train_microbatches: int = 1  # gradient-accumulation splits per step
    optimizer: str = "adamw"     # adamw | adafactor
    opt_state_dtype: str = "float32"
    # long-context applicability (assignment rules)
    subquadratic: bool = False

    # ---- derived ----
    @property
    def is_attn_layer(self):
        """layer index -> True if attention (vs mamba) mixer."""
        if self.attn_period == 0:
            return lambda l: self.ssm_inner == 0
        return lambda l: (l % self.attn_period) == self.attn_offset

    @property
    def is_moe_layer(self):
        if self.num_experts == 0:
            return lambda l: False
        return lambda l: (l >= self.first_dense_layers
                          and (l % self.moe_period) == self.moe_offset)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers)."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        layers = self.enc_layers + L if self.enc_dec else L
        for l in range(layers):
            dec_layer = (not self.enc_dec) or l >= self.enc_layers
            if self.is_attn_layer(l if not self.enc_dec else
                                  max(l - self.enc_layers, 0)):
                if self.mla:
                    n += d * self.q_lora_rank
                    n += self.q_lora_rank * self.num_heads * (
                        self.head_dim + self.rope_head_dim)
                    n += d * (self.kv_lora_rank + self.rope_head_dim)
                    n += 2 * self.kv_lora_rank * self.num_heads * \
                        self.head_dim
                    n += self.num_heads * self.head_dim * d
                else:
                    n += d * self.num_heads * self.head_dim * 2
                    n += d * self.num_kv_heads * self.head_dim * 2
                if self.enc_dec and dec_layer:  # cross attention
                    n += d * self.num_heads * self.head_dim * 2
                    n += d * self.num_kv_heads * self.head_dim * 2
            else:
                n += self.d_model * (2 * self.ssm_inner + 2 *
                                     self.ssm_groups * self.ssm_state
                                     + self.ssm_heads)
                n += self.ssm_inner * d
            mats = 3 if self.activation in ("swiglu", "geglu") else 2
            if self.is_moe_layer(l):
                n += d * self.num_experts  # router
                n += self.num_experts * 3 * d * self.moe_d_ff
                n += self.num_shared_experts * 3 * d * self.moe_d_ff
            elif self.d_ff:
                n += mats * d * self.d_ff
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared only)."""
        if self.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        layers = range(self.num_layers)
        inactive = 0
        for l in layers:
            if self.is_moe_layer(l):
                inactive += (self.num_experts - self.num_experts_per_tok) \
                    * 3 * self.d_model * self.moe_d_ff
        return full - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

ARCHS = (
    "starcoder2_3b", "gemma_7b", "deepseek_coder_33b", "deepseek_7b",
    "qwen3_moe_235b", "deepseek_v2_236b", "chameleon_34b", "mamba2_1p3b",
    "jamba_52b", "seamless_m4t_medium",
)

# canonical --arch ids (hyphenated, as assigned)
ARCH_IDS = {
    "starcoder2-3b": "starcoder2_3b",
    "gemma-7b": "gemma_7b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "deepseek-7b": "deepseek_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "chameleon-34b": "chameleon_34b",
    "mamba2-1.3b": "mamba2_1p3b",
    "jamba-v0.1-52b": "jamba_52b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ARCH_IDS.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod_name = ARCH_IDS.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool,
                                                                    str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (skip per "
                       "assignment; DESIGN.md §4)")
    return True, ""
