"""DeepSeek-Coder-33B [arXiv:2401.14196; hf]: llama-arch, GQA kv=8."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
    head_dim=128, d_ff=19200, vocab_size=32256,
    activation="swiglu", rope_theta=1e5,
    train_microbatches=4,
)

SMOKE = dataclasses.replace(
    CONFIG, train_microbatches=1, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, d_ff=160, vocab_size=256)
