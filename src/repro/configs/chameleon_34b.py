"""Chameleon-34B [arXiv:2405.09818]: early-fusion VLM; VQ image tokens
share the 65536 vocab; qk-norm. Modality frontend = stub (VQ tokens or
precomputed patch embeddings via input_specs)."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=22016, vocab_size=65536,
    activation="swiglu", rope_theta=1e4, qk_norm=True,
    frontend="vision", train_microbatches=4,
)

SMOKE = dataclasses.replace(
    CONFIG, train_microbatches=1, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, d_ff=128, vocab_size=256)
