"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-235B-A22B]: 128 experts top-8,
qk-norm, every layer MoE (no dense FFN)."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    head_dim=128, d_ff=0, vocab_size=151936,
    activation="swiglu", rope_theta=1e6, qk_norm=True,
    num_experts=128, num_experts_per_tok=8, moe_d_ff=1536,
    moe_period=1, opt_state_dtype="bfloat16", train_microbatches=8,
)

SMOKE = dataclasses.replace(
    CONFIG, train_microbatches=1, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, vocab_size=256, num_experts=8, num_experts_per_tok=2,
    moe_d_ff=64)
