"""Deterministic synthetic LM data pipeline.

Stateless: batch(step) is a pure function of (seed, step), so a resumed
run replays exactly the same stream (the checkpoint/restart test relies
on this — a real deployment would checkpoint its data iterator the same
way). Tokens follow a Zipf-ish unigram mixture with short repeated
motifs so the loss actually decreases.

Sharded placement: batches are laid out with the train step's input
sharding (batch over the data axes) via jax.device_put.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


class SyntheticLMData:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, par=None, src_len: int = 0,
                 d_model: int = 0):
        self.V = vocab_size
        self.S = seq_len
        self.B = global_batch
        self.seed = seed
        self.par = par
        self.src_len = src_len
        self.d_model = d_model
        # fixed Zipf unigram distribution + motif table
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, min(vocab_size, 4096) + 1)
        p = 1.0 / ranks ** 1.1
        self.probs = p / p.sum()
        self.motifs = rng.integers(0, min(vocab_size, 4096),
                                   size=(64, 16))

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.choice(len(self.probs), p=self.probs,
                          size=(self.B, self.S + 1)).astype(np.int32)
        # splice in motifs to create learnable structure
        n_motifs = (self.S + 1) // 32
        for b in range(min(self.B, 64)):
            ids = rng.integers(0, 64, n_motifs)
            pos = rng.integers(0, self.S + 1 - 16, n_motifs)
            for i, p0 in zip(ids, pos):
                toks[b, p0:p0 + 16] = self.motifs[i]
        out = {"tokens": toks}
        if self.src_len:
            out["src_embeds"] = rng.normal(
                size=(self.B, self.src_len, self.d_model)).astype(
                    np.float32)
        return self._place(out)

    def _place(self, out):
        if self.par is None or self.par.mesh is None:
            return {k: jax.numpy.asarray(v) for k, v in out.items()}
        mesh = self.par.mesh
        d = {}
        for k, v in out.items():
            spec = P(tuple(self.par.data_axes),
                     *([None] * (v.ndim - 1)))
            d[k] = jax.device_put(v, NamedSharding(mesh, spec))
        return d
