"""The scheduling front door: declarative `Scenario` -> `run` -> `Result`.

One entry point replaces the per-driver engine plumbing that used to be
scattered through `benchmarks/` (DESIGN.md §7): a `Scenario` names the
workload (a trace, a fleet, a synth spec, or a trace file), the policy,
the parameters (or a sweep grid), and the engine — and `run` routes it
to the event-driven host `fabric.engine.Simulator` or the batched XLA
`fabric.jax_engine`, normalizing either outcome into one `Result`.

`Result` is the SINGLE place padding/NaN semantics live:

* ``cct[b, c]`` is NaN for unfinished or padded coflows;
* ``fct[b, f]`` is NaN for unfinished or padded flows;
* ``makespan[b]`` (last absolute FCT) and ``avg_cct[b]`` are NaN when a
  row finished nothing (an all-padding session slab row, an empty
  trace) — never 0.0, which would masquerade as a zero-second replay.

The engine-equivalence contract (jax CCTs within 1% of the numpy
reference at full fidelity) is owned here and regression-tested in
``tests/test_api.py``; drivers consume `Result` and never branch on the
engine again.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import List, Mapping, Optional, Tuple

import numpy as np

from repro.core.coflow import Trace
from repro.core.params import SchedulerParams
from repro.core.policies import resolve_policy

# the one set of mechanism-switch names both engines resolve: traced /
# structure switches on the jax plane, policy ctor kwargs (or
# SchedulerParams fields) on the numpy plane
MECHANISM_KEYS = ("work_conservation", "dynamics_requeue", "lcof",
                  "per_flow_threshold", "clairvoyant")


@dataclasses.dataclass(frozen=True, eq=False)
class Scenario:
    """A declarative scheduling experiment.

    Exactly one trace source must be set: `trace` (one Trace), `traces`
    (a fleet, jax engine replays it batched), `synth` (kwargs for
    `traces.synth.fb_like_trace`), or `trace_path` (the public
    coflow-benchmark format). `sweep` replaces `params` with a grid of
    settings over ONE trace (vmapped on the jax engine, looped on
    numpy). `mechanisms` holds the Fig. 10 ablation switches by their
    shared names; `policy_kwargs` passes extra host-policy ctor args
    (numpy engine only).
    """
    policy: str = "saath"
    engine: str = "numpy"              # "numpy" | "jax"
    params: SchedulerParams = dataclasses.field(
        default_factory=SchedulerParams)
    sweep: Optional[Tuple[SchedulerParams, ...]] = None
    # trace source (exactly one)
    trace: Optional[Trace] = None
    traces: Optional[Tuple[Trace, ...]] = None
    synth: Optional[Mapping] = None
    trace_path: Optional[str] = None
    # engine knobs
    fidelity: str = "flow"             # jax: "flow" | "coflow"
    mechanisms: Optional[Mapping] = None
    policy_kwargs: Optional[Mapping] = None
    max_jump: Optional[float] = None   # numpy: Simulator re-eval cadence
    topology: Optional[object] = None  # fabric model (fabric.topology);
    #                                    None/BigSwitch() = the paper's
    #                                    big switch, LeafSpine(...) adds
    #                                    per-uplink/downlink capacities
    #                                    on BOTH engines
    use_pallas: bool = False           # jax: route contention/max-min
    #                                    through the Pallas kernels
    #                                    (interpret mode off-TPU)
    warm_timing: bool = False          # jax: extra runs split compile
    #                                    time out; no-op on numpy (no
    #                                    compile to split)
    clairvoyance: Optional[bool] = None  # sugar for the "clairvoyant"
    #                                    mechanism switch: False = learn
    #                                    coflow sizes from pilot flows
    #                                    (core.sampling), None = the
    #                                    params.clairvoyant field
    label: str = ""

    def hash(self) -> str:
        """Stable digest of everything that determines the outcome —
        the cache/record key benchmarks persist across PRs."""
        h = hashlib.blake2b(digest_size=8)

        def upd(*parts):
            h.update(repr(parts).encode())

        upd(self.policy, self.engine, self.fidelity, self.label,
            dataclasses.astuple(self.params), self.max_jump,
            repr(self.topology), self.use_pallas, self.clairvoyance)
        if self.sweep is not None:
            upd(tuple(dataclasses.astuple(p) for p in self.sweep))
        upd(tuple(sorted((self.mechanisms or {}).items())),
            tuple(sorted((self.policy_kwargs or {}).items())))
        if self.synth is not None:
            upd("synth", tuple(sorted(self.synth.items())))
        elif self.trace_path is not None:
            upd("path", self.trace_path)
        else:
            for t in resolve_traces(self):
                upd(t.num_ports, len(t.coflows))
                for c in t.coflows:
                    # exact per-flow layout, not permutation-insensitive
                    # aggregates — distinct experiments must not share a
                    # cache/record key
                    h.update(np.float64(c.arrival).tobytes())
                    h.update(np.asarray(
                        [(f.src, f.dst, f.size) for f in c.flows],
                        np.float64).tobytes())
        return h.hexdigest()


def resolve_traces(sc: Scenario) -> List[Trace]:
    """Materialize the scenario's trace source (exactly one allowed)."""
    sources = [sc.trace is not None, sc.traces is not None,
               sc.synth is not None, sc.trace_path is not None]
    if sum(sources) != 1:
        raise ValueError(
            "Scenario needs exactly one trace source: "
            "trace | traces | synth | trace_path")
    if sc.trace is not None:
        return [sc.trace]
    if sc.traces is not None:
        return list(sc.traces)
    if sc.trace_path is not None:
        from repro.traces.loader import load_coflow_benchmark
        return [load_coflow_benchmark(sc.trace_path)]
    from repro.traces.synth import fb_like_trace
    return [fb_like_trace(**dict(sc.synth))]


@dataclasses.dataclass
class Result:
    """Normalized outcome of `run` (see the module docstring for the
    NaN/padding contract). The leading axis is the trace axis for fleet
    scenarios and the setting axis for sweeps."""
    engine: str
    policy: str
    cct: np.ndarray           # (B, C) seconds, arrival-relative
    fct: np.ndarray           # (B, F) seconds, ABSOLUTE completion time
    sent: np.ndarray          # (B, F) bytes
    num_coflows: np.ndarray   # (B,) real (un-padded) coflows per row
    num_flows: np.ndarray     # (B,) real flows per row
    steps: int                # TOTAL coordinator invocations across the
    #                           batch (numpy: summed Simulator steps;
    #                           jax: scan event-steps x lanes) — the
    #                           normalized unit amortized costs divide by
    wall_seconds: float
    compile_seconds: float = 0.0   # jax cold-minus-warm (warm_timing)
    sched_seconds: float = 0.0     # numpy: host time inside the policy
    scenario: Optional[Scenario] = None
    traces: Optional[list] = dataclasses.field(default=None, repr=False)
    params_rows: Optional[list] = dataclasses.field(default=None,
                                                    repr=False)
    _tables: Optional[list] = dataclasses.field(default=None, repr=False)

    @property
    def batch(self) -> int:
        return self.cct.shape[0]

    @property
    def avg_cct(self) -> np.ndarray:
        """(B,) mean CCT over finished real coflows; NaN when none."""
        from repro.fabric.metrics import nan_row_mean

        return nan_row_mean(self.cct)

    @property
    def makespan(self) -> np.ndarray:
        """(B,) last ABSOLUTE flow completion time; NaN when a row
        finished nothing (both engines agree on this through here —
        including zero-flow rows, e.g. an empty trace)."""
        if self.fct.shape[1] == 0:
            return np.full(self.fct.shape[0], np.nan)
        fin = np.isfinite(self.fct)
        safe = np.where(fin, self.fct, -np.inf).max(axis=1)
        return np.where(fin.any(axis=1), safe, np.nan)

    def row_cct(self, b: int = 0) -> np.ndarray:
        """(C_b,) per-coflow CCTs of row `b`, padding trimmed."""
        return self.cct[b, :int(self.num_coflows[b])]

    def row_fct(self, b: int = 0) -> np.ndarray:
        return self.fct[b, :int(self.num_flows[b])]

    def table(self, b: int = 0):
        """Materialize row `b` as a filled `FlowTable` (for the metrics
        helpers that consume tables) — works for BOTH engines, so
        drivers never special-case `run_to_table` again."""
        if self._tables is not None:
            return self._tables[b]
        if self.traces is None:
            raise ValueError("Result carries no traces to rebuild from")
        from repro.fabric.state import FlowTable

        p = (self.params_rows[b] if self.params_rows
             else SchedulerParams())
        t = FlowTable.from_trace(self.traces[b], p.port_bw)
        F, C = t.size.shape[0], t.num_coflows
        t.sent[:] = self.sent[b, :F]
        t.fct[:] = self.fct[b, :F]
        t.done[:] = np.isfinite(self.fct[b, :F])
        t.cct[:] = self.cct[b, :C]
        t.finished[:] = np.isfinite(self.cct[b, :C])
        t.active[:] = False
        return t

    def summary(self, b: int = 0) -> dict:
        """Flat record for machine-readable benchmark emission."""
        cct = self.row_cct(b)
        fin = cct[np.isfinite(cct)]
        return {
            "engine": self.engine, "policy": self.policy,
            "scenario": self.scenario.hash() if self.scenario else "",
            "label": self.scenario.label if self.scenario else "",
            "row": b,
            "num_coflows": int(self.num_coflows[b]),
            "avg_cct": float(self.avg_cct[b]),
            "p50_cct": float(np.percentile(fin, 50)) if fin.size
            else float("nan"),
            "p90_cct": float(np.percentile(fin, 90)) if fin.size
            else float("nan"),
            "makespan": float(self.makespan[b]),
            "steps": self.steps,
            "wall_seconds": self.wall_seconds,
            "compile_seconds": self.compile_seconds,
        }


def result_from_completions(completions, *, engine: str = "jax",
                            policy: str = "saath", steps: int = 0,
                            wall_seconds: float = 0.0) -> Result:
    """Normalize a stream of online `CompletedCoflow`s (one session /
    tenant) into the same single-row `Result` the offline engines
    produce — NaN/padding semantics, `avg_cct`, `makespan`, `summary()`
    and `benchmarks.common.record` all work unchanged. An empty stream
    yields the canonical "nothing completed" row (NaN aggregates)."""
    comps = list(completions)
    C = len(comps)
    F = int(sum(d.fct.size for d in comps))
    cct = np.full((1, max(C, 0)), np.nan)
    fct = np.full((1, F), np.nan)
    sent = np.zeros((1, F))
    lo = 0
    for i, d in enumerate(comps):
        n = d.fct.size
        cct[0, i] = d.cct
        fct[0, lo:lo + n] = d.fct
        if d.size is not None:
            sent[0, lo:lo + n] = d.size
        lo += n
    return Result(engine=engine, policy=policy, cct=cct, fct=fct,
                  sent=sent, num_coflows=np.array([C]),
                  num_flows=np.array([F]), steps=steps,
                  wall_seconds=wall_seconds)


def check_mechanisms(mechanisms: "Mapping | None") -> dict:
    """The ONE validator for mechanism-switch names — shared by
    `Scenario` routing, `SessionPool`, and `SaathSession`. Returns a
    plain dict copy; raises on unknown keys."""
    mech = dict(mechanisms or {})
    unknown = set(mech) - set(MECHANISM_KEYS)
    if unknown:
        raise ValueError(
            f"unknown mechanism switches {sorted(unknown)}; "
            f"available: {', '.join(MECHANISM_KEYS)}")
    return mech


def _split_mechanisms(sc: Scenario):
    """Validate mechanism names once for both engines; the
    `clairvoyance` sugar field folds into the shared "clairvoyant"
    mechanism switch (explicit `mechanisms` entry wins via the fold
    order — the sugar only fills the gap)."""
    mech = check_mechanisms(sc.mechanisms)
    if sc.clairvoyance is not None:
        mech.setdefault("clairvoyant", sc.clairvoyance)
    return mech


def run(scenario: Scenario) -> Result:
    """Execute a Scenario on its engine and normalize the outcome."""
    sc = scenario
    if sc.engine not in ("numpy", "jax"):
        raise ValueError(
            f"unknown engine {sc.engine!r}; available: numpy, jax")
    if sc.fidelity not in ("flow", "coflow"):
        raise ValueError(f"unknown fidelity {sc.fidelity!r}; "
                         f"available: flow, coflow")
    if sc.engine == "numpy" and sc.fidelity != "flow":
        raise ValueError(
            "the numpy reference replay is inherently flow-fidelity; "
            'fidelity="coflow" is the jax engine\'s throughput mode')
    resolve_policy(sc.policy, sc.engine)   # raises with available list
    from repro.fabric.topology import normalize_topology
    normalize_topology(sc.topology)        # raises on a non-fabric object
    traces = resolve_traces(sc)
    settings = list(sc.sweep) if sc.sweep is not None else None
    if settings is not None and len(traces) != 1:
        raise ValueError("sweep scenarios take exactly one trace")
    if sc.engine == "numpy":
        return _run_numpy(sc, traces, settings)
    return _run_jax(sc, traces, settings)


def _run_numpy(sc: Scenario, traces: List[Trace],
               settings) -> Result:
    from repro.core.policies import make_policy
    from repro.fabric.engine import Simulator
    from repro.fabric.state import FlowTable

    mech = _split_mechanisms(sc)

    def one(trace, params):
        if "dynamics_requeue" in mech:
            params = dataclasses.replace(
                params, dynamics_requeue=mech["dynamics_requeue"])
        if "work_conservation" in mech:
            params = dataclasses.replace(
                params, work_conservation=mech["work_conservation"])
        if "clairvoyant" in mech:
            params = dataclasses.replace(
                params, clairvoyant=mech["clairvoyant"])
        pol_kw = dict(sc.policy_kwargs or {})
        for k in ("lcof", "per_flow_threshold"):
            if k in mech:
                pol_kw[k] = mech[k]
        table = FlowTable.from_trace(trace, params.port_bw)
        policy = make_policy(sc.policy, params, **pol_kw)
        res = Simulator(params, max_jump=sc.max_jump,
                        topology=sc.topology).run(table, policy)
        return res, params

    t0 = time.perf_counter()
    if settings is not None:
        rows = [one(traces[0], p) for p in settings]
        row_traces = [traces[0]] * len(settings)
    else:
        rows = [one(t, sc.params) for t in traces]
        row_traces = traces
    wall = time.perf_counter() - t0

    results = [r for r, _ in rows]
    params_rows = [p for _, p in rows]
    B = len(results)
    Cm = max(r.table.num_coflows for r in results)
    Fm = max(r.table.size.shape[0] for r in results)
    cct = np.full((B, Cm), np.nan)
    fct = np.full((B, Fm), np.nan)
    sent = np.zeros((B, Fm))
    for b, r in enumerate(results):
        C, F = r.table.num_coflows, r.table.size.shape[0]
        cct[b, :C] = r.table.cct
        fct[b, :F] = r.table.fct
        sent[b, :F] = r.table.sent
    return Result(
        engine="numpy", policy=sc.policy, cct=cct, fct=fct, sent=sent,
        num_coflows=np.array([r.table.num_coflows for r in results]),
        num_flows=np.array([r.table.size.shape[0] for r in results]),
        steps=sum(r.steps for r in results), wall_seconds=wall,
        sched_seconds=sum(r.sched_seconds for r in results),
        scenario=sc, traces=row_traces, params_rows=params_rows,
        _tables=[r.table for r in results])


def _run_jax(sc: Scenario, traces: List[Trace], settings) -> Result:
    from repro.fabric import jax_engine

    if sc.policy_kwargs:
        raise ValueError(
            "policy_kwargs are numpy-engine only; use mechanisms= for "
            "the shared ablation switches")
    mech = _split_mechanisms(sc)

    if settings is not None:
        if mech:
            raise ValueError(
                "sweep scenarios encode work_conservation / "
                "dynamics_requeue / clairvoyant per setting "
                "(SchedulerParams fields); lcof / per_flow_threshold "
                "ablations need per-setting scenarios")

        def go():
            return jax_engine.simulate_sweep(
                traces[0], settings, fidelity=sc.fidelity,
                topology=sc.topology, use_pallas=sc.use_pallas)
        row_traces = [traces[0]] * len(settings)
        params_rows = settings
        counts = [(len(traces[0].coflows), traces[0].num_flows)
                  ] * len(settings)
    else:
        def go():
            return jax_engine.simulate_batch(
                traces, sc.params, fidelity=sc.fidelity,
                topology=sc.topology, use_pallas=sc.use_pallas, **mech)
        row_traces = traces
        params_rows = [sc.params] * len(traces)
        counts = [(len(t.coflows), t.num_flows) for t in traces]

    t0 = time.perf_counter()
    eres = go()
    wall = time.perf_counter() - t0
    compile_s = 0.0
    if sc.warm_timing:
        # best of two warm runs: one-shot wall clocks on shared/throttled
        # hosts wander ±15%, which matters at the fleet speedup gate
        warm = float("inf")
        for _ in range(2):
            t0 = time.perf_counter()
            eres = go()
            warm = min(warm, time.perf_counter() - t0)
        compile_s, wall = max(wall - warm, 0.0), warm

    return Result(
        engine="jax", policy=sc.policy,
        cct=np.asarray(eres.cct, np.float64),
        fct=np.asarray(eres.fct, np.float64),
        sent=np.asarray(eres.sent, np.float64),
        num_coflows=np.array([c for c, _ in counts]),
        num_flows=np.array([f for _, f in counts]),
        steps=eres.events * eres.cct.shape[0], wall_seconds=wall,
        compile_seconds=compile_s, scenario=sc, traces=row_traces,
        params_rows=params_rows)


__all__ = ["Scenario", "Result", "run", "resolve_traces",
           "result_from_completions", "MECHANISM_KEYS",
           "check_mechanisms"]
