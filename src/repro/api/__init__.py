"""`repro.api` — the one scheduling front door (DESIGN.md §7/§8).

Declarative experiments::

    from repro.api import Scenario, run
    res = run(Scenario(policy="saath", engine="jax",
                       synth=dict(num_coflows=60, num_ports=24)))
    res.avg_cct, res.makespan, res.table(0)

Online sessions::

    from repro.api import SaathSession
    sess = SaathSession(params, num_ports=24, backend="jax")
    sess.submit(coflows); sess.advance(0.5); done = sess.poll()

Multi-tenant fleets (one slab, one dispatch per step)::

    from repro.api import SessionPool
    pool = SessionPool(params, num_ports=24, max_sessions=16)
    tenants = [pool.session() for _ in range(16)]
    pool.advance(0.5); done = pool.poll()
"""
from repro.api.pool import PoolFullError, SessionPool
from repro.api.scenario import (MECHANISM_KEYS, Result, Scenario,
                                resolve_traces, result_from_completions,
                                run)
from repro.api.session import CompletedCoflow, SaathSession

__all__ = ["Scenario", "Result", "run", "resolve_traces",
           "result_from_completions", "MECHANISM_KEYS", "SaathSession",
           "CompletedCoflow", "SessionPool", "PoolFullError"]
