"""`repro.api` — the one scheduling front door (DESIGN.md §7).

Declarative experiments::

    from repro.api import Scenario, run
    res = run(Scenario(policy="saath", engine="jax",
                       synth=dict(num_coflows=60, num_ports=24)))
    res.avg_cct, res.makespan, res.table(0)

Online sessions::

    from repro.api import SaathSession
    sess = SaathSession(params, num_ports=24, backend="jax")
    sess.submit(coflows); sess.advance(0.5); done = sess.poll()
"""
from repro.api.scenario import (MECHANISM_KEYS, Result, Scenario,
                                resolve_traces, run)
from repro.api.session import CompletedCoflow, SaathSession

__all__ = ["Scenario", "Result", "run", "resolve_traces",
           "MECHANISM_KEYS", "SaathSession", "CompletedCoflow"]
