"""Multi-tenant serving plane: a fleet of `SaathSession`s on ONE slab.

A `SessionPool` hosts up to `max_sessions` concurrent online sessions
as ROWS of a single leading-axis-batched `TraceBatch` slab, so one
dispatch of the jitted `fabric.jax_engine` tick scan advances every
tenant's coordinator at once (`jax.vmap` over the row axis) instead of
N sequential scans over N private slabs. This is the paper's global
coordinator serving many tenants (PAPER.md §5 / Table 2 is about
per-decision coordinator cost under load): the marginal cost of an
extra tenant is one more vmapped lane, not one more compiled replica.

Ownership (DESIGN.md §8):

* the POOL owns the device-facing slab: the padded `TraceBatch` (rows
  recycled via `traces.batch.pack_row`/`blank_row`, flow/coflow
  capacities shared across rows and grown geometrically) and the
  `EngineState` mirror (numpy leaves between dispatches, so dirty rows
  are rewritten in place);
* each `SaathSession` is a VIEW onto one pool row: it keeps the host
  truth for its tenant (live `_Entry`s, clock, δ-grid tick, epoch,
  pending-horizon mirror) and delegates every device interaction —
  `advance`, `plan_tick`, slab membership — to the pool. A standalone
  `SaathSession(backend="jax")` is simply the row-0 view of a private
  single-row pool, so single-session code is the B=1 case of the same
  machinery.

Rows advance to INDEPENDENT horizons: `jax_engine.session_advance`
takes a per-row `n_end`, and a lane at (or past) its horizon is an
exact no-op, so `pool.advance(dt)` moves every tenant together in one
dispatch chain while `session.advance(dt)` on a single view moves only
its row (the other lanes no-op). Per-session results are bitwise
identical to standalone sessions — padding never perturbs a row's
arithmetic (tests/test_pool.py).

Long-horizon sessions re-base their δ-grid EPOCH on re-pack once the
row's relative tick exceeds ``REBASE_TICKS``: arrivals, deadlines, and
completion times are stored relative to the row epoch, so a session
that has been up for hours keeps full δ resolution in the f32 slab
(absolute times would lose the grid beyond ~1e6 ticks).
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import List, Optional, Tuple

import jax
import numpy as np

from repro.core.params import SchedulerParams

# re-base a row's grid epoch at the first re-pack past this relative
# tick: f32 keeps exact integers to 2^24 and δ-resolution sums well
# past 2^20, so re-basing at 2^20 leaves a 16x safety margin
REBASE_TICKS = 1 << 20
# hard per-dispatch cap on relative ticks — a single advance spanning
# more than this is split into epochs (each split re-packs and
# re-bases, so `tickf` arithmetic never leaves the f32-exact range)
MAX_REL_TICKS = 1 << 22


class SessionPool:
    """An admission-capped fleet of jax-backend `SaathSession`s sharing
    one device slab.

    All sessions share the pool's `SchedulerParams`, fabric size
    (`num_ports`), mechanism switches and fidelity — one compiled tick
    structure serves the whole fleet. `session()` admits a new tenant
    (raising when the pool is full); `release()` (or
    `SaathSession.close()`) frees the row for the next tenant.
    """

    def __init__(self, params: Optional[SchedulerParams] = None, *,
                 num_ports: int, max_sessions: int = 16,
                 mechanisms: Optional[dict] = None,
                 fidelity: str = "flow", kernel: Optional[str] = None,
                 chunk: int = 32, min_coflow_capacity: int = 16,
                 min_flow_capacity: int = 64):
        from repro.api.scenario import MECHANISM_KEYS
        from repro.fabric import jax_engine

        mech = dict(mechanisms or {})
        unknown = set(mech) - set(MECHANISM_KEYS)
        if unknown:
            raise ValueError(
                f"unknown mechanism switches {sorted(unknown)}; "
                f"available: {', '.join(MECHANISM_KEYS)}")
        params = params or SchedulerParams()
        if "dynamics_requeue" in mech:
            params = dataclasses.replace(
                params, dynamics_requeue=mech["dynamics_requeue"])
        if "work_conservation" in mech:
            params = dataclasses.replace(
                params, work_conservation=mech["work_conservation"])
        self.params = params
        self.num_ports = int(num_ports)
        self.kernel = kernel
        self.chunk = int(chunk)
        self.max_sessions = int(max_sessions)
        if self.max_sessions <= 0:
            raise ValueError("max_sessions must be positive")

        self._je = jax_engine
        self._ep = jax_engine.EngineParams.from_scheduler(
            params,
            work_conservation=mech.get("work_conservation"),
            dynamics_requeue=mech.get("dynamics_requeue"),
            lcof=mech.get("lcof", True),
            per_flow_threshold=mech.get("per_flow_threshold", True))
        self._features = jax_engine.features_for(
            params, fidelity=fidelity,
            dynamics_requeue=mech.get("dynamics_requeue"),
            lcof=mech.get("lcof", True),
            per_flow_threshold=mech.get("per_flow_threshold", True))

        self._C_cap = int(min_coflow_capacity)
        self._F_cap = int(min_flow_capacity)
        self._sessions: List[Optional["object"]] = \
            [None] * self.max_sessions
        self._free = list(range(self.max_sessions))
        self._blank_rows: set = set()
        self._tb = None        # TraceBatch (numpy, B rows)
        self._state = None     # EngineState with numpy leaves

    # ---- admission -------------------------------------------------------

    @property
    def num_sessions(self) -> int:
        return self.max_sessions - len(self._free)

    @property
    def sessions(self) -> list:
        return [s for s in self._sessions if s is not None]

    def session(self):
        """Admit a new tenant session; raises `RuntimeError` when the
        pool is at its admission cap."""
        from repro.api.session import SaathSession

        if not self._free:
            raise RuntimeError(
                f"SessionPool is full ({self.max_sessions} sessions); "
                f"release one (or raise max_sessions) to admit more")
        row = self._free.pop(0)
        sess = SaathSession(self.params, num_ports=self.num_ports,
                            backend="jax", kernel=self.kernel,
                            chunk=self.chunk, _pool=self, _row=row)
        self._sessions[row] = sess
        self._blank_rows.discard(row)
        return sess

    def release(self, sess) -> None:
        """Free a session's row (dropping any unfinished coflows); the
        row is recycled for the next admitted tenant."""
        row = sess._row
        if row is None or self._sessions[row] is not sess:
            raise ValueError("session does not belong to this pool")
        self._sessions[row] = None
        self._blank_rows.add(row)
        bisect.insort(self._free, row)
        sess._row = None
        sess._pool = None

    def _adopt(self, sess) -> None:
        """Bind an externally-constructed standalone session as row 0
        of this (private, single-row) pool."""
        assert self.max_sessions == 1 and self._free == [0]
        self._free.clear()
        self._sessions[0] = sess

    # ---- fleet stepping --------------------------------------------------

    def advance(self, dt: float) -> float:
        """Move EVERY admitted session's clock by `dt` seconds and
        schedule all their δ-grid ticks with one vmapped dispatch chain;
        returns the (common) elapsed fleet time."""
        if dt < 0:
            raise ValueError("advance(dt) needs dt >= 0")
        delta = self.params.delta
        targets = []
        for s in self.sessions:
            s._clock += float(dt)
            targets.append((s, int(math.floor(s._clock / delta + 1e-9))))
        self._advance(targets)
        return float(dt)

    def poll(self) -> List[Tuple[object, object]]:
        """Completed-since-last-poll coflows across the fleet, as
        (session, CompletedCoflow) pairs."""
        out = []
        for s in self.sessions:
            out.extend((s, d) for d in s.poll())
        return out

    # ---- slab machinery (the device-facing half of the row-view
    # contract; sessions call these with themselves as the row) --------

    def _advance(self, targets) -> None:
        """Advance the given (session, global n_end) targets; sessions
        not listed keep their row at its current tick (exact no-ops in
        the dispatch)."""
        work = {}
        for s, n_end in targets:
            if n_end <= s._tick:
                continue
            if not s._live:
                # nothing on the row: the grid is advanced host-side
                s._tick = n_end
                continue
            work[s._row] = (s, n_end)
        while work:
            self._ensure()
            ne = np.asarray(self._state.tick, np.float32).copy()
            for r, (s, n_end) in work.items():
                ne[r] = min(n_end, s._epoch + MAX_REL_TICKS) - s._epoch
            state, _ = self._je.session_advance(
                self._state, self._tb, self._ep, n_end=ne,
                chunk=self.chunk, kernel=self.kernel,
                features=self._features)
            self._state = jax.tree_util.tree_map(
                lambda a: np.array(a), state)
            nxt = {}
            for r, (s, n_end) in work.items():
                self._sync_row(s)
                if s._tick >= n_end or \
                        all(e.finished for e in s._live.values()):
                    continue
                # the MAX_REL_TICKS split: re-pack (re-basing the
                # epoch) and keep going toward the real target
                s._tb_dirty = True
                nxt[r] = (s, n_end)
            work = nxt

    def _plan_tick(self, sess) -> np.ndarray:
        """One wave-planning coordinator tick for ONE session row; the
        other rows are masked no-ops. Returns the row's admitted mask."""
        self._ensure()
        mask = np.zeros(self.max_sessions, bool)
        mask[sess._row] = True
        state, admitted = self._je.session_plan_tick(
            self._state, self._tb, self._ep, kernel=self.kernel,
            features=self._features, row_mask=mask)
        self._state = jax.tree_util.tree_map(lambda a: np.array(a),
                                             state)
        adm = np.asarray(admitted)[sess._row]
        self._sync_row(sess)
        return adm

    def _ensure(self) -> None:
        """Re-pack dirty rows (and re-blank released ones) into the
        shared slab, growing the flow/coflow capacities geometrically
        when any row outgrows them (a growth re-packs every row — the
        padded shapes are shared, but per-row state is carried through
        the sessions' host entries, so nothing is lost)."""
        from repro.traces.batch import blank_row, empty_batch

        need_c = need_f = 0
        for s in self.sessions:
            if s._tb_dirty:
                need_c = max(need_c, len(s._live))
                need_f = max(need_f, sum(e.size.size
                                         for e in s._live.values()))
        grew = False
        while self._C_cap < need_c:
            self._C_cap *= 2
            grew = True
        while self._F_cap < need_f:
            self._F_cap *= 2
            grew = True
        if self._tb is None or grew:
            self._tb = empty_batch(self.max_sessions,
                                   flow_capacity=self._F_cap,
                                   coflow_capacity=self._C_cap,
                                   port_capacity=self.num_ports)
            self._state = self._blank_state()
            self._blank_rows.clear()
            for s in self.sessions:
                s._tb_dirty = True
        for r in self._blank_rows:
            blank_row(self._tb, r)
            self._blank_state_row(r)
        self._blank_rows.clear()
        for s in self.sessions:
            if s._tb_dirty:
                self._repack_row(s)
            elif s._state_dirty:
                self._restate_row(s)

    def _blank_state(self):
        from repro.core.jax_coordinator import CoordState
        from repro.fabric.jax_engine import EngineState

        B, C, F = self.max_sessions, self._C_cap, self._F_cap
        return EngineState(
            coord=CoordState(np.full((B, C), -1, np.int32),
                             np.full((B, C), np.inf, np.float32),
                             np.zeros((B, C), bool)),
            sent=np.zeros((B, F), np.float32),
            done=np.ones((B, F), bool),
            fct=np.zeros((B, F), np.float32),
            finished=np.ones((B, C), bool),
            cct=np.full((B, C), np.nan, np.float32),
            t0=np.zeros((B,), np.float32),
            tick=np.zeros((B,), np.int32),
            rate=np.zeros((B, F), np.float32),
            pend_sent=np.zeros((B, F), np.float32),
            pend_tick=np.zeros((B,), np.float32),
            pend_next=np.zeros((B,), np.float32))

    def _blank_state_row(self, r: int) -> None:
        st = self._state
        st.coord.queue[r] = -1
        st.coord.deadline[r] = np.inf
        st.coord.running[r] = False
        st.sent[r] = 0.0
        st.done[r] = True
        st.fct[r] = 0.0
        st.finished[r] = True
        st.cct[r] = np.nan
        st.t0[r] = 0.0
        st.tick[r] = 0
        st.rate[r] = 0.0
        st.pend_sent[r] = 0.0
        st.pend_tick[r] = 0.0
        st.pend_next[r] = 0.0

    def _repack_row(self, s) -> None:
        from repro.traces.batch import pack_row

        if s._tick - s._epoch >= REBASE_TICKS:
            # re-base the row's grid epoch: all slab times below are
            # stored relative to it, restoring δ resolution in f32
            s._epoch = s._tick
        table = s._rebuild_table()
        pack_row(self._tb, s._row, table,
                 arrival_rank=[e.rank for e in s._slots])
        s._flow_lo = table.flow_lo.copy()
        s._flow_hi = table.flow_hi.copy()
        s._tb_dirty = False
        self._restate_row(s)

    def _restate_row(self, s) -> None:
        """Rewrite one row of the EngineState mirror from the session's
        host entries (the carry that survives re-packs)."""
        st, r = self._state, s._row
        epoch_t = s._epoch * self.params.delta
        self._blank_state_row(r)
        st.done[r] = ~self._tb.flow_valid[r]
        st.finished[r] = ~self._tb.coflow_valid[r]
        for i, e in enumerate(s._slots):
            lo, hi = s._flow_lo[i], s._flow_hi[i]
            st.sent[r, lo:hi] = e.sent
            st.done[r, lo:hi] = e.done
            st.fct[r, lo:hi] = np.where(
                e.done, np.nan_to_num(e.fct) - epoch_t, 0.0)
            st.finished[r, i] = e.finished
            st.cct[r, i] = e.cct
            st.coord.queue[r, i] = e.queue
            st.coord.deadline[r, i] = e.deadline - epoch_t \
                if np.isfinite(e.deadline) else np.inf
            st.coord.running[r, i] = e.running
            st.rate[r, lo:hi] = e.rate
            st.pend_sent[r, lo:hi] = e.pend_sent
        st.tick[r] = s._tick - s._epoch
        if s._pend is not None:
            st.pend_tick[r] = s._pend[0] - s._epoch
            st.pend_next[r] = s._pend[1] - s._epoch
        s._state_dirty = False

    def _sync_row(self, s) -> None:
        """Mirror one row of the device state back into the session's
        host entries (absolute f64 times reconstructed from the row
        epoch)."""
        st, r = self._state, s._row
        epoch_t = s._epoch * self.params.delta
        sent = np.asarray(st.sent[r], np.float64)
        done = np.asarray(st.done[r])
        fct = np.asarray(st.fct[r], np.float64)
        finished = np.asarray(st.finished[r])
        cct = np.asarray(st.cct[r], np.float64)
        queue = np.asarray(st.coord.queue[r])
        deadline = np.asarray(st.coord.deadline[r], np.float64)
        running = np.asarray(st.coord.running[r])
        rate = np.asarray(st.rate[r], np.float64)
        pend_sent = np.asarray(st.pend_sent[r], np.float64)
        for i, e in enumerate(s._slots):
            lo, hi = s._flow_lo[i], s._flow_hi[i]
            e.sent = sent[lo:hi].copy()
            e.done = done[lo:hi].copy()
            e.fct = np.where(e.done, fct[lo:hi] + epoch_t, np.nan)
            e.rate = rate[lo:hi].copy()
            e.pend_sent = pend_sent[lo:hi].copy()
            e.finished = bool(finished[i])
            e.cct = float(cct[i])
            e.queue = int(queue[i])
            e.deadline = float(deadline[i] + epoch_t)
            e.running = bool(running[i])
        tick_rel = int(st.tick[r])
        s._tick = s._epoch + tick_rel
        pn = float(st.pend_next[r])
        s._pend = (s._epoch + int(st.pend_tick[r]), s._epoch + int(pn)) \
            if pn > tick_rel else None


__all__ = ["SessionPool", "REBASE_TICKS"]
