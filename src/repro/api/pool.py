"""Multi-tenant serving plane: a fleet of `SaathSession`s on ONE slab.

A `SessionPool` hosts up to `max_sessions` concurrent online sessions
as ROWS of a single leading-axis-batched `TraceBatch` slab, so one
dispatch of the jitted `fabric.jax_engine` tick scan advances every
tenant's coordinator at once (`jax.vmap` over the row axis) instead of
N sequential scans over N private slabs. This is the paper's global
coordinator serving many tenants (PAPER.md §5 / Table 2 is about
per-decision coordinator cost under load): the marginal cost of an
extra tenant is one more vmapped lane, not one more compiled replica.

Ownership (DESIGN.md §8):

* the POOL owns the device-facing slab, and since ISSUE 5 the
  authoritative `TraceBatch` + `EngineState` leaves LIVE ON DEVICE
  between dispatches. Membership/state changes (`submit`, `poll`
  retirement, `release`, `complete`) mark rows dirty, and `_ensure`
  applies them as DIRTY-ROW SCATTER updates (`jax_engine.scatter_rows`
  over host-staged `traces.batch.pack_row` rows) — a clean row never
  re-crosses the host-device boundary. Numpy mirrors survive only as
  the lazily-materialized debug/oracle view (`host_view()`) and the
  per-row host entries sessions carry;
* each `SaathSession` is a VIEW onto one pool row: it keeps the host
  truth for its tenant (live `_Entry`s, clock, δ-grid tick, epoch,
  pending-horizon mirror) and delegates every device interaction —
  `advance`, `plan_tick`, slab membership — to the pool. After a
  dispatch the row's host entries are STALE until someone looks
  (`poll`, `snapshot`, a re-pack): `_materialize` then gathers exactly
  the stale rows back (`jax_engine.gather_rows`) in one dispatch. A
  standalone `SaathSession(backend="jax")` is simply the row-0 view of
  a private single-row pool, so single-session code is the B=1 case of
  the same machinery.

Per-tenant scheduler parameters: every slab row carries its OWN
`EngineParams` (thresholds, δ, deadline factor, traced wc/requeue/
lcof/per-flow switches) — `session(params=..., mechanisms=...)` admits
a tenant under its own configuration, and the stacked (B,)-leaf
`EngineParams` rides the same single while_loop dispatch
(`jax_engine.session_advance` vmaps the parameter rows exactly like
`simulate_sweep` does for offline grids). The one compiled-shape
constraint is `num_queues` (K): all tenants must share the pool's K.
The STATIC structure switches (`features_for`) are OR-combined across
admitted rows, mirroring `simulate_sweep`'s "dynamics compiled in when
ANY setting re-queues" rule.

Rows advance to INDEPENDENT horizons: `jax_engine.session_advance`
takes a per-row `n_end`, and a lane at (or past) its horizon is an
exact no-op, so `pool.advance(dt)` moves every tenant together in one
dispatch chain while `session.advance(dt)` on a single view moves only
its row (the other lanes no-op). Per-session results are bitwise
identical to standalone sessions — padding never perturbs a row's
arithmetic (tests/test_pool.py, tests/test_pool_fuzz.py).

Long-horizon sessions re-base their δ-grid EPOCH on re-pack once the
row's relative tick exceeds ``REBASE_TICKS``: arrivals, deadlines, and
completion times are stored relative to the row epoch, so a session
that has been up for hours keeps full δ resolution in the f32 slab
(absolute times would lose the grid beyond ~1e6 ticks). The epoch is
strictly PER ROW — an old tenant re-basing never perturbs a young
neighbor's grid (tests/test_pool.py).

Sharded slab (ISSUE 6): ``SessionPool(..., shards=N)`` partitions the
row axis across N devices on a 1-D "rows" mesh
(`jax_engine.row_mesh`): the slab is kept in a FOLDED dispatch layout
— every leaf reshaped ``(B, ...) -> (N, B/N, ...)`` with shard i
resident on device i — and `session_advance` `pmap`s the shard axis,
so each device runs its OWN while_loop over its rows and terminates
independently (pmap compiles the exact single-slab program per
device — no GSPMD partitioner, hence no partitioner-inserted
collectives, which would deadlock divergent per-shard loops on the
CPU backend). The dirty-row scatter stage keeps ONE QUEUE PER SHARD
(a dirty row only funnels an update through its owning shard). Rows
are independent sessions — there is no cross-shard communication
inside the loop — so an N-shard pool is bitwise-identical to the
1-shard pool (tests/test_pool_sharded.py). CPU CI gets N host devices
via ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

Async double-buffered dispatch (ISSUE 6, default ON): `advance` ENQUEUES
the fleet dispatch and returns without downloading the tiny control
mirrors — the device (tick, finished) handles are parked as the
pool's deferred ctl and consumed lazily (`_sync_ctl`) at the next
poll / snapshot / re-pack / `host_view` point. Chained advances
overwrite the parked ctl, so a burst of K advances costs K dispatches
but ONE control download. This is safe because ticks only grow and a
lane at (or past) the horizon a dispatch hands it is an exact no-op:
a STALE tick mirror used as an untargeted row's horizon can only
UNDER-ask, never perturb. ``async_dispatch=False`` restores the
blocking per-dispatch download.

Opt-in pinned features: ``SessionPool(..., features=(pfw, dyn, abl))``
freezes the compiled structure switches up front, so a heterogeneous
tenant joining mid-flight NEVER recompiles the fleet executable —
admission validates that the tenant's required features are compiled
in (the same OR-superset rule `_ensure` applies dynamically: the
traced per-row parameter switches make compiled-in machinery
semantics-preserving for rows that don't use it).

`pool.io` counts every host-device crossing (row scatters/gathers,
full rebuild uploads, the tiny control reads — deferred, under async
dispatch, to the next sync point), which is how
`benchmarks/pool_throughput.py` proves clean-row advances upload
nothing.
"""
from __future__ import annotations

import bisect
import functools
import math
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.sanitize import accounted_transfer
from repro.core.params import SchedulerParams

# re-base a row's grid epoch at the first re-pack past this relative
# tick: f32 keeps exact integers to 2^24 and δ-resolution sums well
# past 2^20, so re-basing at 2^20 leaves a 16x safety margin
REBASE_TICKS = 1 << 20
# hard per-dispatch cap on relative ticks — a single advance spanning
# more than this is split into epochs (each split re-packs and
# re-bases, so `tickf` arithmetic never leaves the f32-exact range)
MAX_REL_TICKS = 1 << 22


def _io_accounted(method):
    """Mark a SessionPool method as a SANCTIONED host-device crossing:
    its transfers are what the `pool.io` counters cover, so they run
    inside an `accounted_transfer` carve-out. Everything else the pool
    does is then provably transfer-free under
    `repro.analysis.sanitize.assert_no_transfers` — the sanitizer the
    pool suites arm around clean-row advances."""
    @functools.wraps(method)
    def wrapper(self, *args, **kwargs):
        with accounted_transfer():
            return method(self, *args, **kwargs)
    return wrapper


def _tree_nbytes(tree) -> int:
    return int(sum(np.asarray(leaf).nbytes
                   for leaf in jax.tree_util.tree_leaves(tree)))


class PoolFullError(RuntimeError):
    """The pool is at its admission cap (`max_sessions` live rows).

    The ONE failure `CoflowServer.register` translates into an
    `AdmissionError`; any other pool/session fault propagates untouched
    (it is a bug or a bad configuration, not an admission decision)."""


class SessionPool:
    """An admission-capped fleet of jax-backend `SaathSession`s sharing
    one device-resident slab.

    All sessions share the pool's fabric size (`num_ports`), fidelity,
    and queue count K — one compiled tick structure serves the whole
    fleet — but each admitted tenant may bring its own
    `SchedulerParams`/mechanism switches (`session(params=...,
    mechanisms=...)`); rows without overrides run the pool defaults.
    `session()` admits a new tenant (raising when the pool is full);
    `release()` (or `SaathSession.close()`) frees the row for the next
    tenant.
    """

    def __init__(self, params: Optional[SchedulerParams] = None, *,
                 num_ports: int, max_sessions: int = 16,
                 mechanisms: Optional[dict] = None,
                 fidelity: str = "flow", kernel: Optional[str] = None,
                 chunk: int = 32, min_coflow_capacity: int = 16,
                 min_flow_capacity: int = 64, shards: int = 1,
                 async_dispatch: bool = True,
                 features: Optional[tuple] = None,
                 topology=None):
        from repro.fabric import jax_engine
        from repro.fabric.topology import (leaf_links_for,
                                           normalize_topology)

        self._je = jax_engine
        self.num_ports = int(num_ports)
        # fabric model, PINNED at construction like num_ports/K: the
        # link segment layout is part of the slab shape (Lf leaves) and
        # wc_maxmin is a compiled structure switch, so heterogeneous
        # topologies cannot share one slab without recompiles
        self.topology = normalize_topology(topology)
        self._Lf = leaf_links_for(self.topology, self.num_ports)
        self.kernel = kernel
        self.chunk = int(chunk)
        self.max_sessions = int(max_sessions)
        if self.max_sessions <= 0:
            raise ValueError("max_sessions must be positive")
        self._fidelity = fidelity
        self.shards = int(shards)
        if self.shards > 1:
            if self.max_sessions % self.shards:
                raise ValueError(
                    f"max_sessions ({self.max_sessions}) must be a "
                    f"multiple of shards ({self.shards}): the row axis "
                    f"is partitioned evenly across the mesh")
            self._mesh = jax_engine.row_mesh(self.shards)
            self._sharding = jax.sharding.NamedSharding(
                self._mesh, jax.sharding.PartitionSpec("rows"))
        else:
            if self.shards < 1:
                raise ValueError("shards must be >= 1")
            self._mesh = None
            self._sharding = None
        self._async = bool(async_dispatch)
        if features is not None and len(features) == 3:
            # pre-topology callers pinned (pfw, dyn, abl); the fabric
            # fill switch rides the pool's own topology
            features = tuple(features) + (
                getattr(self.topology, "wc_fill", "greedy") == "maxmin",)
        if features is not None and len(features) == 4:
            # pre-sampling callers pinned (pfw, dyn, abl, maxmin):
            # every tenant was clairvoyant, so sampling stays out
            features = tuple(features) + (False,)
        if features is not None and (len(features) != 5
                                     or not all(isinstance(b, (bool,
                                                               np.bool_))
                                                for b in features)):
            raise ValueError(
                "features must be a 5-tuple of bools (per_flow_wc, "
                "with_dynamics, with_ablations, wc_maxmin, "
                "with_sampling)")
        self._pinned = tuple(bool(b) for b in features) \
            if features is not None else None

        self.params, self._ep, self._base_features = \
            self._resolve(params or SchedulerParams(), mechanisms)

        self._C_cap = int(min_coflow_capacity)
        self._F_cap = int(min_flow_capacity)
        self._sessions: List[Optional["object"]] = \
            [None] * self.max_sessions
        self._free = list(range(self.max_sessions))
        self._blank_rows: set = set()
        self._tb = None        # TraceBatch, DEVICE leaves (authoritative)
        # EngineState, DEVICE leaves (authoritative). A sharded pool
        # stores it in DISPATCH LAYOUT — folded (shards, B/shards, ...)
        # with shard i on device i — so the pmap chain consumes and
        # produces it with ZERO per-dispatch reshapes; sync points
        # unfold on demand (`_state_flat`)
        self._state = None
        self._tb_disp = None   # folded view of _tb (dispatch cache)
        self._ep_disp = None   # folded view of _ep_stack
        self._scratch = None   # 1-row numpy TraceBatch packing stage
        # tiny host control mirrors, refreshed from each dispatch's
        # status download: per-row relative tick (the no-op horizon for
        # unworked rows) and per-coflow finished flags (so poll only
        # gathers rows that completed something new)
        self._ticks = None     # (B,) np.int32
        self._fin = None       # (B, C) np.bool_
        # per-row scheduler parameters (stacked at dispatch time)
        self._row_ep = [self._ep] * self.max_sessions
        self._row_feat = [self._base_features] * self.max_sessions
        self._ep_stack = None          # stacked (B,)-leaf EngineParams
        self._features_now = self._pinned or self._base_features
        # pilot leaf compiled into the slab? (with_sampling): the
        # TraceBatch STRUCTURE differs, so a flip is a rebuild-class
        # event — pinned pools never flip (admission validates)
        self._sampling = bool(self._features_now[4])
        # async dispatch chain: the parked device ctl handles of the
        # most recent dispatch, plus the rows awaiting its download
        self._ctl = None               # (tick_dev, fin_dev) | None
        self._pend_rows: dict = {}     # row -> (session, global n_end)
        # sessions whose `_new_done` is set: the O(1) index behind the
        # completion bitmap, so a poll over a clean fleet never walks
        # the roster (B per-session polls per step must not cost B^2)
        self._fresh: set = set()
        # host<->device transfer accounting (benchmarks assert on this)
        self.io = dict(full_uploads=0, row_uploads=0, row_downloads=0,
                       upload_bytes=0, download_bytes=0, ctl_bytes=0,
                       dispatches=0)

    def _resolve(self, params: Optional[SchedulerParams],
                 mechanisms: Optional[dict]) -> tuple:
        """Validate one tenant's (params, mechanisms) against the pool's
        compiled structure; returns (params, EngineParams, features)."""
        from repro.api.scenario import check_mechanisms

        mech = check_mechanisms(mechanisms)
        p = (params or self.params).with_mechanisms(mech)
        if hasattr(self, "params") and \
                p.num_queues != self.params.num_queues:
            raise ValueError(
                f"per-tenant params must share the pool's num_queues "
                f"(K={self.params.num_queues} is a compiled shape); "
                f"got K={p.num_queues}")
        lcof = mech.get("lcof", True)
        per_flow = mech.get("per_flow_threshold", True)
        ep = self._je.EngineParams.from_scheduler(
            p, lcof=lcof, per_flow_threshold=per_flow)
        feat = self._je.features_for(
            p, fidelity=self._fidelity, lcof=lcof,
            per_flow_threshold=per_flow, topology=self.topology)
        if self._pinned is not None:
            names = ("per_flow_wc", "with_dynamics", "with_ablations",
                     "wc_maxmin", "with_sampling")
            for i, name in enumerate(names):
                if feat[i] and not self._pinned[i]:
                    raise ValueError(
                        f"tenant needs compiled feature {name!r} but "
                        f"the pool pinned features={self._pinned} at "
                        f"construction; pin a superset (pinning is "
                        f"what keeps admission recompile-free)")
            if not p.clairvoyant and not self._pinned[4]:
                # a learned-mode tenant carries a traced clairvoyant
                # leaf in its EngineParams row — admitting one into a
                # pool compiled without sampling would change the
                # stacked-parameter structure (a recompile)
                raise ValueError(
                    "non-clairvoyant tenant needs compiled feature "
                    f"'with_sampling' but the pool pinned features="
                    f"{self._pinned} at construction; pin a superset")
        return p, ep, feat

    # ---- admission -------------------------------------------------------

    @property
    def num_sessions(self) -> int:
        return self.max_sessions - len(self._free)

    @property
    def sessions(self) -> list:
        return [s for s in self._sessions if s is not None]

    @_io_accounted
    def session(self, params: Optional[SchedulerParams] = None,
                mechanisms: Optional[dict] = None):
        """Admit a new tenant session — with its OWN scheduler
        parameters/mechanism switches when given (pool defaults
        otherwise); raises `PoolFullError` (a `RuntimeError`) when the
        pool is at its admission cap."""
        from repro.api.session import SaathSession

        if not self._free:
            raise PoolFullError(
                f"SessionPool is full ({self.max_sessions} sessions); "
                f"release one (or raise max_sessions) to admit more")
        p, ep, feat = self._resolve(params, mechanisms)
        # admission commits the tenant's EngineParams row to device —
        # a sanctioned crossing, counted like every other upload
        self.io["upload_bytes"] += _tree_nbytes(ep)
        row = self._free.pop(0)
        sess = SaathSession(p, num_ports=self.num_ports,
                            backend="jax", kernel=self.kernel,
                            chunk=self.chunk, topology=self.topology,
                            _pool=self, _row=row)
        self._sessions[row] = sess
        self._blank_rows.discard(row)
        self._row_ep[row] = ep
        self._row_feat[row] = feat
        self._ep_stack = None
        return sess

    def release(self, sess) -> None:
        """Free a session's row (dropping any unfinished coflows); the
        row is recycled for the next admitted tenant."""
        row = sess._row
        if row is None or self._sessions[row] is not sess:
            raise ValueError("session does not belong to this pool")
        self._sessions[row] = None
        self._blank_rows.add(row)
        bisect.insort(self._free, row)
        sess._row = None
        sess._pool = None
        sess._host_stale = False
        sess._new_done = False
        sess._host_done = False
        self._fresh.discard(sess)
        # any parked ctl entry for the freed row is disarmed by the
        # session-identity check in `_sync_ctl` (the row re-blanks — a
        # scatter, which syncs first — before its next reuse)
        self._row_ep[row] = self._ep
        self._row_feat[row] = self._base_features
        self._ep_stack = None

    def _adopt(self, sess) -> None:
        """Bind an externally-constructed standalone session as row 0
        of this (private, single-row) pool."""
        assert self.max_sessions == 1 and self._free == [0]
        self._free.clear()
        self._sessions[0] = sess

    # ---- fleet stepping --------------------------------------------------

    def advance(self, dt: float) -> float:
        """Move EVERY admitted session's clock by `dt` seconds and
        schedule all their δ-grid ticks with one vmapped dispatch chain
        (each row on its own δ grid); returns the (common) elapsed
        fleet time."""
        if dt < 0:
            raise ValueError("advance(dt) needs dt >= 0")
        targets = []
        for s in self.sessions:
            s._clock += float(dt)
            targets.append(
                (s, int(math.floor(s._clock / s.params.delta + 1e-9))))
        self._advance(targets)
        return float(dt)

    def poll(self) -> List[Tuple[object, object]]:
        """Completed-since-last-poll coflows across the fleet, as
        (session, CompletedCoflow) pairs."""
        self._materialize(completions_only=True)
        out = []
        for s in self.sessions:
            out.extend((s, d) for d in s.poll())
        return out

    def completed_sessions(self) -> list:
        """The fleet's NEW-COMPLETION BITMAP, as the sessions it names:
        rows whose last dispatch finished something not yet drained by
        a poll, plus rows with host-side force-completes
        (`SaathSession.complete`). This is the harvest index the
        `CoflowServer` advance loop walks — a clean tenant costs ZERO
        host work per fleet step (no per-session `poll()` probe). A
        sync point of the async dispatch contract (consumes the
        deferred ctl download)."""
        self._sync_ctl()
        return [s for s in self.sessions
                if s._new_done or s._host_done]

    # ---- slab machinery (the device-facing half of the row-view
    # contract; sessions call these with themselves as the row) --------

    def _target_tick(self, s) -> int:
        """The session's effective tick target: its last synced tick,
        or the horizon of a still-parked async dispatch (whichever is
        later) — the skip test must not re-dispatch a row already
        enqueued to (or past) the asked-for horizon."""
        pend = self._pend_rows.get(s._row)
        if pend is not None and pend[0] is s:
            return max(s._tick, pend[1])
        return s._tick

    @_io_accounted
    def _advance(self, targets) -> None:
        """Advance the given (session, global n_end) targets; sessions
        not listed keep their row at its current tick (exact no-ops in
        the dispatch)."""
        work = {}
        for s, n_end in targets:
            if n_end <= self._target_tick(s):
                continue
            if not s._live:
                # nothing on the row: the grid is advanced host-side
                s._tick = n_end
                continue
            work[s._row] = (s, n_end)
        if not work:
            return
        if self._async and all(n_end - s._epoch <= MAX_REL_TICKS
                               for s, n_end in work.values()):
            self._dispatch_async(work)
            return
        # blocking path: giant horizon jumps need the MAX_REL_TICKS
        # split loop (each leg re-packs and re-bases the epoch), whose
        # decisions read the fresh ctl — flush any parked one first
        self._sync_ctl()
        while work:
            self._ensure()
            ne = self._ticks.astype(np.float32)
            for r, (s, n_end) in work.items():
                ne[r] = min(n_end, s._epoch + MAX_REL_TICKS) - s._epoch
            tb, ep = self._dispatch_slab()
            state, _ = self._je.session_advance(
                self._state, tb, ep, n_end=ne,
                chunk=self.chunk, kernel=self.kernel,
                features=self._features_now, mesh=self._mesh)
            self._state = state          # stays device-resident
            self.io["dispatches"] += 1
            tick_h = np.array(state.tick).reshape(-1)
            fin_h = np.array(state.finished)
            fin_h = fin_h.reshape(-1, fin_h.shape[-1])
            self.io["ctl_bytes"] += tick_h.nbytes + fin_h.nbytes
            nxt = {}
            for r, (s, n_end) in work.items():
                s._tick = s._epoch + int(tick_h[r])
                s._host_stale = True
                if (fin_h[r] != self._fin[r]).any():
                    s._new_done = True   # poll must gather this row
                    self._fresh.add(s)
                if s._tick >= n_end or bool(fin_h[r].all()):
                    continue
                # the MAX_REL_TICKS split: re-pack (re-basing the
                # epoch) and keep going toward the real target
                s._tb_dirty = True
                nxt[r] = (s, n_end)
            self._ticks, self._fin = tick_h, fin_h
            work = nxt

    @_io_accounted
    def _dispatch_async(self, work) -> None:
        """The double-buffered fast path: enqueue the fleet dispatch
        and RETURN — no control download, no host sync. The device
        (tick, finished) handles are parked as the deferred ctl; a
        chain of advances overwrites the parked pair (ticks only grow,
        so only the LAST dispatch's ctl matters) and the download
        happens once, at the next sync point (`_sync_ctl`). Untargeted
        rows ride on the possibly-STALE tick mirror as their horizon:
        a stale mirror can only under-ask, and a lane at or past its
        horizon is an exact no-op, so staleness never perturbs a row."""
        self._ensure()
        ne = self._ticks.astype(np.float32)
        for r, (s, n_end) in work.items():
            ne[r] = n_end - s._epoch     # caller checked the rel cap
        tb, ep = self._dispatch_slab()
        state, _ = self._je.session_advance(
            self._state, tb, ep, n_end=ne,
            chunk=self.chunk, kernel=self.kernel,
            features=self._features_now, mesh=self._mesh, block=False)
        self._state = state              # stays device-resident
        self.io["dispatches"] += 1
        self._ctl = (state.tick, state.finished)
        for r, (s, n_end) in work.items():
            s._host_stale = True
            self._pend_rows[r] = (s, n_end)

    @_io_accounted
    def _sync_ctl(self) -> None:
        """Consume the deferred control download of the async dispatch
        chain: ONE host transfer of the tiny (tick, finished) mirrors
        covers every dispatch enqueued since the last sync. MUST run
        before anything reads or writes the host ctl mirrors — poll's
        completion scan, snapshot gathers, dirty-row scatters and
        rebuilds (which overwrite mirror rows), `host_view` — so a
        stale parked ctl can never clobber fresher mirror writes."""
        if self._ctl is None:
            return
        tick_dev, fin_dev = self._ctl
        self._ctl = None
        tick_h = np.array(tick_dev).reshape(-1)
        fin_h = np.array(fin_dev)
        fin_h = fin_h.reshape(-1, fin_h.shape[-1])
        self.io["ctl_bytes"] += tick_h.nbytes + fin_h.nbytes
        pend, self._pend_rows = self._pend_rows, {}
        short = []
        for r, (s, n_end) in pend.items():
            if s._row != r or self._sessions[r] is not s:
                continue          # released (maybe recycled) row
            s._tick = s._epoch + int(tick_h[r])
            if (fin_h[r] != self._fin[r]).any():
                s._new_done = True   # poll must gather this row
                self._fresh.add(s)
            if s._tick < n_end and not bool(fin_h[r].all()):
                short.append((r, s._tick, n_end))
        self._ticks, self._fin = tick_h, fin_h
        if short:
            raise RuntimeError(
                f"async session_advance stopped short of its horizon "
                f"on rows {short} (step budget exhausted?)")

    @_io_accounted
    def _plan_tick(self, sess) -> np.ndarray:
        """One wave-planning coordinator tick for ONE session row; the
        other rows are masked no-ops. Returns the row's admitted mask."""
        self._ensure()
        mask = np.zeros(self.max_sessions, bool)
        mask[sess._row] = True
        state, admitted = self._je.session_plan_tick(
            self._state_flat(), self._tb, self._ep_stack,
            kernel=self.kernel,
            features=self._features_now, row_mask=mask)
        self._state = self._fold_state(state)
        self.io["dispatches"] += 1
        adm_all = np.asarray(admitted)
        self.io["ctl_bytes"] += adm_all.nbytes
        sess._host_stale = True
        self._materialize([sess])
        return adm_all[sess._row]

    @_io_accounted
    def _ensure(self) -> None:
        """Flush host-side changes to the device slab: released rows are
        re-blanked and dirty rows re-packed, both as ROW SCATTERS
        (`jax_engine.scatter_rows`) — clean rows never re-upload. A
        capacity growth (any row outgrowing the shared flow/coflow
        capacities, grown geometrically) is the one full-slab rebuild
        path; per-row state is carried through the sessions' host
        entries, so nothing is lost."""
        need_c = need_f = 0
        for s in self.sessions:
            if s._tb_dirty:
                need_c = max(need_c, len(s._live))
                need_f = max(need_f, sum(e.size.size
                                         for e in s._live.values()))
        grew = False
        while self._C_cap < need_c:
            self._C_cap *= 2
            grew = True
        while self._F_cap < need_f:
            self._F_cap *= 2
            grew = True
        if self._ep_stack is None and self._pinned is None:
            feats = [self._base_features] + \
                [self._row_feat[s._row] for s in self.sessions]
            self._features_now = tuple(
                any(f[i] for f in feats) for i in range(5))
        # pinned features stay pinned: admission already validated
        # every tenant against them, so membership churn can never
        # change the compiled structure (no recompiles)
        if bool(self._features_now[4]) != self._sampling:
            # the pilot mask is a slab LEAF: compiling sampling in (or
            # out) changes the TraceBatch structure, so the slab and
            # the packing scratch must be rebuilt from scratch
            self._sampling = bool(self._features_now[4])
            self._scratch = None
            grew = True
        if self._tb is None or grew:
            self._rebuild()
        else:
            self._scatter_dirty()
        if self._ep_stack is None:
            rows = self._row_ep
            if self._sampling or any(
                    e.dp.clairvoyant is not None for e in rows):
                # heterogeneous fleets mix clairvoyant rows (empty
                # clairvoyant subtree) with learned rows (f32 scalar);
                # stacking needs one structure, and a sampling slab
                # keeps the leaf CONCRETE even when every current
                # tenant is clairvoyant so a learned tenant joining
                # later never changes the parameter pytree
                rows = [e if e.dp.clairvoyant is not None
                        else e._replace(dp=e.dp._replace(
                            clairvoyant=jnp.float32(1.0)))
                        for e in rows]
            self._ep_stack = self._place(jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *rows))
            self._ep_disp = None

    @_io_accounted
    def _scatter_dirty(self) -> None:
        from repro.traces.batch import row_of, stack_rows

        dirty = [s for s in self.sessions
                 if s._tb_dirty or s._state_dirty]
        if not dirty and not self._blank_rows:
            return
        # re-packing reads the host entries: sync the dirty rows first
        self._materialize(dirty)
        tb_rows, st_rows = [], []
        for r in sorted(self._blank_rows):
            self._blank_scratch()
            tb_rows.append((r, row_of(self._scratch, 0)))
            st_rows.append((r, self._blank_state_row()))
        self._blank_rows.clear()
        for s in dirty:
            if s._tb_dirty:
                self._pack_row_np(self._scratch_tb(), 0, s)
                tb_rows.append((s._row, row_of(self._scratch, 0)))
            st_rows.append((s._row, self._state_row(s)))
            s._state_dirty = False
        for r, row in st_rows:
            self._ticks[r] = int(row.tick)
            self._fin[r] = row.finished
        # ONE SCATTER QUEUE PER SHARD: staged rows funnel through their
        # owning shard's fused scatter (the unsharded pool keeps the
        # single fused call — exactly the pre-shard dispatch shape)
        per = self.max_sessions // self.shards
        buckets: dict = {}
        for r, row in tb_rows:
            buckets.setdefault(r // per, ([], []))[0].append((r, row))
        for r, row in st_rows:
            buckets.setdefault(r // per, ([], []))[1].append((r, row))
        st = self._state_flat()
        for sh in sorted(buckets):
            tb_g, st_g = buckets[sh]
            st_idx = np.array([r for r, _ in st_g], np.int32)
            st_payload = jax.tree_util.tree_map(
                lambda *xs: np.stack(xs), *[p for _, p in st_g])
            self.io["upload_bytes"] += _tree_nbytes(st_payload)
            if tb_g:
                # one fused scatter dispatch covers both trees
                tb_idx = np.array([r for r, _ in tb_g], np.int32)
                tb_payload = stack_rows([p for _, p in tb_g])
                self.io["row_uploads"] += len(tb_g)
                self.io["upload_bytes"] += _tree_nbytes(tb_payload)
                self._tb, st = self._je.scatter_rows(
                    (self._tb, st), (tb_idx, st_idx),
                    (tb_payload, st_payload))
            else:
                st = self._je.scatter_rows(st, st_idx, st_payload)
        if self._sharding is not None:
            # keep the slab pinned to its row sharding between
            # dispatches (a no-op when the scatter preserved it) and
            # drop the folded dispatch cache the scatter invalidated
            self._tb = self._place(self._tb)
            self._tb_disp = None
        self._state = self._fold_state(st)

    def _scratch_tb(self):
        from repro.traces.batch import empty_batch

        if self._scratch is None:
            self._scratch = empty_batch(
                1, flow_capacity=self._F_cap,
                coflow_capacity=self._C_cap,
                port_capacity=self.num_ports,
                leaf_links=self._Lf,
                sampling=self._sampling)
        return self._scratch

    def _blank_scratch(self):
        from repro.traces.batch import blank_row

        blank_row(self._scratch_tb(), 0)

    @_io_accounted
    def _rebuild(self) -> None:
        """Full-slab rebuild (first build, or a capacity growth): pack
        every row host-side and upload the whole slab once — the ONLY
        path that moves full mirrors to the device."""
        from repro.traces.batch import empty_batch

        self._materialize()
        self._scratch = None
        tb = empty_batch(self.max_sessions,
                         flow_capacity=self._F_cap,
                         coflow_capacity=self._C_cap,
                         port_capacity=self.num_ports,
                         leaf_links=self._Lf,
                         sampling=self._sampling)
        rows = [self._blank_state_row()
                for _ in range(self.max_sessions)]
        self._blank_rows.clear()
        for s in self.sessions:
            s._tb_dirty = True
            self._pack_row_np(tb, s._row, s)
            rows[s._row] = self._state_row(s)
            s._state_dirty = False
        state = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *rows)
        self.io["full_uploads"] += 1
        self.io["upload_bytes"] += _tree_nbytes(tb) + _tree_nbytes(state)
        # the upload pins the row sharding: each shard receives exactly
        # its own rows (sharding=None -> default single-device slab);
        # the state uploads directly in dispatch layout (the fold is a
        # free host-side numpy reshape)
        self._tb = jax.device_put(tb, self._sharding)
        self._tb_disp = None
        self._state = jax.device_put(self._fold(state), self._sharding)
        self._ticks = state.tick.copy()
        self._fin = state.finished.copy()

    def _place(self, tree):
        """Re-pin a slab tree to the pool's row sharding (identity for
        an unsharded pool). `PartitionSpec("rows")` partitions dim 0,
        so the same sharding pins flat (B, ...) trees (one row block
        per device) and folded (shards, B/shards, ...) trees (one
        shard index per device) identically."""
        if self._sharding is None:
            return tree
        return jax.device_put(tree, self._sharding)

    def _fold(self, tree):
        """Reshape every leaf (B, ...) -> (shards, B/shards, ...): the
        pmap dispatch layout of a sharded pool (identity when
        unsharded). Shard-local on a row-sharded leaf — no rows move."""
        if self.shards <= 1:
            return tree
        S = self.shards
        return jax.tree_util.tree_map(
            lambda x: x.reshape(S, x.shape[0] // S, *x.shape[1:]),
            tree)

    def _unfold(self, tree):
        """Inverse of `_fold`: dispatch layout back to flat rows."""
        if self.shards <= 1:
            return tree
        return jax.tree_util.tree_map(
            lambda x: x.reshape(x.shape[0] * x.shape[1],
                                *x.shape[2:]), tree)

    def _fold_state(self, flat):
        """Flat engine state -> stored dispatch layout, re-pinned so
        shard i lives on mesh device i."""
        if self.shards <= 1:
            return flat
        return jax.device_put(self._fold(flat), self._sharding)

    def _state_flat(self):
        """The engine state as flat (B, ...) rows — what the
        row-indexed sync machinery (gather/scatter/plan/host_view)
        operates on. A device-side reshape for a sharded pool; the
        identity otherwise."""
        return self._unfold(self._state)

    def _dispatch_slab(self):
        """The (tb, ep) pair in dispatch layout — folded views cached
        until the flat authoritative trees change (they change only on
        scatter/rebuild/membership churn, never per advance, so the
        async dispatch hot loop performs no reshapes at all)."""
        if self.shards <= 1:
            return self._tb, self._ep_stack
        if self._tb_disp is None:
            self._tb_disp = self._place(self._fold(self._tb))
        if self._ep_disp is None:
            self._ep_disp = self._place(self._fold(self._ep_stack))
        return self._tb_disp, self._ep_disp

    def _pack_row_np(self, tb, r: int, s) -> None:
        """Pack one session's live coflows into row `r` of a NUMPY
        TraceBatch (the 1-row scratch for scatters, the full slab for
        rebuilds), re-basing the row's grid epoch when due."""
        from repro.traces.batch import pack_row

        if s._tick - s._epoch >= REBASE_TICKS:
            # re-base the row's grid epoch: all slab times below are
            # stored relative to it, restoring δ resolution in f32
            s._epoch = s._tick
        table = s._rebuild_table()
        pack_row(tb, r, table,
                 arrival_rank=[e.rank for e in s._slots],
                 topology=self.topology if self._Lf else None,
                 pilot_frac=s.params.pilot_frac)
        s._flow_lo = table.flow_lo.copy()
        s._flow_hi = table.flow_hi.copy()
        s._tb_dirty = False

    def _blank_state_row(self):
        from repro.core.jax_coordinator import CoordState
        from repro.fabric.jax_engine import EngineState

        C, F = self._C_cap, self._F_cap
        return EngineState(
            coord=CoordState(np.full((C,), -1, np.int32),
                             np.full((C,), np.inf, np.float32),
                             np.zeros((C,), bool)),
            sent=np.zeros((F,), np.float32),
            done=np.ones((F,), bool),
            fct=np.zeros((F,), np.float32),
            finished=np.ones((C,), bool),
            cct=np.full((C,), np.nan, np.float32),
            t0=np.float32(0.0),
            tick=np.int32(0),
            rate=np.zeros((F,), np.float32),
            pend_sent=np.zeros((F,), np.float32),
            pend_tick=np.float32(0.0),
            pend_next=np.float32(0.0))

    def _state_row(self, s):
        """One row of engine state rebuilt from the session's host
        entries (the carry that survives re-packs), as unbatched numpy
        arrays ready to scatter. Pads (and retired slots) stay at the
        blank-row identity: done/finished, zero rates."""
        row = self._blank_state_row()
        epoch_t = s._epoch * s.params.delta
        for i, e in enumerate(s._slots):
            lo, hi = s._flow_lo[i], s._flow_hi[i]
            row.sent[lo:hi] = e.sent
            row.done[lo:hi] = e.done
            row.fct[lo:hi] = np.where(
                e.done, np.nan_to_num(e.fct) - epoch_t, 0.0)
            row.finished[i] = e.finished
            row.cct[i] = e.cct
            row.coord.queue[i] = e.queue
            row.coord.deadline[i] = e.deadline - epoch_t \
                if np.isfinite(e.deadline) else np.inf
            row.coord.running[i] = e.running
            row.rate[lo:hi] = e.rate
            row.pend_sent[lo:hi] = e.pend_sent
        row = row._replace(tick=np.int32(s._tick - s._epoch))
        if s._pend is not None:
            row = row._replace(
                pend_tick=np.float32(s._pend[0] - s._epoch),
                pend_next=np.float32(s._pend[1] - s._epoch))
        return row

    @_io_accounted
    def _materialize(self, sessions=None,
                     completions_only: bool = False) -> None:
        """Gather STALE rows of the device state back into their
        sessions' host entries — one `gather_rows` dispatch for the
        whole stale set (absolute f64 times reconstructed from the row
        epochs). Clean host mirrors cost nothing; this is the lazy
        half of the device-resident contract. `sessions` restricts the
        sync to the rows a caller actually inspects (a snapshot of one
        tenant never downloads its neighbors); `completions_only`
        (the poll fast path) syncs only rows whose dispatch-status
        mirror shows NEW completions — a row that merely progressed
        stays stale (and free) until a re-pack or snapshot needs it.
        A sync point of the async dispatch contract: the deferred ctl
        is consumed before the stale/new-done flags are read."""
        if self._state is None:
            return
        self._sync_ctl()
        if completions_only and not self._fresh:
            return                    # clean fleet: O(1), no roster walk
        stale = [s for s in (self.sessions if sessions is None
                             else sessions)
                 if s._host_stale
                 and (s._new_done or not completions_only)]
        if not stale:
            return
        idx = np.array([s._row for s in stale], np.int32)
        rows = self._je.gather_rows(self._state_flat(), idx)
        host = jax.tree_util.tree_map(np.asarray, rows)
        self.io["row_downloads"] += len(stale)
        self.io["download_bytes"] += _tree_nbytes(host)
        for j, s in enumerate(stale):
            self._sync_row(s, host, j)
            s._host_stale = False
            s._new_done = False
            self._fresh.discard(s)

    def _sync_row(self, s, st, j: int) -> None:
        """Mirror row `j` of the gathered host state into session `s`'s
        entries (absolute f64 times reconstructed from the row
        epoch)."""
        epoch_t = s._epoch * s.params.delta
        sent = np.asarray(st.sent[j], np.float64)
        done = np.asarray(st.done[j])
        fct = np.asarray(st.fct[j], np.float64)
        finished = np.asarray(st.finished[j])
        cct = np.asarray(st.cct[j], np.float64)
        queue = np.asarray(st.coord.queue[j])
        deadline = np.asarray(st.coord.deadline[j], np.float64)
        running = np.asarray(st.coord.running[j])
        rate = np.asarray(st.rate[j], np.float64)
        pend_sent = np.asarray(st.pend_sent[j], np.float64)
        for i, e in enumerate(s._slots):
            lo, hi = s._flow_lo[i], s._flow_hi[i]
            e.sent = sent[lo:hi].copy()
            e.done = done[lo:hi].copy()
            e.fct = np.where(e.done, fct[lo:hi] + epoch_t, np.nan)
            e.rate = rate[lo:hi].copy()
            e.pend_sent = pend_sent[lo:hi].copy()
            e.finished = bool(finished[i])
            e.cct = float(cct[i])
            e.queue = int(queue[i])
            e.deadline = float(deadline[i] + epoch_t)
            e.running = bool(running[i])
        tick_rel = int(st.tick[j])
        s._tick = s._epoch + tick_rel
        self._ticks[s._row] = tick_rel        # keep the ctl mirror true
        if not s._host_done and \
                any(e.finished for e in s._live.values()):
            s._host_done = True   # gathered completions await a poll;
            # keep the row visible to the harvest bitmap even though
            # `_new_done` is consumed by this gather
        pn = float(st.pend_next[j])
        s._pend = (s._epoch + int(st.pend_tick[j]), s._epoch + int(pn)) \
            if pn > tick_rel else None

    # ---- debug/oracle view ----------------------------------------------

    @_io_accounted
    def host_view(self) -> tuple:
        """Materialize NUMPY copies of the device slab as
        (TraceBatch, EngineState) — the lazily-built debug/oracle view
        (the device arrays stay authoritative; mutating the copies has
        no effect). Returns (None, None) before the first dispatch."""
        if self._tb is None:
            return None, None
        self._sync_ctl()
        tb_h = jax.tree_util.tree_map(np.asarray, self._tb)
        st_h = jax.tree_util.tree_map(np.asarray, self._state_flat())
        # a full-slab download: account it like any other host pull so
        # `pool.io` stays the single source of truth for transfers
        self.io["download_bytes"] += _tree_nbytes(tb_h) + _tree_nbytes(st_h)
        return tb_h, st_h


__all__ = ["SessionPool", "PoolFullError", "REBASE_TICKS"]
