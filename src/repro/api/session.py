"""Online scheduling sessions: `submit` / `advance` / `poll`.

Offline `repro.api.run` replays traces whose arrivals are known up
front. Real coflow schedulers are *incremental* — Saath's Fig. 7 tick,
Philae's online ordering, DCoflow's deadline admission all consume
arrivals as they happen — so `SaathSession` exposes the same Fig. 7
coordinator as an open-loop service:

* ``submit(coflows)`` registers new coflows at the current session
  clock (each `Coflow.arrival` may also name a future instant);
* ``advance(dt)`` moves the session clock and schedules every δ-grid
  tick up to it;
* ``poll()`` returns (and retires) the coflows that completed since the
  last poll;
* ``plan_tick()`` runs ONE coordinator tick in *wave-planning* mode
  (admitted coflows complete instantly) — the mode
  `runtime.coflow_bridge.plan_waves` is a thin client of.

Two backends share the session contract (DESIGN.md §7/§8):

* ``backend="jax"`` — the serving path: the session is a VIEW onto one
  row of a `repro.api.SessionPool` slab (a standalone session owns a
  private single-row pool; `SessionPool.session()` hands out rows of a
  shared multi-tenant slab). The session keeps the host truth — live
  `_Entry`s, clock, global δ-grid tick, row epoch, and the pending
  event-horizon mirror — and the pool owns the packed `TraceBatch` +
  `EngineState` and every jitted dispatch;
* ``backend="numpy"`` — the event-driven host reference (the parity
  oracle), sharing `fabric.engine.integrate_interval` with the offline
  `Simulator` so the two loops cannot drift.

Incremental replay is EXACT on both backends: the δ grid is pinned at
the session epoch, ticks at or past the advance horizon are pure
no-ops, the schedule at a tick is only ever evaluated once every
arrival at or before it has been submitted, and a schedule interval a
horizon cap truncates is RESUMED (stored rates, anchored integration)
rather than re-evaluated — so feeding a trace's coflows in at their
arrival times reproduces the offline `run()` trajectory event for
event. On the jax slab that makes the incremental CCTs bitwise-equal
to the offline jitted scan (tests/test_session.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.coflow import Coflow, Flow, Trace
from repro.core.params import SchedulerParams
from repro.fabric.state import FlowTable


@dataclasses.dataclass
class CompletedCoflow:
    """One finished coflow, as returned (once) by `poll`."""
    handle: int
    arrival: float
    cct: float              # seconds, arrival-relative
    fct: np.ndarray         # absolute per-flow completion times
    size: np.ndarray = None  # per-flow bytes (completions moved them all)


@dataclasses.dataclass
class _Entry:
    """Host mirror of one live coflow's dynamic state (the carry that
    survives slab re-packs)."""
    handle: int
    arrival: float
    rank: int               # session-global FIFO rank (submission order)
    src: np.ndarray
    dst: np.ndarray
    size: np.ndarray
    sent: np.ndarray
    done: np.ndarray
    fct: np.ndarray         # absolute, NaN until done
    rate: np.ndarray = None      # last schedule's per-flow rates
    pend_sent: np.ndarray = None  # sent at the pending-schedule anchor
    queue: int = -1
    deadline: float = math.inf
    running: bool = False
    finished: bool = False
    cct: float = math.nan


class SaathSession:
    """An online Saath coordinator over a fixed fabric.

    `params` are the paper's scheduler knobs; `num_ports` fixes the
    fabric (uniform `params.port_bw` per port). `mechanisms` takes the
    shared ablation switch names (`repro.api.MECHANISM_KEYS`).

    With ``backend="jax"`` the session is a row view onto a
    `SessionPool` slab (private single-row pool unless constructed via
    `SessionPool.session()`, in which case `params`/`mechanisms` come
    from the pool).
    """

    def __init__(self, params: Optional[SchedulerParams] = None, *,
                 num_ports: int, backend: str = "jax",
                 mechanisms: Optional[dict] = None,
                 fidelity: str = "flow", kernel: Optional[str] = None,
                 chunk: int = 32, min_coflow_capacity: int = 16,
                 min_flow_capacity: int = 64,
                 topology=None,
                 _pool=None, _row: Optional[int] = None):
        if backend not in ("jax", "numpy"):
            raise ValueError(
                f"unknown backend {backend!r}; available: jax, numpy")
        from repro.api.scenario import check_mechanisms
        from repro.fabric.topology import normalize_topology

        mech = check_mechanisms(mechanisms)
        self.num_ports = int(num_ports)
        self.backend = backend
        # fabric model: threaded to the private pool's slab (jax) or the
        # policy's allocation walk (numpy); a pooled session inherits
        # the pool's pinned topology
        self.topology = normalize_topology(topology) if _pool is None \
            else _pool.topology
        self.kernel = kernel
        self.chunk = int(chunk)

        self._clock = 0.0       # continuous session time
        self._tick = 0          # global δ-grid ticks already scheduled
        self._epoch = 0         # δ-grid tick the slab row is based at
        self._seq = 0           # next handle / global FIFO rank
        self._live: Dict[int, _Entry] = {}
        self._slots: List[_Entry] = []      # slab slot order
        self._flow_lo = self._flow_hi = None
        self._tb_dirty = True   # membership changed -> re-pack
        self._state_dirty = True  # dynamic state changed host-side
        self._host_stale = False  # device row ahead of the host entries
        self._new_done = False  # device row holds unseen completions
        self._host_done = False  # host-side completions awaiting a poll
        # pending capped schedule interval, as GLOBAL tick indices
        # (anchor tick, horizon tick); per-flow anchor rates/sent live
        # in the entries. numpy keeps continuous times instead.
        self._pend = None

        if backend == "jax":
            if _pool is not None:
                self._pool = _pool
                self._row = _row
                # pool.session() resolves per-tenant params/mechanisms
                # and passes the merged result; plain adoption falls
                # back to the pool defaults
                self.params = params if params is not None \
                    else _pool.params
            else:
                from repro.api.pool import SessionPool

                pool = SessionPool(
                    params, num_ports=num_ports, max_sessions=1,
                    mechanisms=mech, fidelity=fidelity, kernel=kernel,
                    chunk=chunk,
                    min_coflow_capacity=min_coflow_capacity,
                    min_flow_capacity=min_flow_capacity,
                    topology=self.topology)
                pool._adopt(self)
                self._pool = pool
                self._row = 0
                self.params = pool.params
        else:
            from repro.core.policies import make_policy
            from repro.fabric.engine import Simulator

            self.params = (params or SchedulerParams()) \
                .with_mechanisms(mech)
            pol_kw = {k: mech[k] for k in ("lcof", "per_flow_threshold",
                                           "work_conservation")
                      if k in mech}
            self._policy = make_policy("saath", self.params, **pol_kw)
            # the incremental loop calls policy.schedule directly, so
            # the topology is installed on the policy here (Simulator
            # only installs it inside run())
            self._policy.topology = self.topology
            self._sim = Simulator(self.params, topology=self.topology)
            self._table: Optional[FlowTable] = None
            # a schedule whose event horizon extends past the last
            # advance target: (evaluation instant, next-event instant).
            # Resuming continues THIS interval instead of re-evaluating,
            # so the incremental replay is event-for-event the offline
            # Simulator loop (exact, not just 1%-close).
            self._pending: "tuple[float, float] | None" = None

    # ---- public surface --------------------------------------------------

    @property
    def now(self) -> float:
        return self._clock

    @property
    def num_live(self) -> int:
        return len(self._live)

    @property
    def _C_cap(self) -> int:
        return self._pool._C_cap

    @property
    def _F_cap(self) -> int:
        return self._pool._F_cap

    def close(self) -> None:
        """Release this session's pool row (jax backend; unfinished
        coflows are dropped). The session is unusable afterwards."""
        if self.backend == "jax" and self._pool is not None:
            self._pool.release(self)
        self._live.clear()

    def _check_open(self) -> None:
        if self.backend == "jax" and self._pool is None:
            raise RuntimeError("session was closed (its pool row was "
                               "released)")

    def submit(self, coflows: Sequence[Coflow]) -> List[int]:
        """Register coflows; returns their session handles. A coflow's
        `arrival` below the current clock is clamped to it (the
        coordinator cannot schedule the past)."""
        self._check_open()
        handles = []
        for cf in coflows:
            src = np.array([f.src for f in cf.flows], np.int32)
            dst = np.array([f.dst for f in cf.flows], np.int32)
            size = np.array([f.size for f in cf.flows], np.float64)
            if src.size == 0:
                raise ValueError("coflow needs at least one flow")
            ports = np.concatenate([src, dst])
            if ((ports < 0) | (ports >= self.num_ports)).any():
                raise ValueError(
                    f"flow port out of range for the {self.num_ports}-"
                    f"port fabric")
            w = src.size
            e = _Entry(
                handle=self._seq, arrival=max(float(cf.arrival),
                                              self._clock),
                rank=self._seq, src=src, dst=dst, size=size,
                sent=np.zeros(w), done=np.zeros(w, bool),
                fct=np.full(w, np.nan), rate=np.zeros(w),
                pend_sent=np.zeros(w))
            self._live[e.handle] = e
            handles.append(e.handle)
            self._seq += 1
        self._tb_dirty = True
        return handles

    def advance(self, dt: float) -> float:
        """Move the session clock by `dt` seconds, scheduling every
        δ-grid tick up to it; returns the new clock."""
        if dt < 0:
            raise ValueError("advance(dt) needs dt >= 0")
        self._check_open()
        self._clock += float(dt)
        n_end = int(math.floor(self._clock / self.params.delta + 1e-9))
        if self.backend == "jax":
            self._pool._advance([(self, n_end)])
        else:
            self._advance_numpy(n_end)
        return self._clock

    def poll(self) -> List[CompletedCoflow]:
        """Completed-since-last-poll coflows. Retired slots are
        reclaimed LAZILY: a finished coflow left packed is a masked
        no-op to the engine (exactly like an offline replay, whose pack
        keeps completed coflows resident), so the slab is only
        re-packed when the next `submit` actually changes membership —
        polling never dirties a row. On the jax backend this is also a
        lazy materialization point: the device row is only gathered
        back to the host when someone looks (and only rows with NEW
        completions are gathered at all)."""
        if self.backend == "jax" and self._pool is not None:
            self._pool._materialize(completions_only=True)
        out = []
        for h in list(self._live):
            e = self._live[h]
            if e.finished:
                out.append(CompletedCoflow(handle=h, arrival=e.arrival,
                                           cct=float(e.cct),
                                           fct=e.fct.copy(),
                                           size=e.size.copy()))
                del self._live[h]
        # the pool's completion bitmap: nothing finished is left
        # undrained after a poll (completions_only materialization can
        # leave finished entries only when `out` captured them)
        self._host_done = any(e.finished for e in self._live.values())
        return out

    def drain(self, max_seconds: float = 3600.0,
              step: float = 1.0) -> List[CompletedCoflow]:
        """Advance until every submitted coflow has completed (or
        `max_seconds` of virtual time pass); returns all completions."""
        out = self.poll()
        spent = 0.0
        while self._live and spent < max_seconds:
            self.advance(step)
            spent += step
            out += self.poll()
        if self._live:
            raise RuntimeError(
                f"{len(self._live)} coflows unfinished after "
                f"{max_seconds}s of virtual time")
        return out

    def plan_tick(self) -> List[int]:
        """One coordinator tick in wave-planning mode: the admitted
        coflows complete instantly (an SPMD collective is indivisible —
        issuing it IS completing it for planning purposes) and their
        handles are returned; the clock moves one δ."""
        self._check_open()
        before = self._tick
        admitted = self._planned_admissions()
        # jax backend: session_plan_tick already advanced the device
        # tick (synced back); numpy (and the no-live early-out) has not
        self._tick = max(self._tick, before + 1)
        self._clock = max(self._clock, self._tick * self.params.delta)
        self.complete(admitted)
        return admitted

    def snapshot(self) -> Dict[int, dict]:
        """Per-live-coflow scheduler view, keyed by handle: the queue
        the coordinator placed it in, its starvation deadline, whether
        it is admitted (`running`), finished, and its bytes sent. On
        the jax backend this materializes the device row lazily (and
        only THIS session's row)."""
        if self.backend == "jax" and self._pool is not None:
            self._check_open()
            self._pool._materialize([self])
        return {h: {"queue": e.queue, "deadline": e.deadline,
                    "running": e.running, "finished": e.finished,
                    "sent": float(np.sum(e.sent))}
                for h, e in self._live.items()}

    def complete(self, handles: Sequence[int]) -> None:
        """Force-complete coflows at the current clock (wave planning /
        external cancellation)."""
        if self.backend == "jax" and self._pool is not None:
            # the untouched entries must be fresh before the row's
            # state is rebuilt from them at the next re-pack
            self._pool._materialize([self])
        now = self._clock
        for h in handles:
            e = self._live[h]
            if e.finished:
                continue
            e.sent[:] = e.size
            e.done[:] = True
            e.fct[:] = now
            e.finished = True
            e.cct = now - e.arrival
        if handles:
            self._host_done = True   # completions_only gathers skip
            # host-forced completes; flag the row for the harvest scan
        self._state_dirty = True
        # the stored schedule (and any capped interval of it) is stale
        self._pend = None
        if self.backend == "numpy":
            self._pending = None
        if self.backend == "numpy" and self._table is not None \
                and not self._tb_dirty:
            # mutate the live table in place (no re-pack needed)
            for h in handles:
                i = self._slots.index(self._live[h])
                lo, hi = (self._table.flow_lo[i], self._table.flow_hi[i])
                self._table.sent[lo:hi] = self._table.size[lo:hi]
                self._table.done[lo:hi] = True
                self._table.fct[lo:hi] = now
                self._table.finished[i] = True
                self._table.active[i] = False
                self._table.cct[i] = now - self._table.arrival[i]
            self._state_dirty = False

    def _rebuild_table(self) -> FlowTable:
        """Re-materialize the live coflows (slot order = submission
        order) as a fresh FlowTable with arrivals relative to the row
        epoch — the shared first step of both backends' re-pack paths
        (the numpy backend's epoch is always 0)."""
        self._slots = list(self._live.values())
        epoch_t = self._epoch * self.params.delta
        coflows = [Coflow(cid=i, arrival=e.arrival - epoch_t,
                          flows=[Flow(0, int(s), int(d), float(z))
                                 for s, d, z in zip(e.src, e.dst,
                                                    e.size)])
                   for i, e in enumerate(self._slots)]
        return FlowTable.from_trace(
            Trace(num_ports=self.num_ports, coflows=coflows),
            self.params.port_bw)

    # ---- numpy backend: incremental event-driven reference ---------------

    def _ensure_table(self) -> None:
        if not self._tb_dirty:
            return
        table = self._rebuild_table()
        # restore carried-over dynamic + coordinator state
        self._policy.reset(table)
        for i, e in enumerate(self._slots):
            lo, hi = table.flow_lo[i], table.flow_hi[i]
            table.sent[lo:hi] = e.sent
            table.done[lo:hi] = e.done
            table.fct[lo:hi] = e.fct
            table.rate[lo:hi] = e.rate
            table.finished[i] = e.finished
            table.cct[i] = e.cct
            self._policy._queue[i] = e.queue
            self._policy._deadline[i] = e.deadline
            self._policy._running[i] = e.running
        self._table = table
        self._tb_dirty = False
        self._state_dirty = False

    def _sync_from_table(self) -> None:
        t = self._table
        for i, e in enumerate(self._slots):
            lo, hi = t.flow_lo[i], t.flow_hi[i]
            e.sent = t.sent[lo:hi].copy()
            e.done = t.done[lo:hi].copy()
            e.fct = t.fct[lo:hi].copy()
            e.rate = t.rate[lo:hi].copy()
            e.finished = bool(t.finished[i])
            e.cct = float(t.cct[i])
            e.queue = int(self._policy._queue[i])
            e.deadline = float(self._policy._deadline[i])
            e.running = bool(self._policy._running[i])

    def _advance_numpy(self, n_end: int) -> None:
        if n_end <= self._tick:
            return
        if not self._live:
            self._tick = n_end
            return
        self._ensure_table()
        from repro.fabric.engine import _quantize_up, integrate_interval

        table, pol, p = self._table, self._policy, self.params
        now = self._tick * p.delta
        target = n_end * p.delta
        eps = 1e-12
        guard = 0
        while now < target - eps:
            guard += 1
            if guard > self._sim.max_steps:
                raise RuntimeError("session exceeded max_steps")

            # resume a schedule interval a previous advance capped: keep
            # integrating the STORED rates to its event horizon (or to a
            # since-submitted arrival's tick — a discrete event the
            # offline loop would have stopped at) before re-evaluating.
            # This keeps the evaluation instants — and with them the
            # §4.3 drift re-queues and max_jump cadence — exactly the
            # offline Simulator's.
            if self._pending is not None:
                t_eval, t_next = self._pending
                if t_next <= now + eps:
                    self._pending = None
                    continue
                stop_ev = t_next
                late = table.arrival[table.arrival > t_eval + eps]
                if late.size:
                    stop_ev = min(stop_ev, max(
                        _quantize_up(float(late.min()), p.delta),
                        t_eval + p.delta))
                if stop_ev <= now + eps:
                    self._pending = None
                    continue
                stop = min(stop_ev, target)
                self._sim._activate(table, t_eval)
                integrate_interval(table, table.rate.copy(),
                                   table.flow_live(), now, stop)
                now = stop
                if stop >= stop_ev - eps:
                    self._pending = None
                continue

            self._sim._activate(table, now)
            if table.finished.all():
                now = target
                break
            live = table.flow_live()
            future = table.arrival[table.arrival > now + eps]
            next_arrival = float(future.min()) if future.size \
                else math.inf
            if not live.any():
                now = target if math.isinf(next_arrival) else \
                    min(_quantize_up(next_arrival, p.delta), target)
                continue
            rates = pol.schedule(table, now)
            t_ev = self._sim._next_event(table, pol, now, rates,
                                         next_arrival)
            if math.isinf(t_ev):
                raise RuntimeError(
                    f"session deadlock at t={now:.3f}: no rates, no "
                    f"events ({int(live.sum())} live flows)")
            t_next = max(_quantize_up(t_ev, p.delta), now + p.delta)
            stop = min(t_next, target)
            integrate_interval(table, rates, live, now, stop)
            if stop < t_next - eps:
                self._pending = (now, t_next)
            now = stop
        self._tick = n_end
        self._sync_from_table()

    # ---- wave planning ---------------------------------------------------

    def _planned_admissions(self) -> List[int]:
        live = [e for e in self._live.values() if not e.finished]
        if not live:
            return []
        now = self._tick * self.params.delta
        if self.backend == "jax":
            adm = self._pool._plan_tick(self)
            return [e.handle for i, e in enumerate(self._slots)
                    if adm[i] and not e.finished]
        self._ensure_table()
        self._pending = None          # planning re-evaluates every tick
        table, pol = self._table, self._policy
        self._sim._activate(table, now)
        rates = pol.schedule(table, now)
        out = [e.handle for i, e in enumerate(self._slots)
               if not e.finished
               and rates[table.flow_lo[i]:table.flow_hi[i]].max() > 0]
        self._sync_from_table()
        return out


__all__ = ["SaathSession", "CompletedCoflow"]
