"""Pallas TPU kernel for coflow contention k_c (the LCoF hot spot).

k_c = #other coflows sharing >=1 sender or receiver port with coflow c.

Shaped as an MXU problem: S = A_s A_s^T + A_r A_r^T over the (C, P)
{0,1} incidence matrices, then k_c = row-count of S > 0 (minus self).
The grid tiles (C x C) into (bc x bc) blocks; each block needs two
(bc, P) incidence strips in VMEM and accumulates a (bc,) partial count
into the output, so VMEM = 4 * bc * P * 4B + bc * 4B. With bc = 256 and
P = 512 padded that is ~2 MB — far under the ~16 MB v5e VMEM budget,
and both MXU operands are 128-aligned after ops.py padding.

Table 2 of the paper shows LCoF ordering is half the coordinator's
compute; this kernel is why the in-framework coordinator stays <<1 ms at
512 ports x 4096 coflows (benchmarks/table2_coordinator_latency.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _contention_kernel(a_s_row, a_r_row, a_s_col, a_r_col, k_ref, *, bc):
    i = pl.program_id(0)
    j = pl.program_id(1)
    s = jnp.dot(a_s_row[...], a_s_col[...].T,
                preferred_element_type=jnp.float32)
    s += jnp.dot(a_r_row[...], a_r_col[...].T,
                 preferred_element_type=jnp.float32)
    blocks = (s > 0.5).astype(jnp.float32)   # (bc, bc) "c blocks c'"
    # on the diagonal block, remove self-contention
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (bc, bc), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (bc, bc), 1)
    on_diag = (i == j) & (row_ids == col_ids)
    blocks = jnp.where(on_diag, 0.0, blocks)
    partial = blocks.sum(axis=1)             # (bc,)

    @pl.when(j == 0)
    def _init():
        k_ref[...] = partial

    @pl.when(j > 0)
    def _acc():
        k_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("bc", "interpret"))
def contention_pallas(a_send: jax.Array, a_recv: jax.Array,
                      active: jax.Array, *, bc: int = 256,
                      interpret: bool = False) -> jax.Array:
    """a_send/a_recv: (C, P) float32 {0,1}; active: (C,) bool.

    Returns (C,) int32 contention counts (0 for inactive coflows).
    C and P are padded to multiples of (bc, 128) here; callers pass any
    shape.
    """
    C, P = a_send.shape
    Cp = -(-C // bc) * bc
    Pp = -(-P // 128) * 128
    act = active.astype(a_send.dtype)[:, None]
    a_s = jnp.zeros((Cp, Pp), a_send.dtype).at[:C, :P].set(a_send * act)
    a_r = jnp.zeros((Cp, Pp), a_recv.dtype).at[:C, :P].set(a_recv * act)

    grid = (Cp // bc, Cp // bc)
    strip = pl.BlockSpec((bc, Pp), lambda i, j: (i, 0))
    stripT = pl.BlockSpec((bc, Pp), lambda i, j: (j, 0))
    out = pl.BlockSpec((bc,), lambda i, j: (i,))
    k = pl.pallas_call(
        functools.partial(_contention_kernel, bc=bc),
        grid=grid,
        in_specs=[strip, strip, stripT, stripT],
        out_specs=out,
        out_shape=jax.ShapeDtypeStruct((Cp,), jnp.float32),
        interpret=interpret,
    )(a_s, a_r, a_s, a_r)
    return jnp.where(active, k[:C].astype(jnp.int32), 0)
