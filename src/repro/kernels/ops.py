"""Public jit'd entry points for the Pallas kernels.

Each op dispatches: TPU -> compiled Pallas kernel; everywhere else ->
the pure-jnp oracle in ref.py (identical semantics, lowerable on any
backend — this is what the CPU dry-run and the smoke tests compile).
Set ``force='pallas'`` / ``force='ref'`` / ``force='interpret'`` to pin
a path (tests use 'interpret' to execute the kernel body on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.contention import contention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.maxmin import maxmin_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

# maxmin kernel VMEM budget (see maxmin.py)
_MAXMIN_MAX_P = 256
_MAXMIN_MAX_F = 4096


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:  # pragma: no cover
        return False


def _path(force: str | None) -> str:
    if force is not None:
        return force
    return "pallas" if _on_tpu() else "ref"


def contention(a_send, a_recv, active, *, force: str | None = None):
    p = _path(force)
    if p == "ref":
        return ref.contention_ref(a_send, a_recv, active)
    return contention_pallas(a_send, a_recv, active,
                             interpret=(p == "interpret"))


def maxmin_rates(src_onehot, dst_onehot, live, bw_send, bw_recv, *,
                 force: str | None = None):
    p = _path(force)
    P, F = src_onehot.shape
    if p == "ref" or P > _MAXMIN_MAX_P or F > _MAXMIN_MAX_F:
        return ref.maxmin_ref(src_onehot, dst_onehot, live, bw_send, bw_recv)
    return maxmin_pallas(src_onehot, dst_onehot, live, bw_send, bw_recv,
                         interpret=(p == "interpret"))


def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                    force: str | None = None, **kw):
    p = _path(force)
    if p == "ref":
        assert q_offset == 0, "ref path is offset-free (full prefill)"
        return ref.attention_ref(q, k, v, causal=causal)
    return flash_attention_pallas(q, k, v, causal=causal, q_offset=q_offset,
                                  interpret=(p == "interpret"), **kw)


def ssd_scan(x, dt, a, b, c, *, init_state=None, force: str | None = None,
             **kw):
    p = _path(force)
    if p == "ref":
        return ref.ssd_ref(x, dt, a, b, c, init_state=init_state)
    return ssd_scan_pallas(x, dt, a, b, c, init_state=init_state,
                           interpret=(p == "interpret"), **kw)


__all__ = ["contention", "maxmin_rates", "flash_attention", "ssd_scan"]
