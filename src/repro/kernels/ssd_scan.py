"""Pallas TPU kernel for the Mamba-2 SSD chunked scan (arXiv:2405.21060).

State-space duality: within a chunk of length Lc the recurrence

    S_t = exp(dt_t a) S_{t-1} + dt_t x_t b_t^T ,   y_t = S_t c_t

is computed as a (masked, decay-weighted) attention-like matmul, and the
state is carried *across* chunks in VMEM scratch through the sequential
chunk grid dimension — the TPU-native replacement for the paper's
(GPU) warp-level scan:

    y_intra = [ (c_c b_c^T) ⊙ decay(t,u) ⊙ dt_u, lower-tri ] @ x_c
    y_inter = exp(cum_t) * (c_c @ S_prev^T)
    S_new   = exp(cum_L) S_prev + (x ⊙ dt exp(cum_L - cum))^T @ b_c

Grid = (B*H, L/Lc), chunk innermost. Per-step VMEM: x, b, c chunks +
(Lc, Lc) decay matrix + (Dh, N) state ≈ 0.6 MB at Lc=128, Dh=64, N=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, dta_ref, b_ref, c_ref, s0_ref,
                y_ref, sfin_ref, state_ref, *, lc):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)        # (Lc, Dh)
    dt = dt_ref[0].astype(jnp.float32)      # (Lc, 1)
    dta = dta_ref[0].astype(jnp.float32)    # (Lc, 1)  = dt * a_h
    b = b_ref[0].astype(jnp.float32)        # (Lc, N)
    c = c_ref[0].astype(jnp.float32)        # (Lc, N)

    cum = jnp.cumsum(dta, axis=0)           # (Lc, 1) inclusive
    # decay(t, u) = exp(cum_t - cum_u) for u <= t
    diff = cum - cum.reshape(1, lc)         # (Lc, Lc) cum_t - cum_u
    rows = jax.lax.broadcasted_iota(jnp.int32, (lc, lc), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (lc, lc), 1)
    tri = rows >= cols
    decay = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)

    g = jnp.dot(c, b.T, preferred_element_type=jnp.float32)  # (Lc, Lc)
    m = g * decay * dt.reshape(1, lc)
    y = jnp.dot(m, x, preferred_element_type=jnp.float32)    # intra

    s_prev = state_ref[...]                 # (Dh, N)
    y += jnp.exp(cum) * jnp.dot(c, s_prev.T,
                                preferred_element_type=jnp.float32)

    cl = cum[lc - 1]                        # (1,) total chunk decay
    w = jnp.exp(cl - cum) * dt              # (Lc, 1)
    s_new = jnp.exp(cl) * s_prev + jnp.dot(
        (x * w).T, b, preferred_element_type=jnp.float32)
    state_ref[...] = s_new

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(j == nj - 1)
    def _fin():
        sfin_ref[0] = s_new.astype(sfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lc", "interpret"))
def ssd_scan_pallas(x: jax.Array, dt: jax.Array, a: jax.Array,
                    b: jax.Array, c: jax.Array, *,
                    init_state: jax.Array | None = None,
                    lc: int = 128, interpret: bool = False):
    """x: (B, L, H, Dh); dt: (B, L, H); a: (H,); b, c: (B, L, G, N).

    L must be a multiple of lc. Returns (y (B, L, H, Dh),
    final_state (B, H, Dh, N)); matches ref.ssd_ref.
    """
    B, L, H, Dh = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    assert L % lc == 0, "pad L to a multiple of the chunk length"

    # layout: fold heads into the leading grid axis
    xx = jnp.moveaxis(x, 2, 1).reshape(B * H, L, Dh)
    dtt = jnp.moveaxis(dt, 2, 1).reshape(B * H, L, 1)
    dta = dtt * jnp.tile(a, B)[:, None, None]   # per-head a, bh = b*H + h
    bb = jnp.moveaxis(b, 2, 1).reshape(B * G, L, N)
    cc = jnp.moveaxis(c, 2, 1).reshape(B * G, L, N)
    s0 = (init_state if init_state is not None
          else jnp.zeros((B, H, Dh, N), jnp.float32)).reshape(B * H, Dh, N)

    grid = (B * H, L // lc)
    from jax.experimental.pallas import tpu as pltpu

    y, sfin = pl.pallas_call(
        functools.partial(_ssd_kernel, lc=lc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, lc, Dh), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, lc, 1), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, lc, 1), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, lc, N),
                         lambda bh, j, rep=rep: (bh // rep, j, 0)),
            pl.BlockSpec((1, lc, N),
                         lambda bh, j, rep=rep: (bh // rep, j, 0)),
            pl.BlockSpec((1, Dh, N), lambda bh, j: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, lc, Dh), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, Dh, N), lambda bh, j: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, L, Dh), x.dtype),
            jax.ShapeDtypeStruct((B * H, Dh, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Dh, N), jnp.float32)],
        interpret=interpret,
    )(xx, dtt, dta, bb, cc, s0)
    y = jnp.moveaxis(y.reshape(B, H, L, Dh), 1, 2)
    return y, sfin.reshape(B, H, Dh, N)
