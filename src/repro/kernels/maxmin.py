"""Pallas TPU kernel: bipartite max-min water-filling rate assignment.

Table 2 of the paper attributes most coordinator compute to assigning
work-conservation rates; this kernel runs the whole progressive-filling
solve in VMEM — one grid step, `2P` fixed rounds of dense mat-vec
products against the (P, F) one-hot incidence matrices (MXU work), no
HBM traffic between rounds.

Sized for the coordinator's working set (P <= 256 ports padded, F <=
4096 flows padded: 2 * 256 * 4096 * 4 B = 8 MB of VMEM). ops.py falls
back to ref.maxmin_ref beyond that.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30


def _maxmin_kernel(src_ref, dst_ref, live_ref, bws_ref, bwr_ref, rates_ref,
                   *, rounds):
    src = src_ref[...]          # (P, F) one-hot f32
    dst = dst_ref[...]
    live = live_ref[...]        # (1, F) f32 {0,1}

    def body(_, state):
        rates, frozen, avail_s, avail_r = state
        act = live * (1.0 - frozen)                       # (1, F)
        cnt_s = jnp.dot(src, act.T,
                        preferred_element_type=jnp.float32)  # (P, 1)
        cnt_r = jnp.dot(dst, act.T, preferred_element_type=jnp.float32)
        lvl_s = jnp.where(cnt_s > 0, avail_s / jnp.maximum(cnt_s, 1.0), BIG)
        lvl_r = jnp.where(cnt_r > 0, avail_r / jnp.maximum(cnt_r, 1.0), BIG)
        lvl = jnp.minimum(lvl_s.min(), lvl_r.min())
        sat_s = ((lvl_s <= lvl + 1e-12) & (cnt_s > 0)).astype(jnp.float32)
        sat_r = ((lvl_r <= lvl + 1e-12) & (cnt_r > 0)).astype(jnp.float32)
        inc = (jnp.dot(sat_s.T, src, preferred_element_type=jnp.float32)
               + jnp.dot(sat_r.T, dst,
                         preferred_element_type=jnp.float32))   # (1, F)
        hit = act * (inc > 0.5).astype(jnp.float32)
        rates = rates + lvl * hit
        avail_s = jnp.maximum(
            avail_s - lvl * jnp.dot(src, hit.T,
                                    preferred_element_type=jnp.float32), 0.0)
        avail_r = jnp.maximum(
            avail_r - lvl * jnp.dot(dst, hit.T,
                                    preferred_element_type=jnp.float32), 0.0)
        return rates, frozen + hit, avail_s, avail_r

    init = (jnp.zeros_like(live), 1.0 - live, bws_ref[...], bwr_ref[...])
    rates, _, _, _ = jax.lax.fori_loop(0, rounds, body, init)
    rates_ref[...] = rates


@functools.partial(jax.jit, static_argnames=("interpret",))
def maxmin_pallas(src_onehot: jax.Array, dst_onehot: jax.Array,
                  live: jax.Array, bw_send: jax.Array, bw_recv: jax.Array,
                  *, interpret: bool = False) -> jax.Array:
    """src/dst_onehot: (P, F) f32 {0,1}; live: (F,) bool; bw: (P,).

    Returns (F,) f32 max-min fair rates. Matches ref.maxmin_ref.
    """
    P, F = src_onehot.shape
    Pp = -(-P // 8) * 8
    Fp = -(-F // 128) * 128
    src = jnp.zeros((Pp, Fp), jnp.float32).at[:P, :F].set(src_onehot)
    dst = jnp.zeros((Pp, Fp), jnp.float32).at[:P, :F].set(dst_onehot)
    lv = jnp.zeros((1, Fp), jnp.float32).at[0, :F].set(
        live.astype(jnp.float32))
    bws = jnp.zeros((Pp, 1), jnp.float32).at[:P, 0].set(bw_send)
    bwr = jnp.zeros((Pp, 1), jnp.float32).at[:P, 0].set(bw_recv)

    rates = pl.pallas_call(
        functools.partial(_maxmin_kernel, rounds=2 * P + 2),
        grid=(1,),
        in_specs=[pl.BlockSpec((Pp, Fp), lambda _: (0, 0)),
                  pl.BlockSpec((Pp, Fp), lambda _: (0, 0)),
                  pl.BlockSpec((1, Fp), lambda _: (0, 0)),
                  pl.BlockSpec((Pp, 1), lambda _: (0, 0)),
                  pl.BlockSpec((Pp, 1), lambda _: (0, 0))],
        out_specs=pl.BlockSpec((1, Fp), lambda _: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, Fp), jnp.float32),
        interpret=interpret,
    )(src, dst, lv, bws, bwr)
    return rates[0, :F]
