"""Pure-jnp oracles for every Pallas kernel (CPU-runnable ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def contention_ref(a_send: jax.Array, a_recv: jax.Array,
                   active: jax.Array) -> jax.Array:
    """(C,P) incidence + (C,) active -> (C,) int32 contention counts."""
    act = active.astype(a_send.dtype)[:, None]
    a_s = a_send * act
    a_r = a_recv * act
    share = a_s @ a_s.T + a_r @ a_r.T
    blocks = share > 0.5
    k = blocks.sum(axis=1) - jnp.diagonal(blocks)
    return jnp.where(active, k.astype(jnp.int32), 0)


def maxmin_ref(src_onehot: jax.Array, dst_onehot: jax.Array,
               live: jax.Array, bw_send: jax.Array, bw_recv: jax.Array,
               num_rounds: int | None = None) -> jax.Array:
    """Bipartite max-min fair rates by progressive filling.

    src_onehot/dst_onehot: (P, F) {0,1}; live: (F,) bool; bw: (P,).
    Returns (F,) rates. Matches core.policies.base.maxmin_waterfill.
    """
    P, F = src_onehot.shape
    rounds = num_rounds or 2 * P + 2
    big = jnp.float32(1e30)

    def body(state, _):
        rates, frozen, avail_s, avail_r = state
        act = (~frozen) & live
        actf = act.astype(jnp.float32)
        cnt_s = src_onehot @ actf
        cnt_r = dst_onehot @ actf
        lvl_s = jnp.where(cnt_s > 0, avail_s / jnp.maximum(cnt_s, 1.0), big)
        lvl_r = jnp.where(cnt_r > 0, avail_r / jnp.maximum(cnt_r, 1.0), big)
        lvl = jnp.minimum(lvl_s.min(), lvl_r.min())
        any_act = act.any()
        sat_s = (lvl_s <= lvl + 1e-12) & (cnt_s > 0)
        sat_r = (lvl_r <= lvl + 1e-12) & (cnt_r > 0)
        hit = act & ((sat_s @ src_onehot) + (sat_r @ dst_onehot) > 0.5)
        hit = hit & any_act
        rates = jnp.where(hit, lvl, rates)
        hitf = hit.astype(jnp.float32)
        avail_s = jnp.maximum(avail_s - lvl * (src_onehot @ hitf), 0.0)
        avail_r = jnp.maximum(avail_r - lvl * (dst_onehot @ hitf), 0.0)
        return (rates, frozen | hit, avail_s, avail_r), None

    init = (jnp.zeros(F, jnp.float32), ~live,
            bw_send.astype(jnp.float32), bw_recv.astype(jnp.float32))
    (rates, _, _, _), _ = jax.lax.scan(body, init, None, length=rounds)
    return rates


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, scale: float | None = None,
                  logit_dtype=jnp.float32) -> jax.Array:
    """(B, H, S, D) x (B, Hkv, T, D) GQA attention, materialized softmax."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qg = q.reshape(B, Hkv, G, S, D)
    logits = jnp.einsum("bhgsd,bhtd->bhgst", qg.astype(logit_dtype),
                        k.astype(logit_dtype)) * scale
    if causal:
        T = k.shape[2]
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgst,bhtd->bhgsd", p, v.astype(logit_dtype))
    return o.reshape(B, H, S, D).astype(q.dtype)


def ssd_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
            c: jax.Array, *, init_state: jax.Array | None = None):
    """Mamba-2 SSD (state-space dual) sequential reference.

    x: (B, L, H, Dh) inputs; dt: (B, L, H) step sizes (post-softplus);
    a: (H,) negative state decay rates (A = -exp(a_log));
    b, c: (B, L, G, N) input/output projections (G state groups, heads
    grouped H//G per group). Returns (y, final_state) with y shaped like
    x and state (B, H, Dh, N).

    Recurrence per head h (group g = h // (H//G)):
      S_t = exp(dt_t * a_h) * S_{t-1} + dt_t * x_t b_t^T
      y_t = S_t c_t
    """
    B, L, H, Dh = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    bh = jnp.repeat(b, rep, axis=2)  # (B, L, H, N)
    ch = jnp.repeat(c, rep, axis=2)

    s0 = (init_state if init_state is not None
          else jnp.zeros((B, H, Dh, N), jnp.float32))

    def step(s, inp):
        xt, dtt, bt, ct = inp     # (B,H,Dh), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(dtt * a)[..., None, None]           # (B,H,1,1)
        s = decay * s + (dtt[..., None, None]
                         * xt[..., None] * bt[:, :, None, :])
        yt = jnp.einsum("bhdn,bhn->bhd", s, ct)
        return s, yt

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(bh, 1, 0).astype(jnp.float32),
          jnp.moveaxis(ch, 1, 0).astype(jnp.float32))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B, L, H, Dh)
    return y, s_fin
