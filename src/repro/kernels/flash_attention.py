"""Pallas TPU flash attention (forward) — tiled online-softmax prefill.

Used by the 32k-prefill path on TPU. Grid = (B*H, Sq/bq, Skv/bk) with the
kv dimension innermost (sequential on TPU), carrying the running softmax
state (m, l, acc) in VMEM scratch across kv iterations — the classic
FlashAttention-2 schedule adapted to the MXU: bq x bk = 256 x 512 blocks
keep both matmuls (s = q k^T and p v) 128-aligned, and the working set
(q, k, v blocks + acc) is ~1.5 MB of VMEM.

GQA is handled without materializing repeated KV heads: the kv BlockSpec
index map divides the query-head grid index by the group size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq, bk, scale, causal, q_offset, kv_len):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip blocks that are entirely masked under causality
    q_hi = q_offset + i * bq + bq - 1   # largest absolute q position
    k_lo = j * bk
    run = (not causal) or (k_lo <= q_hi)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

        q_ids = q_offset + i * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 0)
        k_ids = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_ids < kv_len
        if causal:
            mask &= k_ids <= q_ids
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = s.max(axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l_prev + p.sum(axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(j == nj - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "bq", "bk", "q_offset", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = 256, bk: int = 512,
                           q_offset: int = 0,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, S, D); k, v: (B, Hkv, T, D) with H % Hkv == 0.

    q_offset: absolute position of q[0] (chunked prefill against a longer
    KV). Returns (B, H, S, D) in q.dtype.
    """
    B, H, S, D = q.shape
    _, Hkv, T, _ = k.shape
    G = H // Hkv
    scale = 1.0 / (D ** 0.5)

    bq = min(bq, max(S, 8))
    bk = min(bk, max(T, 128))
    Sp = -(-S // bq) * bq
    Tp = -(-T // bk) * bk
    qq = jnp.zeros((B * H, Sp, D), q.dtype).at[:, :S].set(
        q.reshape(B * H, S, D))
    kk = jnp.zeros((B * Hkv, Tp, D), k.dtype).at[:, :T].set(
        k.reshape(B * Hkv, T, D))
    vv = jnp.zeros((B * Hkv, Tp, D), v.dtype).at[:, :T].set(
        v.reshape(B * Hkv, T, D))

    grid = (B * H, Sp // bq, Tp // bk)
    from jax.experimental.pallas import tpu as pltpu

    o = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale,
                          causal=causal, q_offset=q_offset, kv_len=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, i, j, G=G: (bh // G, j, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, i, j, G=G: (bh // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qq, kk, vv)
    return o[:, :S].reshape(B, H, S, D)
