"""Loader for the public coflow-benchmark trace format.

Format (github.com/coflow/coflow-benchmark, FB-UIUC trace):

    <num_ports> <num_coflows>
    <id> <arrival_ms> <num_mappers> <m1 m2 ...> <num_reducers> \
        <r1:size_mb r2:size_mb ...>

Each reducer entry is `port:total_MB_received`; the shuffle bytes of one
reducer are split equally across the coflow's mappers (the convention used
by the open-source coflowsim this paper compares against).
"""
from __future__ import annotations

from repro.core.coflow import Coflow, Flow, Trace

MB = 1024.0 * 1024.0


def load_coflow_benchmark(path: str) -> Trace:
    with open(path) as fh:
        tokens = fh.readline().split()
        num_ports, num_coflows = int(tokens[0]), int(tokens[1])
        coflows = []
        fid = 0
        for _ in range(num_coflows):
            parts = fh.readline().split()
            cid = int(parts[0])
            arrival = float(parts[1]) / 1e3
            nm = int(parts[2])
            mappers = [int(x) % num_ports for x in parts[3:3 + nm]]
            idx = 3 + nm
            nr = int(parts[idx])
            flows = []
            for ent in parts[idx + 1: idx + 1 + nr]:
                r, sz = ent.split(":")
                dst = int(r) % num_ports
                per_mapper = float(sz) * MB / max(len(mappers), 1)
                for src in mappers:
                    flows.append(Flow(fid, src, dst,
                                      max(per_mapper, 1.0)))
                    fid += 1
            coflows.append(Coflow(cid=cid, arrival=arrival, flows=flows))
    tr = Trace(num_ports=num_ports, coflows=coflows)
    tr.validate()
    return tr
