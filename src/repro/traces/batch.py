"""Padded batch representation of coflow traces for the XLA fleet engine.

``pack`` flattens a list of `Trace` (or pre-built `FlowTable`) objects
into one `TraceBatch` of rectangular arrays — flows padded to a common
F, coflows to a common C, ports to a common P — so `fabric.jax_engine`
can `jax.vmap` a whole fleet of replays into a single XLA computation.

Padding semantics (see DESIGN.md §3):

* padded flows have ``flow_valid=False`` and start *done* in the
  engine, so they never go live, never contribute to port counts, and
  never hold a coflow open;
* padded coflows have ``coflow_valid=False`` and ``arrival=+inf`` so
  they never activate; their width is 1 so Eq. 1 arithmetic stays
  benign;
* ``arrival_rank`` is the host-computed exact FIFO rank (stable argsort
  of arrival) — float arrivals may collide in f32, ranks cannot.

Pad sizes round up to multiples (flows: 64, coflows: 16) so traces of
slightly different sizes share one compiled engine executable.
"""
from __future__ import annotations

from typing import List, NamedTuple, Sequence, Union

import numpy as np

from repro.core.coflow import Trace
from repro.fabric.state import FlowTable


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


class TraceBatch(NamedTuple):
    """B padded traces. Leading axis of every array is the trace axis."""
    # per-flow (B, F)
    cid: np.ndarray         # int32 owning coflow (0 for padding)
    src: np.ndarray         # int32 sender port
    dst: np.ndarray         # int32 receiver port
    size: np.ndarray        # float32 bytes (1.0 for padding)
    flow_valid: np.ndarray  # bool
    # per-coflow (B, C)
    arrival: np.ndarray       # float32 seconds (+inf for padding)
    arrival_rank: np.ndarray  # int32 exact FIFO rank (host-computed)
    width: np.ndarray         # int32 total flow count N_c
    coflow_valid: np.ndarray  # bool
    flow_lo: np.ndarray       # int32 [lo, hi) contiguous flow range —
    flow_hi: np.ndarray       # segment reductions become cumsum diffs
    # per-port (B, P)
    bw_send: np.ndarray     # float32 bytes/s
    bw_recv: np.ndarray     # float32 bytes/s
    # port-count machinery (host-precomputed): flows reordered by
    # (cid, src) / (cid, dst) make every (coflow, port) group contiguous,
    # so the engine's live-flow port counts are 1-D cumsum differences
    # over [lo, hi) instead of (F, 2P) scatter/cumsum work.
    perm_src: np.ndarray    # (B, F) int32 flow order sorted by (cid, src)
    perm_dst: np.ndarray    # (B, F) int32 flow order sorted by (cid, dst)
    lo_src: np.ndarray      # (B, C, P) int32 group start in perm_src order
    hi_src: np.ndarray      # (B, C, P) int32 group end
    lo_dst: np.ndarray      # (B, C, P) int32
    hi_dst: np.ndarray      # (B, C, P) int32
    # flows sorted by (cid, valid-first, size): within every coflow the
    # REAL flows occupy [flow_lo, flow_hi) in ascending size order, so
    # the engine's §4.3 finished-flow median is an order-statistics
    # lookup over contiguous segments (no per-tick sort, no scatters).
    perm_size: np.ndarray   # (B, F) int32
    # leaf-spine link-incidence layout (DESIGN.md §11): Lf leaf ids per
    # flow (Lf itself = "crosses no shared link" — intra-leaf flows and
    # padding), per-leaf uplink/downlink capacities, and the same
    # (cid, link)-sorted permutation + searchsorted group-bounds trick
    # as perm_src, so per-(coflow, link) live counts are segment sums.
    # Lf=0 (BigSwitch) keeps every link array zero-width and the
    # engine's link machinery compiled out entirely.
    link_up: np.ndarray     # (B, F) int32 uplink leaf id, Lf = none
    link_dn: np.ndarray     # (B, F) int32 downlink leaf id, Lf = none
    bw_up: np.ndarray       # (B, Lf) float32 uplink capacity, bytes/s
    bw_dn: np.ndarray       # (B, Lf) float32 downlink capacity
    perm_up: np.ndarray     # (B, F) int32 flow order by (cid, link_up)
    perm_dn: np.ndarray     # (B, F) int32 flow order by (cid, link_dn)
    lo_up: np.ndarray       # (B, C, Lf) int32 group start in perm_up
    hi_up: np.ndarray       # (B, C, Lf) int32 group end
    lo_dn: np.ndarray       # (B, C, Lf) int32
    hi_dn: np.ndarray       # (B, C, Lf) int32
    # non-clairvoyant pilot layout (core.sampling): each coflow's first
    # K_c flows in slab order are its pilots. None = sampling compiled
    # out — an empty pytree subtree, so every pre-existing jaxpr is
    # byte-identical (the Lf=0 leaf-spine pattern).
    pilot: np.ndarray | None = None  # (B, F) bool

    @property
    def num_traces(self) -> int:
        return self.cid.shape[0]

    @property
    def max_flows(self) -> int:
        return self.cid.shape[1]

    @property
    def max_coflows(self) -> int:
        return self.arrival.shape[1]

    @property
    def num_ports(self) -> int:
        return self.bw_send.shape[1]

    @property
    def num_leaf_links(self) -> int:
        """Lf — leaves of the packed leaf-spine topology (0 = big
        switch; a STATIC shape, so `if tb.num_leaf_links:` inside the
        jitted tick compiles the link machinery in or out)."""
        return self.bw_up.shape[1]

    @property
    def has_pilots(self) -> bool:
        """Sampling layout packed in? (STATIC — compiled in or out.)"""
        return self.pilot is not None

    def row(self, b: int) -> "TraceBatch":
        """Single-trace slice, keeping the (1, ...) batch axis."""
        return TraceBatch(*(None if a is None else a[b:b + 1]
                            for a in self))


def empty_batch(num_rows: int, *, flow_capacity: int, coflow_capacity: int,
                port_capacity: int, leaf_links: int = 0,
                sampling: bool = False) -> TraceBatch:
    """An all-padding TraceBatch: every row is a blank slab row (no
    valid coflows or flows). This is the `SessionPool`'s backing store —
    rows are written in place with `pack_row` as sessions submit and
    recycled (re-blanked) as they retire, so the padded shapes (and the
    compiled engine executables) survive arbitrary membership churn."""
    B, F = num_rows, flow_capacity
    C, P = coflow_capacity, port_capacity
    Lf = leaf_links
    if B <= 0 or P <= 0 or F < 0 or C < 0 or Lf < 0:
        raise ValueError("empty_batch needs positive rows/ports and "
                         "non-negative flow/coflow/link capacities")
    return TraceBatch(
        cid=np.zeros((B, F), np.int32), src=np.zeros((B, F), np.int32),
        dst=np.zeros((B, F), np.int32), size=np.ones((B, F), np.float32),
        flow_valid=np.zeros((B, F), bool),
        arrival=np.full((B, C), np.inf, np.float32),
        arrival_rank=np.full((B, C), 2 ** 30, np.int32),
        width=np.ones((B, C), np.int32),
        coflow_valid=np.zeros((B, C), bool),
        flow_lo=np.zeros((B, C), np.int32),
        flow_hi=np.zeros((B, C), np.int32),
        bw_send=np.zeros((B, P), np.float32),
        bw_recv=np.zeros((B, P), np.float32),
        perm_src=np.tile(np.arange(F, dtype=np.int32), (B, 1)),
        perm_dst=np.tile(np.arange(F, dtype=np.int32), (B, 1)),
        lo_src=np.zeros((B, C, P), np.int32),
        hi_src=np.zeros((B, C, P), np.int32),
        lo_dst=np.zeros((B, C, P), np.int32),
        hi_dst=np.zeros((B, C, P), np.int32),
        perm_size=np.tile(np.arange(F, dtype=np.int32), (B, 1)),
        link_up=np.full((B, F), Lf, np.int32),
        link_dn=np.full((B, F), Lf, np.int32),
        bw_up=np.zeros((B, Lf), np.float32),
        bw_dn=np.zeros((B, Lf), np.float32),
        perm_up=np.tile(np.arange(F, dtype=np.int32), (B, 1)),
        perm_dn=np.tile(np.arange(F, dtype=np.int32), (B, 1)),
        lo_up=np.zeros((B, C, Lf), np.int32),
        hi_up=np.zeros((B, C, Lf), np.int32),
        lo_dn=np.zeros((B, C, Lf), np.int32),
        hi_dn=np.zeros((B, C, Lf), np.int32),
        pilot=np.zeros((B, F), bool) if sampling else None,
    )


def blank_row(tb: TraceBatch, b: int) -> None:
    """Reset row `b` to all-padding in place (recycle a freed slab row)."""
    F = tb.max_flows
    tb.cid[b] = 0
    tb.src[b] = 0
    tb.dst[b] = 0
    tb.size[b] = 1.0
    tb.flow_valid[b] = False
    tb.arrival[b] = np.inf
    tb.arrival_rank[b] = 2 ** 30
    tb.width[b] = 1
    tb.coflow_valid[b] = False
    tb.flow_lo[b] = 0
    tb.flow_hi[b] = 0
    tb.bw_send[b] = 0.0
    tb.bw_recv[b] = 0.0
    tb.perm_src[b] = np.arange(F, dtype=np.int32)
    tb.perm_dst[b] = np.arange(F, dtype=np.int32)
    tb.lo_src[b] = 0
    tb.hi_src[b] = 0
    tb.lo_dst[b] = 0
    tb.hi_dst[b] = 0
    tb.perm_size[b] = np.arange(F, dtype=np.int32)
    tb.link_up[b] = tb.bw_up.shape[1]
    tb.link_dn[b] = tb.bw_up.shape[1]
    tb.bw_up[b] = 0.0
    tb.bw_dn[b] = 0.0
    tb.perm_up[b] = np.arange(F, dtype=np.int32)
    tb.perm_dn[b] = np.arange(F, dtype=np.int32)
    tb.lo_up[b] = 0
    tb.hi_up[b] = 0
    tb.lo_dn[b] = 0
    tb.hi_dn[b] = 0
    if tb.pilot is not None:
        tb.pilot[b] = False


def pack_row(tb: TraceBatch, b: int, t: FlowTable, *,
             arrival_rank=None, topology=None,
             pilot_frac: float = 0.1) -> None:
    """Write one FlowTable into slab row `b` in place (blanking it
    first), recomputing the row's host-side permutations/segment
    layouts. `arrival_rank` overrides the per-row arrival argsort with
    caller-supplied exact FIFO ranks — an online session's ranks are
    session-global submission ranks, which must survive re-packs that
    see only the still-live subset. Raises when the row's capacities
    cannot hold the table (the caller grows the slab and re-packs)."""
    f, c = t.size.shape[0], t.num_coflows
    F, C, P = tb.max_flows, tb.max_coflows, tb.num_ports
    if f > F or c > C or t.num_ports > P:
        raise ValueError(
            f"slab row capacity exceeded: ({f} flows, {c} coflows, "
            f"{t.num_ports} ports) > ({F}, {C}, {P})")
    blank_row(tb, b)
    if c == 0:
        return
    tb.cid[b, :f] = t.cid
    # padded flows get the first padded coflow id — or, when the
    # trace fills C exactly, the LAST REAL id (the pad run then
    # contiguously extends that coflow's run). Either way segment
    # ids form non-repeating contiguous runs, which is all the
    # engine's segmented reductions need; any gather through a pad
    # cid must stay masked by flow_valid (pads start done).
    tb.cid[b, f:] = min(c, C - 1)
    tb.src[b, :f] = t.src
    tb.dst[b, :f] = t.dst
    tb.size[b, :f] = t.size
    tb.flow_valid[b, :f] = True
    tb.arrival[b, :c] = t.arrival
    tb.arrival_rank[b, :c] = np.argsort(
        np.argsort(t.arrival, kind="stable"), kind="stable") \
        if arrival_rank is None else arrival_rank
    tb.width[b, :c] = t.width
    tb.coflow_valid[b, :c] = True
    tb.flow_lo[b, :c] = t.flow_lo
    tb.flow_hi[b, :c] = t.flow_hi
    tb.bw_send[b, :t.num_ports] = t.bw_send
    tb.bw_recv[b, :t.num_ports] = t.bw_recv
    for port, perm_out, lo_out, hi_out in (
            (t.src, tb.perm_src, tb.lo_src, tb.hi_src),
            (t.dst, tb.perm_dst, tb.lo_dst, tb.hi_dst)):
        order = np.lexsort((port, t.cid)).astype(np.int32)
        perm_out[b, :f] = order
        keys = t.cid[order].astype(np.int64) * P + port[order]
        grid = np.arange(C * P, dtype=np.int64)
        lo_out[b] = np.searchsorted(keys, grid, "left").reshape(C, P)
        hi_out[b] = np.searchsorted(keys, grid, "right").reshape(C, P)
    # (cid, valid-first, size) order: pads share the last real cid
    # when the trace fills C exactly, so the valid key pushes them
    # BEHIND that coflow's real flows — [flow_lo, flow_hi) stays a
    # correct segment of real flows in this permutation too.
    tb.perm_size[b] = np.lexsort(
        (tb.size[b], ~tb.flow_valid[b], tb.cid[b])).astype(np.int32)
    if tb.pilot is not None:
        # pilot layout (core.sampling): first K_c flows per coflow in
        # slab order — identical to the numpy SizeEstimator's rule
        from repro.core.sampling import pilot_mask

        tb.pilot[b, :f] = pilot_mask(t.cid, t.flow_lo, t.width,
                                     pilot_frac)
    # leaf-spine link layout (blank_row already reset it to "no links")
    Lf = tb.bw_up.shape[1]
    need = 0 if topology is None else topology.leaf_count(t.num_ports)
    if need == 0:
        return
    if need > Lf:
        raise ValueError(
            f"slab row link capacity exceeded: topology needs {need} "
            f"leaves > {Lf} packed")
    cap_up, cap_dn = topology.link_caps(t.bw_send, t.bw_recv)
    tb.bw_up[b, :need] = cap_up
    tb.bw_dn[b, :need] = cap_dn
    up, dn = topology.flow_links(t.src, t.dst)
    # sentinel Lf = "touches no shared link" (intra-leaf; also the
    # blank value padding keeps) — excluded from the (cid, link) grid
    tb.link_up[b, :f] = np.where(up >= 0, up, Lf).astype(np.int32)
    tb.link_dn[b, :f] = np.where(dn >= 0, dn, Lf).astype(np.int32)
    grid = (np.arange(C, dtype=np.int64)[:, None] * (Lf + 1)
            + np.arange(Lf, dtype=np.int64)[None, :]).ravel()
    for link, perm_out, lo_out, hi_out in (
            (tb.link_up[b, :f], tb.perm_up, tb.lo_up, tb.hi_up),
            (tb.link_dn[b, :f], tb.perm_dn, tb.lo_dn, tb.hi_dn)):
        order = np.lexsort((link, t.cid)).astype(np.int32)
        perm_out[b, :f] = order
        keys = t.cid[order].astype(np.int64) * (Lf + 1) + link[order]
        lo_out[b] = np.searchsorted(keys, grid, "left").reshape(C, Lf)
        hi_out[b] = np.searchsorted(keys, grid, "right").reshape(C, Lf)


def row_of(tb: TraceBatch, b: int) -> tuple:
    """Copies of row `b`'s leaves WITHOUT the batch axis — the unit the
    `SessionPool`'s dirty-row scatter path stages host-side (pack into a
    1-row scratch with `pack_row`, slice with `row_of`, stack the dirty
    set with `stack_rows`, scatter once)."""
    return tuple(None if a is None else np.array(a[b]) for a in tb)


def stack_rows(rows: Sequence[tuple]) -> TraceBatch:
    """Stack `row_of` tuples into a (k, ...) TraceBatch update payload
    (the host-side half of `jax_engine.scatter_rows`)."""
    if not rows:
        raise ValueError("stack_rows needs at least one row")
    return TraceBatch(*(None if cols[0] is None else np.stack(cols)
                        for cols in zip(*rows)))


def pack(traces: Sequence[Union[Trace, FlowTable]], *,
         port_bw: float = None,
         flow_multiple: int = 64, coflow_multiple: int = 16,
         flow_capacity: int = 0, coflow_capacity: int = 0,
         port_capacity: int = 0, topology=None,
         sampling: bool = False, pilot_frac: float = 0.1) -> TraceBatch:
    """Pad/pack traces (or FlowTables) into one TraceBatch.

    `port_bw` is required when packing `Trace` objects (FlowTables carry
    their own per-port bandwidths). DAG stage dependencies are a
    host-simulator feature and are rejected here.

    The `*_capacity` floors support incremental (session) packing: a
    `SessionPool` re-packs its live coflows into a slab whose
    capacities only ever grow geometrically, so the padded shapes — and
    therefore the compiled engine executables — stay stable across
    submit/retire churn while freed rows are recycled (`pack_row` /
    `blank_row` are the in-place per-row primitives it uses).
    """
    tables: List[FlowTable] = []
    for t in traces:
        if isinstance(t, Trace):
            if port_bw is None:
                raise ValueError("port_bw is required to pack Trace objects")
            tables.append(FlowTable.from_trace(t, port_bw))
        else:
            tables.append(t)
    if not tables:
        raise ValueError("pack() needs at least one trace")
    for t in tables:
        if t.deps is not None:
            raise NotImplementedError(
                "DAG stage deps are not supported by the batched engine; "
                "use fabric.engine.Simulator")

    B = len(tables)
    F = max(_round_up(max(t.size.shape[0] for t in tables), flow_multiple),
            flow_capacity)
    C = max(_round_up(max(t.num_coflows for t in tables), coflow_multiple),
            coflow_capacity)
    P = max(max(t.num_ports for t in tables), port_capacity)
    topo = None
    Lf = 0
    if topology is not None:
        from repro.fabric.topology import normalize_topology

        topo = normalize_topology(topology)
        Lf = topo.leaf_count(P)
        if Lf == 0:
            topo = None      # BigSwitch: no link leaves at all

    tb = empty_batch(B, flow_capacity=F, coflow_capacity=C,
                     port_capacity=P, leaf_links=Lf, sampling=sampling)
    for b, t in enumerate(tables):
        pack_row(tb, b, t, topology=topo, pilot_frac=pilot_frac)
    return tb


__all__ = ["TraceBatch", "pack", "pack_row", "blank_row", "empty_batch",
           "row_of", "stack_rows"]
