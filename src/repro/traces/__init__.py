from repro.traces.batch import TraceBatch, pack
from repro.traces.loader import load_coflow_benchmark
from repro.traces.synth import fb_like_trace, tiny_trace

__all__ = ["fb_like_trace", "tiny_trace", "load_coflow_benchmark",
           "TraceBatch", "pack"]
