"""FB-like synthetic trace generator.

The public Facebook trace (coflow-benchmark) is not bundled; this module
re-synthesizes a trace matching the distributions the paper reports:

* Fig. 2(a): 23% of coflows have a single flow; the rest are map-reduce
  shuffles (M senders x R receivers, all-pairs flows) with heavy-tailed
  M, R.
* Fig. 2(b): of the multi-flow coflows, ~65% have equal-length flows
  (50/77 of all multi-flow coflows in the trace) and the rest have
  lognormal-skewed per-flow sizes.
* Table 1 bins: coflow total sizes are lognormal-heavy-tailed so that
  roughly half the coflows are <=100 MB and half the widths are <=10.
* 150 ports, 1 Gbps each, Poisson arrivals sized by a target load.

Deterministic given `seed`. `load` ~ offered bytes / fabric capacity.
"""
from __future__ import annotations

import numpy as np

from repro.core.coflow import Coflow, Flow, Trace

MB = 1024.0 * 1024.0
GBPS = 1e9 / 8.0

_FLOW_FLOOR = 1024.0


def _floor_preserving_total(per: np.ndarray, total: float) -> np.ndarray:
    """Apply the 1 KB per-flow floor WITHOUT inflating the coflow total.

    Clamping after normalization (`np.maximum(per, 1024.0)`) silently
    adds bytes on skewed coflows and drifts the Table-1 size bins.
    Instead, flows at the floor are fixed and the remainder is
    renormalized into the leftover budget, iterating until no flow
    falls below the floor. When the floor is infeasible
    (total < w * 1KB) the bytes are split equally. Deterministic —
    pure arithmetic on `per`, no RNG draws."""
    per = np.asarray(per, float).copy()
    w = per.size
    if total <= _FLOW_FLOOR * w:
        return np.full(w, total / w)
    fixed = np.zeros(w, bool)
    for _ in range(w):
        budget = total - _FLOW_FLOOR * fixed.sum()
        free = ~fixed
        per[free] *= budget / per[free].sum()
        low = free & (per < _FLOW_FLOOR)
        if not low.any():
            break
        fixed |= low
        per[fixed] = _FLOW_FLOOR
    return per


def fb_like_trace(num_coflows: int = 526, num_ports: int = 150, *,
                  seed: int = 0, load: float = 0.9,
                  arrival_speedup: float = 1.0,
                  max_width: int = 2000,
                  frac_single: float = 0.23,
                  frac_equal_of_multi: float = 0.65) -> Trace:
    rng = np.random.default_rng(seed)
    coflows = []

    # ---- per-coflow structure -------------------------------------------
    kind = rng.uniform(size=num_coflows)
    sizes_total = np.exp(rng.normal(np.log(30 * MB), 2.3, num_coflows))
    sizes_total = np.clip(sizes_total, 64 * 1024, 4e12)

    # heavy-tailed sender/receiver counts (capped by ports)
    def _fanout(n):
        x = 1 + rng.pareto(1.1, n) * 2.0
        return np.minimum(np.ceil(x).astype(int), num_ports)

    M = _fanout(num_coflows)
    R = _fanout(num_coflows)

    # arrivals: Poisson with rate matching target load on the fabric
    mean_bytes = float(sizes_total.mean())
    lam = load * num_ports * GBPS / mean_bytes  # coflows / second
    gaps = rng.exponential(1.0 / lam, num_coflows) / arrival_speedup
    arrivals = np.concatenate([[0.0], np.cumsum(gaps)[:-1]])

    fid = 0
    for c in range(num_coflows):
        arrival = float(arrivals[c])
        total = float(sizes_total[c])
        if kind[c] < frac_single:
            src, dst = rng.choice(num_ports, 2, replace=False)
            flows = [Flow(fid, int(src), int(dst), total)]
            fid += 1
        else:
            m, r = int(M[c]), int(R[c])
            while m * r > max_width:
                if m >= r:
                    m = max(1, m // 2)
                else:
                    r = max(1, r // 2)
            senders = rng.choice(num_ports, m, replace=False)
            receivers = rng.choice(num_ports, r, replace=False)
            w = m * r
            equal = rng.uniform() < frac_equal_of_multi
            if equal:
                per = np.full(w, total / w)
            else:
                skew = np.exp(rng.normal(0.0, 1.0, w))
                per = total * skew / skew.sum()
            per = _floor_preserving_total(per, total)
            flows = []
            i = 0
            for s in senders:
                for d in receivers:
                    flows.append(Flow(fid, int(s), int(d), float(per[i])))
                    fid += 1
                    i += 1
        coflows.append(Coflow(cid=c, arrival=arrival, flows=flows))

    tr = Trace(num_ports=num_ports, coflows=coflows)
    tr.validate()
    return tr


def tiny_trace(num_coflows: int = 40, num_ports: int = 20, *,
               seed: int = 0, **kw) -> Trace:
    """Small trace for tests (same generator, smaller fabric)."""
    kw.setdefault("max_width", 64)
    return fb_like_trace(num_coflows, num_ports, seed=seed, **kw)
