from repro.runtime import buckets, coflow_bridge, overlap

__all__ = ["buckets", "coflow_bridge", "overlap"]
