"""Gradient bucketizer: pytree leaves -> size-bounded buckets (= coflows).

The backward pass produces gradients in reverse-layer order; buckets
preserve that order (bucket 0 = deepest layers = ready first), which
becomes the coflow 'arrival rank' fed to the Saath coordinator.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class Bucket:
    bid: int
    paths: tuple          # leaf key-paths (jax.tree_util keystr)
    leaf_idx: tuple       # flat leaf indices
    bytes: int


def bucketize(tree: Any, bucket_bytes: int = 64 * 1024 * 1024,
              reverse: bool = True) -> List[Bucket]:
    """Greedy fill in (reversed) leaf order; a leaf larger than
    bucket_bytes gets its own bucket."""
    leaves_kp = jax.tree_util.tree_leaves_with_path(tree)
    items = []
    for idx, (kp, leaf) in enumerate(leaves_kp):
        sz = int(np.prod(leaf.shape)) * leaf.dtype.itemsize \
            if hasattr(leaf, "shape") else 8
        items.append((jax.tree_util.keystr(kp), idx, sz))
    if reverse:
        items = items[::-1]

    buckets: List[Bucket] = []
    cur_p, cur_i, cur_b = [], [], 0
    for path, idx, sz in items:
        if cur_b > 0 and cur_b + sz > bucket_bytes:
            buckets.append(Bucket(len(buckets), tuple(cur_p), tuple(cur_i),
                                  cur_b))
            cur_p, cur_i, cur_b = [], [], 0
        cur_p.append(path)
        cur_i.append(idx)
        cur_b += sz
    if cur_b:
        buckets.append(Bucket(len(buckets), tuple(cur_p), tuple(cur_i),
                              cur_b))
    return buckets
