"""Coflow bridge: collective traffic sources -> Saath schedule -> waves.

This is the paper's technique acting as the framework's collective
scheduler (DESIGN.md §2). Each pending collective is one COFLOW:

* a gradient bucket's reduce-scatter / all-reduce over the ``data`` (and
  ``pod``) axis — arrival rank = backward generation order;
* a MoE all-to-all wave over the expert axis;
* background tenants: checkpoint uploads (host/DCN links), KV-cache
  migrations between serving replicas.

Port model (TPU v5e): every chip has independent ICI links per torus
axis, so two collectives contend iff they use the same (axis, chip-
group) resource; DCN/host traffic uses distinct 'ports'. The planner
runs the *same* Fig. 7 algorithm (numpy Saath on a FlowTable whose
ports are (resource, chip) pairs) and emits WAVES: coflows admitted in
the same tick are issued together (they share no contended resource);
later waves are chained behind earlier ones with optimization barriers
(runtime.overlap). All-or-none holds by construction: an SPMD
collective is indivisible across its chips.

Planning is static per train step (sizes known at trace time), replayed
every step boundary — the paper's δ maps to the step interval (§2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.core.coflow import Coflow, Flow, Trace
from repro.core.params import SchedulerParams
from repro.core.policies import make_policy
from repro.fabric.state import FlowTable


@dataclasses.dataclass(frozen=True)
class CollectiveCoflow:
    name: str
    bytes: int                 # per-chip payload
    resources: tuple           # e.g. ("ici:data",), ("ici:model",), ("dcn",)
    arrival_rank: int          # readiness order within the step
    chips: tuple = ()          # chip ids involved; () = all


# canonical resources on a (pod, data, model) mesh
RESOURCES = ("ici:data", "ici:model", "ici:pod", "dcn", "host")


def plan_waves(coflows: Sequence[CollectiveCoflow], *,
               num_chips: int = 16,
               params: SchedulerParams | None = None) -> List[List[str]]:
    """Order collectives with the Saath coordinator; returns waves of
    coflow names (wave = admitted in the same coordinator tick).

    The fabric model: one port per (resource, chip). A coflow's flows
    cover its resource on every involved chip; sizes are the per-chip
    bytes, so per-flow queue thresholds and LCoF act exactly as in the
    paper (a 'wide' MoE a2a demotes faster than a thin DCN upload).
    """
    if not coflows:
        return []
    params = params or SchedulerParams(
        port_bw=50e9, delta=1e-4, start_threshold=8 * 1024 * 1024)
    res_index = {r: i for i, r in enumerate(RESOURCES)}
    P = len(RESOURCES) * num_chips

    # Densely renumber arrival ranks, preserving (rank, submission) order.
    # Duplicate ranks are legal — e.g. two tenants both built with
    # grad_bucket_coflows(rank_offset=0) — and previously collided in the
    # rank->position dicts, silently dropping collectives from the plan.
    order = sorted(range(len(coflows)),
                   key=lambda i: (coflows[i].arrival_rank, i))
    dense_rank = {i: pos for pos, i in enumerate(order)}

    trace_coflows = []
    fid = 0
    for i, c in enumerate(coflows):
        chips = c.chips or tuple(range(num_chips))
        flows = []
        for r in c.resources:
            base = res_index[r] * num_chips
            for ch in chips:
                flows.append(Flow(fid, base + ch, base + ch,
                                  max(c.bytes, 1.0)))
                fid += 1
        trace_coflows.append(
            Coflow(cid=dense_rank[i], arrival=float(dense_rank[i]) * 1e-9,
                   flows=flows))
    trace = Trace(num_ports=P, coflows=trace_coflows)
    table = FlowTable.from_trace(trace, params.port_bw)
    table.active[:] = True

    pol = make_policy("saath", params, work_conservation=False)
    pol.reset(table)

    # FlowTable orders coflows by cid == dense rank, so position == rank
    by_pos: Dict[int, str] = {dense_rank[i]: c.name
                              for i, c in enumerate(coflows)}
    waves: List[List[str]] = []
    now = 0.0
    remaining = set(by_pos)
    guard = 0
    while remaining and guard < len(by_pos) + 2:
        guard += 1
        rates = pol.schedule(table, now)
        admitted = sorted(
            c for c in remaining
            if rates[table.flow_lo[c]:table.flow_hi[c]].max() > 0)
        if not admitted:  # should not happen: ports free up every wave
            admitted = [min(remaining)]
        waves.append([by_pos[c] for c in admitted])
        for c in admitted:
            lo, hi = table.flow_lo[c], table.flow_hi[c]
            table.sent[lo:hi] = table.size[lo:hi]
            table.done[lo:hi] = True
            table.finished[c] = True
            table.active[c] = False
            remaining.discard(c)
        now += params.delta
    if remaining:
        # a truncated plan would silently drop collectives from the step
        raise RuntimeError(
            f"plan_waves failed to place {len(remaining)} collectives "
            f"({sorted(by_pos[c] for c in remaining)}) after {guard} "
            "waves — scheduler made no progress")
    return waves


def grad_bucket_coflows(buckets, *, axes=("ici:data",),
                        rank_offset: int = 0) -> List[CollectiveCoflow]:
    """Buckets arrive in reverse-layer order (bucket 0 ready first)."""
    return [CollectiveCoflow(name=f"grad/{b.bid}", bytes=b.bytes,
                             resources=tuple(axes),
                             arrival_rank=rank_offset + b.bid)
            for b in buckets]
