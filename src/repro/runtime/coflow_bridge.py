"""Coflow bridge: collective traffic sources -> Saath schedule -> waves.

This is the paper's technique acting as the framework's collective
scheduler (DESIGN.md §2). Each pending collective is one COFLOW:

* a gradient bucket's reduce-scatter / all-reduce over the ``data`` (and
  ``pod``) axis — arrival rank = backward generation order;
* a MoE all-to-all wave over the expert axis;
* background tenants: checkpoint uploads (host/DCN links), KV-cache
  migrations between serving replicas.

Port model (TPU v5e): every chip has independent ICI links per torus
axis, so two collectives contend iff they use the same (axis, chip-
group) resource; DCN/host traffic uses distinct 'ports'. The planner is
a thin client of `repro.api.SaathSession` (DESIGN.md §7): collectives
are submitted in dense arrival-rank order and each wave is one
`plan_tick` — the session's wave-planning mode, in which the admitted
(resource-disjoint, all-or-none) set completes instantly. Later waves
are chained behind earlier ones with optimization barriers
(runtime.overlap). ``backend="jax"`` (the default) runs the jitted
coordinator on the session's device slab; ``backend="numpy"`` is the
host reference, kept as the parity oracle — the two produce bitwise-
identical wave orders (tests/test_runtime_bridge.py).

Static per-step planning (sizes known at trace time) remains the
default framework use; an open-loop *online* use of the same session
(arrivals across steps) is demonstrated by examples/online_service.py.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

from repro.core.coflow import Coflow, Flow
from repro.core.params import SchedulerParams


@dataclasses.dataclass(frozen=True)
class CollectiveCoflow:
    name: str
    bytes: int                 # per-chip payload
    resources: tuple           # e.g. ("ici:data",), ("ici:model",), ("dcn",)
    arrival_rank: int          # readiness order within the step
    chips: tuple = ()          # chip ids involved; () = all


# canonical resources on a (pod, data, model) mesh
RESOURCES = ("ici:data", "ici:model", "ici:pod", "dcn", "host")


def collective_to_coflow(c: CollectiveCoflow, *, num_chips: int = 16,
                         arrival: float = 0.0) -> Coflow:
    """One collective as a Coflow on the (resource, chip) port grid: a
    flow per involved chip on each of its resources, sized by the
    per-chip bytes — so per-flow queue thresholds and LCoF act exactly
    as in the paper (a 'wide' MoE a2a demotes faster than a thin DCN
    upload)."""
    res_index = {r: i for i, r in enumerate(RESOURCES)}
    chips = c.chips or tuple(range(num_chips))
    flows, fid = [], 0
    for r in c.resources:
        base = res_index[r] * num_chips
        for ch in chips:
            flows.append(Flow(fid, base + ch, base + ch,
                              max(c.bytes, 1.0)))
            fid += 1
    return Coflow(cid=0, arrival=arrival, flows=flows)


def bridge_params() -> SchedulerParams:
    """Default fabric knobs for the collective plane (50 GB/s ICI-class
    ports, 0.1 ms waves, 8 MB start threshold)."""
    return SchedulerParams(port_bw=50e9, delta=1e-4,
                           start_threshold=8 * 1024 * 1024)


def plan_waves(coflows: Sequence[CollectiveCoflow], *,
               num_chips: int = 16,
               params: SchedulerParams | None = None,
               backend: str = "jax") -> List[List[str]]:
    """Order collectives with the Saath coordinator; returns waves of
    coflow names (wave = admitted in the same coordinator tick).

    All-or-none holds by construction: an SPMD collective is
    indivisible across its chips, so within a wave no two collectives
    share a contended (resource, chip) port. Duplicate arrival ranks
    are legal — e.g. two tenants both built with
    grad_bucket_coflows(rank_offset=0) — and are densely renumbered
    preserving (rank, submission) order before submission, so the
    session's global FIFO ranks reproduce the intended order.
    """
    if not coflows:
        return []
    from repro.api import SaathSession

    params = params or bridge_params()
    P = len(RESOURCES) * num_chips
    order = sorted(range(len(coflows)),
                   key=lambda i: (coflows[i].arrival_rank, i))
    # work conservation off: a wave is an all-or-none admitted set; a
    # partially-issued collective is meaningless
    sess = SaathSession(params, num_ports=P, backend=backend,
                        mechanisms={"work_conservation": False})
    names = {}
    for i in order:
        c = coflows[i]
        h = sess.submit([collective_to_coflow(c, num_chips=num_chips)])[0]
        names[h] = c.name

    waves: List[List[str]] = []
    remaining = set(names)
    guard = 0
    while remaining and guard < len(names) + 2:
        guard += 1
        admitted = sorted(h for h in sess.plan_tick() if h in remaining)
        if not admitted:  # should not happen: ports free up every wave
            admitted = [min(remaining)]
            sess.complete(admitted)
        waves.append([names[h] for h in admitted])
        remaining.difference_update(admitted)
    if remaining:
        # a truncated plan would silently drop collectives from the step
        raise RuntimeError(
            f"plan_waves failed to place {len(remaining)} collectives "
            f"({sorted(names[h] for h in remaining)}) after {guard} "
            "waves — scheduler made no progress")
    return waves


def grad_bucket_coflows(buckets, *, axes=("ici:data",),
                        rank_offset: int = 0) -> List[CollectiveCoflow]:
    """Buckets arrive in reverse-layer order (bucket 0 ready first)."""
    return [CollectiveCoflow(name=f"grad/{b.bid}", bytes=b.bytes,
                             resources=tuple(axes),
                             arrival_rank=rank_offset + b.bid)
            for b in buckets]
