"""Wave-ordered collective issue (the all-or-none issue engine).

Inside jit, XLA is free to reorder independent collectives; to make the
Saath plan binding we chain waves with ``jax.lax.optimization_barrier``:
every collective of wave i+1 data-depends on the results of wave i, so
the compiled program issues the waves in plan order while collectives
*within* a wave (disjoint resources per the planner) remain free to
overlap with each other and with compute.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp


def issue_waves(tensors: Dict[str, jax.Array],
                waves: Sequence[Sequence[str]],
                op: Callable[[str, jax.Array], jax.Array]):
    """Apply `op(name, x)` (a collective) to each named tensor, wave by
    wave, with barriers between waves. Returns dict of results."""
    out: Dict[str, jax.Array] = {}
    token = None
    for wave in waves:
        if token is not None:
            # make this wave's inputs depend on the previous wave's outputs
            gated = jax.lax.optimization_barrier(
                tuple(tensors[n] for n in wave) + (token,))
            wave_in = dict(zip(wave, gated[:-1]))
        else:
            wave_in = {n: tensors[n] for n in wave}
        results = [op(n, wave_in[n]) for n in wave]
        for n, r in zip(wave, results):
            out[n] = r
        # token summarises the wave cheaply (scalar from each result)
        token = jnp.stack([jnp.real(r.ravel()[0]).astype(jnp.float32)
                           for r in results]).sum()
    return out


def scheduled_psum(grads_flat: List[jax.Array], buckets, waves,
                   axis_name: str | tuple):
    """shard_map-level: per-bucket psum of flattened gradients, issued in
    Saath wave order. grads_flat: flat leaf list (same order bucketize
    saw). Returns the reduced flat list."""
    name_to_bucket = {f"grad/{b.bid}": b for b in buckets}
    packed = {
        f"grad/{b.bid}": jnp.concatenate(
            [grads_flat[i].ravel() for i in b.leaf_idx])
        for b in buckets
    }

    def op(name, x):
        return jax.lax.psum(x, axis_name)

    reduced = issue_waves(packed, waves, op)

    out = list(grads_flat)
    for name, vec in reduced.items():
        b = name_to_bucket[name]
        off = 0
        for i in b.leaf_idx:
            n = grads_flat[i].size
            out[i] = vec[off:off + n].reshape(grads_flat[i].shape)
            off += n
    return out
