"""Pluggable fabric models (DESIGN.md §11).

The paper — and this repro through PR 8 — evaluates on the classic
non-blocking BIG SWITCH: contention exists only at the ingress/egress
ports, so an allocation is feasible iff per-port sums fit. Real
datacenter fabrics are leaf-spine trees with oversubscribed uplinks:
inter-leaf traffic also contends on the shared leaf<->spine links, and
which coflow schedules are even feasible changes with the
oversubscription factor.

`FabricModel` lifts that assumption into scenario DATA:

* `BigSwitch()` — the exact current semantics. `bind()` returns None
  and every allocation path takes its pre-refactor branch, so results
  stay BITWISE identical on the numpy plane (the regression guard in
  tests/test_fabric_regression.py holds the line) and the jitted tick
  compiles to the same program.
* `LeafSpine(hosts_per_leaf, oversub, wc_fill)` — ports are grouped
  `hosts_per_leaf` at a time under leaves; an inter-leaf flow crosses
  its source leaf's UPLINK and its destination leaf's DOWNLINK, each
  with capacity (sum of subtended port bandwidth) / `oversub`. At
  `oversub=1.0` (full bisection) the extra links can never bind — an
  uplink's residual is at least the sum of its subtended ports'
  residuals, so the per-port minimum always dominates — which is why
  1:1 reproduces BigSwitch and larger factors express contention the
  big switch cannot.

Both models are FROZEN, HASHABLE dataclasses: `Scenario.topology` is
scenario data (hashed into the result cache key exactly like
`--engine`), and a `SessionPool` pins its topology at construction so
heterogeneous tenant joins never recompile.

The numpy plane consumes a topology through `bind_table`: an
`ExtraLinks` view (per-link capacity vector + per-flow link ids, -1 for
intra-leaf flows) that `greedy_flow_alloc` / `maxmin_waterfill` /
`Saath.schedule` thread through their admission and work-conservation
arithmetic. The jitted plane consumes it through the `TraceBatch`
link-incidence layout (`traces.batch.pack_row`): per-flow link ids plus
a (cid, link)-sorted permutation with searchsorted group bounds — the
same precompute trick as `perm_size` — so per-(coflow, link) flow
counts are one `_segment_sum` inside the tick.

`wc_fill` selects the work-conservation filler on leaf-spine fabrics:
`"greedy"` (default) extends the paper's D4 round walk with per-link
feasibility; `"maxmin"` runs max-min fair water-filling over the
leftover flows instead — the allocation family the in-network papers
assume — and is the path the `kernels/maxmin.py` Pallas kernel
accelerates (`use_pallas`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional

import numpy as np


class ExtraLinks(NamedTuple):
    """The numpy plane's bound view of a topology's non-port links.

    `cap[k]` is the capacity of extra link k (uplinks first, then
    downlinks: k in [0, Lf) is leaf k's uplink, k in [Lf, 2Lf) is leaf
    k-Lf's downlink). `up[f]`/`dn[f]` are flow f's extra-link ids into
    `cap` — both -1 when the flow stays inside one leaf and touches no
    shared link.
    """
    cap: np.ndarray        # (2*Lf,) float64 link capacities, bytes/s
    up: np.ndarray         # (F,) int32 uplink id in [0, Lf), -1 = none
    dn: np.ndarray         # (F,) int32 downlink id in [Lf, 2Lf), -1 = none
    num_uplinks: int       # Lf


@dataclasses.dataclass(frozen=True)
class BigSwitch:
    """The non-blocking fabric of the paper: per-port contention only."""

    def leaf_count(self, num_ports: int) -> int:
        return 0

    def bind(self, table) -> Optional[ExtraLinks]:
        return None


@dataclasses.dataclass(frozen=True)
class LeafSpine:
    """A two-tier leaf-spine fabric with uniform oversubscription.

    Port p lives under leaf `p // hosts_per_leaf`; each leaf owns one
    logical uplink and one logical downlink into the spine (the spine
    itself is non-blocking — ECMP spreads a leaf pair's traffic over
    every spine, so the aggregate leaf<->spine pipe is the binding
    resource). Link capacity is the subtended port bandwidth divided by
    `oversub`; `oversub=1.0` is full bisection.
    """
    hosts_per_leaf: int = 4
    oversub: float = 1.0
    wc_fill: str = "greedy"

    def __post_init__(self):
        if self.hosts_per_leaf < 1:
            raise ValueError("hosts_per_leaf must be >= 1")
        if not self.oversub > 0.0:
            raise ValueError("oversub must be positive")
        if self.wc_fill not in ("greedy", "maxmin"):
            raise ValueError(
                f"wc_fill must be 'greedy' or 'maxmin', "
                f"got {self.wc_fill!r}")

    def leaf_count(self, num_ports: int) -> int:
        return int(math.ceil(num_ports / self.hosts_per_leaf))

    def leaf_of(self, ports: np.ndarray) -> np.ndarray:
        return (np.asarray(ports, np.int32)
                // np.int32(self.hosts_per_leaf)).astype(np.int32)

    def link_caps(self, bw_send: np.ndarray,
                  bw_recv: np.ndarray) -> tuple:
        """Per-leaf (uplink, downlink) capacities from the table's
        per-port bandwidths, as two (Lf,) float64 vectors."""
        P = bw_send.shape[0]
        Lf = self.leaf_count(P)
        leaf = self.leaf_of(np.arange(P, dtype=np.int32))
        cap_up = (np.bincount(leaf, weights=bw_send, minlength=Lf)
                  / self.oversub).astype(np.float64)
        cap_dn = (np.bincount(leaf, weights=bw_recv, minlength=Lf)
                  / self.oversub).astype(np.float64)
        return cap_up, cap_dn

    def flow_links(self, src: np.ndarray, dst: np.ndarray) -> tuple:
        """Per-flow (uplink leaf, downlink leaf) ids, -1 for flows whose
        endpoints share a leaf (they never touch the spine)."""
        up = self.leaf_of(src)
        dn = self.leaf_of(dst)
        inter = up != dn
        m1 = np.int32(-1)
        return (np.where(inter, up, m1).astype(np.int32),
                np.where(inter, dn, m1).astype(np.int32))

    def bind(self, table) -> ExtraLinks:
        """Bind to a `fabric.state.FlowTable`: the ExtraLinks view the
        numpy allocation paths thread through their arithmetic."""
        Lf = self.leaf_count(table.num_ports)
        cap_up, cap_dn = self.link_caps(table.bw_send, table.bw_recv)
        up, dn = self.flow_links(table.src, table.dst)
        dn = np.where(dn >= 0, dn + np.int32(Lf),
                      np.int32(-1)).astype(np.int32)
        return ExtraLinks(
            cap=np.concatenate([cap_up, cap_dn]).astype(np.float64),
            up=up, dn=dn, num_uplinks=Lf)


def normalize_topology(topology) -> object:
    """None -> BigSwitch(); validates anything else is a fabric model."""
    if topology is None:
        return BigSwitch()
    if isinstance(topology, (BigSwitch, LeafSpine)):
        return topology
    raise TypeError(
        f"topology must be BigSwitch, LeafSpine, or None; "
        f"got {topology!r}")


def bind_table(topology, table) -> Optional[ExtraLinks]:
    """The one numpy-plane entry: None (BigSwitch semantics — callers
    take their pre-refactor branch) or the bound ExtraLinks."""
    return normalize_topology(topology).bind(table)


def leaf_links_for(topology, num_ports: int) -> int:
    """How many leaves a slab packed for `topology` must carry (0 keeps
    the link machinery compiled out entirely)."""
    return normalize_topology(topology).leaf_count(num_ports)


def wc_fill_of(topology) -> str:
    """The work-conservation filler a topology asks for ("greedy" for
    BigSwitch/None — the paper's D4 walk)."""
    return getattr(normalize_topology(topology), "wc_fill", "greedy")


__all__ = ["BigSwitch", "LeafSpine", "ExtraLinks", "normalize_topology",
           "bind_table", "leaf_links_for", "wc_fill_of"]
