"""Evaluation metrics used by the paper's figures.

All CCT/FCT durations are measured from the coflow's arrival (the paper's
CCT definition: first flow arrives -> last flow completes).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.fabric.state import FlowTable

MB = 1024.0 * 1024.0


def nan_row_mean(x: np.ndarray) -> np.ndarray:
    """Row-wise mean over finite entries of a (B, N) array; NaN
    (silently — no all-NaN RuntimeWarning) for rows with none.

    THE one definition of "nothing completed" shared by
    `repro.api.Result.avg_cct`, `SimResult.avg_cct` and
    `EngineResult.avg_cct` — the NaN/padding contract lives in the
    `repro.api` normalizer and every plane funnels through here.
    """
    x = np.asarray(x, float)
    fin = np.isfinite(x)
    cnt = fin.sum(axis=1)
    tot = np.where(fin, x, 0.0).sum(axis=1)
    return np.where(cnt > 0, tot / np.maximum(cnt, 1), np.nan)


def percentile_speedup(cct_base: np.ndarray, cct_new: np.ndarray,
                       qs=(10, 50, 90)) -> dict:
    """Per-coflow speedup = CCT_base / CCT_new (Fig. 9's metric).

    When no coflow completed in both runs (empty `ok` mask — overload
    sweeps hit this on hard points) every statistic is NaN with n=0,
    mirroring the `nan_row_mean` "silently NaN" contract above.
    """
    cct_base = np.asarray(cct_base, float)
    cct_new = np.asarray(cct_new, float)
    ok = np.isfinite(cct_base) & np.isfinite(cct_new) & (cct_new > 0)
    if not ok.any():
        out = {f"p{q}": float("nan") for q in qs}
        out["mean"] = float("nan")
        out["overall"] = float("nan")
        out["n"] = 0
        return out
    s = cct_base[ok] / cct_new[ok]
    out = {f"p{q}": float(np.percentile(s, q)) for q in qs}
    out["mean"] = float(s.mean())
    out["overall"] = float(np.mean(cct_base[ok]) / np.mean(cct_new[ok]))
    out["n"] = int(ok.sum())
    return out


def fct_normalized_std(table: FlowTable) -> dict:
    """Fig. 2(c)/13: per-coflow std of flow completion *durations*
    normalized by their mean, split by equal/unequal flow lengths.
    Single-flow coflows are excluded (as in the paper)."""
    eq, uneq = [], []
    for c in range(table.num_coflows):
        lo, hi = table.flow_lo[c], table.flow_hi[c]
        if hi - lo < 2 or not table.finished[c]:
            continue
        d = table.fct[lo:hi] - table.arrival[c]
        v = float(d.std() / max(d.mean(), 1e-12))
        sizes = table.size[lo:hi]
        (eq if sizes.std() <= 1e-9 * max(sizes.mean(), 1.0) else
         uneq).append(v)
    return {"equal": np.asarray(eq), "unequal": np.asarray(uneq)}


def width_size_bins(table: FlowTable) -> np.ndarray:
    """Table 1 bins: 1 = small/thin, 2 = small/wide, 3 = large/thin,
    4 = large/wide. width<=10, size<=100MB are 'thin'/'small'."""
    total = np.zeros(table.num_coflows)
    np.add.at(total, table.cid, table.size)
    thin = table.width <= 10
    small = total <= 100 * MB
    return np.where(small & thin, 1,
                    np.where(small & ~thin, 2, np.where(thin, 3, 4)))


def bin_speedups(table_base: FlowTable, table_new: FlowTable,
                 qs=(50,)) -> dict:
    """Fig. 11/12: median speedup per Table-1 bin + bin fractions."""
    bins = width_size_bins(table_base)
    out = {}
    for b in (1, 2, 3, 4):
        sel = bins == b
        if sel.sum() == 0:
            out[f"bin{b}"] = {"frac": 0.0}
            continue
        d = percentile_speedup(table_base.cct[sel], table_new.cct[sel], qs)
        d["frac"] = float(sel.mean())
        out[f"bin{b}"] = d
    return out


@dataclasses.dataclass
class RunSummary:
    policy: str
    avg_cct: float
    p50_cct: float
    p90_cct: float
    makespan: float
    steps: int
    sched_seconds: float

    @staticmethod
    def from_result(policy: str, res) -> "RunSummary":
        # route through nan_row_mean and pre-filter the percentiles so
        # an all-NaN CCT column (nothing completed) summarizes to NaN
        # silently instead of tripping numpy's empty-slice warnings
        cct = np.asarray(res.table.cct, float)
        fin = cct[np.isfinite(cct)]
        return RunSummary(
            policy=policy,
            avg_cct=float(nan_row_mean(cct[None, :])[0]),
            p50_cct=float(np.percentile(fin, 50)) if fin.size else float("nan"),
            p90_cct=float(np.percentile(fin, 90)) if fin.size else float("nan"),
            makespan=res.makespan,
            steps=res.steps,
            sched_seconds=res.sched_seconds,
        )
