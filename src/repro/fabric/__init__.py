from repro.fabric.engine import SimResult, Simulator
from repro.fabric.state import FlowTable

# fabric.jax_engine (the batched XLA fleet engine) is imported lazily by
# its users — importing it here would pull jax into every fabric import.

__all__ = ["FlowTable", "Simulator", "SimResult"]
