from repro.fabric.engine import SimResult, Simulator, simulate
from repro.fabric.state import FlowTable

__all__ = ["FlowTable", "Simulator", "SimResult", "simulate"]
