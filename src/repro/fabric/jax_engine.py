"""Batched fixed-step XLA fleet simulator (the tentpole of PR 1).

Where `fabric.engine.Simulator` replays ONE trace through a Python
event loop, this module replays a whole fleet: `core.jax_coordinator.
tick_core` is wrapped in a `jax.lax.scan` over δ-grid ticks and
`jax.vmap`-ed over a leading trace axis, so N traces (and, via stacked
`EngineParams`, M parameter settings) run as one XLA computation.

Semantics (DESIGN.md §3): a fixed-step simulation on the δ grid — the
schedule takes effect only at δ ticks, exactly the paper's pipelined
coordinator. Between the discrete events the event-driven reference
jumps across (arrival, flow completion, queue-threshold crossing,
starvation deadline) the Fig. 7 schedule is a deterministic function
of unchanged state, so each scan step safely jumps to the next
grid-quantized event; flow completion instants are still recorded
exactly (rates are constant inside an interval, the completion time
is algebraic). A flow finishing mid-interval leaves its bandwidth
idle until the next tick, matching the reference's δ-sensitivity
(Fig. 14(c)).

Full fidelity vs the numpy `Saath` reference (shared with
`policies.saath_jax`): work conservation runs at FLOW granularity (the
reference's greedy_flow_alloc order) and the §4.3 dynamics re-queue is
modelled exactly (per-coflow finished-flow median via the
host-precomputed size-sorted segment layout, `TraceBatch.perm_size`).
Equivalence is property-tested to 1% in tests/test_jax_engine.py on the
full reference configuration.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import jax_coordinator as jc
from repro.core.params import SchedulerParams
from repro.traces.batch import TraceBatch, pack

# completion slop: a flow whose remaining bytes are within REL_EPS of
# what this tick delivers completes now — f32 cannot resolve finer
# (accumulated over thousands of ticks), and without it a completion can
# slip a tick and desynchronize the replay from the float64 reference.
REL_EPS = 1e-5


class EngineParams(NamedTuple):
    """Traced scheduler knobs: a DynCoordParams plus the δ grid step.

    Every leaf may carry a leading sweep axis (see `simulate_sweep`) —
    including the dp.wc / dp.requeue mechanism switches, so those
    ablation grids vmap instead of recompiling. dp.lcof / dp.per_flow
    are traced too but need the ablation event-horizon structure
    compiled in (`_tick`'s with_ablations), which `simulate_batch`
    derives per call; `simulate_sweep` always runs full-SAATH queues.
    """
    dp: jc.DynCoordParams
    delta: jax.Array      # () f32 seconds

    @staticmethod
    def from_scheduler(p: SchedulerParams, *,
                       work_conservation: "bool | None" = None,
                       dynamics_requeue: "bool | None" = None,
                       lcof: bool = True,
                       per_flow_threshold: bool = True,
                       clairvoyant: "bool | None" = None) -> "EngineParams":
        cp = jc.CoordParams.from_params(p)
        cp = cp._replace(
            work_conservation=(cp.work_conservation
                               if work_conservation is None
                               else work_conservation),
            dynamics_requeue=(cp.dynamics_requeue
                              if dynamics_requeue is None
                              else dynamics_requeue),
            lcof=lcof, per_flow_threshold=per_flow_threshold,
            clairvoyant=(cp.clairvoyant if clairvoyant is None
                         else clairvoyant))
        return EngineParams(jc.DynCoordParams.from_cp(cp),
                            jnp.float32(p.delta))


class EngineState(NamedTuple):
    """Per-trace scan carry (all leaves get a leading batch axis).

    The four trailing fields exist only in SESSION states (online
    `repro.api` slabs; `None` — compiled out — for offline replays):
    they carry the *pending event horizon* of a schedule interval that
    an advance's `n_end` cap truncated, so the next advance resumes the
    STORED rates from the STORED anchor instead of re-evaluating the
    boundary tick — the same discipline the numpy session oracle uses,
    which is what makes incremental replay bitwise-equal to the offline
    scan (re-evaluation is a fixed point only until §4.3 dynamics drift
    moves a queue). Integration is anchor-based: every capped piece of
    the interval recomputes `sent`/`fct` from (pend_tick, pend_sent),
    so splitting an interval at arbitrary horizons cannot change a
    single f32 rounding versus the offline one-shot integration.
    """
    coord: jc.CoordState
    sent: jax.Array      # (F,) f32 bytes
    done: jax.Array      # (F,) bool
    fct: jax.Array       # (F,) f32 absolute completion time (0 until done)
    finished: jax.Array  # (C,) bool
    cct: jax.Array       # (C,) f32 completion - arrival (nan until done)
    t0: jax.Array        # () f32 grid origin (0; kept for generality)
    tick: jax.Array      # () i32 next tick index
    rate: Optional[jax.Array] = None       # (F,) f32 pending rates
    pend_sent: Optional[jax.Array] = None  # (F,) f32 sent at the anchor
    pend_tick: Optional[jax.Array] = None  # () f32 anchor tick index
    pend_next: Optional[jax.Array] = None  # () f32 horizon tick (0=none)


class EngineResult(NamedTuple):
    cct: np.ndarray       # (B, C) nan for unfinished/padded coflows
    fct: np.ndarray       # (B, F) nan for unfinished/padded flows
    sent: np.ndarray      # (B, F) bytes
    finished: np.ndarray  # (B, C) bool (padded coflows report True)
    ticks: int            # max δ-grid ticks simulated across the batch
    events: int           # event steps (scan iterations) executed

    @property
    def avg_cct(self) -> np.ndarray:
        """(B,) mean CCT per trace over its real coflows.

        A row with no finished real coflows (e.g. an all-padding session
        slab row) reports NaN — the "nothing completed" value of the
        `repro.api.Result` normalizer — instead of tripping numpy's
        all-NaN RuntimeWarning.
        """
        from repro.fabric.metrics import nan_row_mean

        return nan_row_mean(self.cct)


# ---- single-trace tick ---------------------------------------------------

def _init_state(tb: TraceBatch, ep: EngineParams) -> EngineState:
    """Single-trace state init (arrays here are unbatched rows).

    The δ grid is pinned at t=0 for every replay — the same grid the
    online sessions use — so an incremental session replay and the
    offline scan see bit-identical `now` values at every tick (an
    arrival-quantized origin would shift the f32 rounding of
    `t0 + tick*δ`). Idle ticks before the first arrival cost nothing:
    the arrival event horizon jumps straight across them.
    """
    F = tb.cid.shape[0]
    C = tb.arrival.shape[0]
    t0 = jnp.float32(0.0)
    return EngineState(
        coord=jc.CoordState(jnp.full((C,), -1, jnp.int32),
                            jnp.full((C,), jnp.inf, jnp.float32),
                            jnp.zeros((C,), bool)),
        sent=jnp.zeros((F,), jnp.float32),
        done=~tb.flow_valid,
        fct=jnp.zeros((F,), jnp.float32),
        finished=~tb.coflow_valid,
        cct=jnp.full((C,), jnp.nan, jnp.float32),
        t0=t0, tick=jnp.int32(0))


# max ticks one event-jump may skip (idle gaps between arrivals are
# jumped exactly; this only caps pathological/finished lanes)
MAX_JUMP_TICKS = 1024.0
# an idle lane (no live flows) jumps straight to its next arrival in
# one step; this only caps that jump inside the f32-exact tick range
IDLE_JUMP_TICKS = float(1 << 22)
# with the §4.3 dynamics re-queue active the cap MIRRORS
# fabric.engine.Simulator's default max_jump of 200δ — semantic, not
# just a guard: the estimated remaining length drifts continuously (no
# discrete event), so both replay loops must re-invoke the coordinator
# at the same bounded cadence or their queue moves (and hence
# trajectories) fork. Between discrete events a re-evaluation on
# unchanged state is a fixed point, so matching the reference's cadence
# costs steps, never correctness.
DYNAMICS_JUMP_TICKS = 200.0


def _segment_sum(data: jax.Array, lo: jax.Array, hi: jax.Array) -> jax.Array:
    """Sum `data` (F,) over contiguous index ranges [lo, hi) (any shape
    of lo/hi) via one cumsum + two boundary gathers."""
    s = jnp.concatenate([jnp.zeros_like(data[:1]), jnp.cumsum(data)])
    return s[hi] - s[lo]


def _segment_max(data: jax.Array, tb: TraceBatch) -> jax.Array:
    """Max of non-negative `data` (F,) per contiguous coflow segment ->
    (C,). Segmented cummax via associative_scan; the value at the last
    flow of each segment is the segment max (0 for padded coflows)."""
    def comb(a, b):
        va, ia = a
        vb, ib = b
        return jnp.where(ia == ib, jnp.maximum(va, vb), vb), ib

    v, _ = jax.lax.associative_scan(comb, (data, tb.cid))
    return jnp.where(tb.coflow_valid, v[tb.flow_hi - 1], 0.0)


def _views(state: EngineState, tb: TraceBatch, now: jax.Array,
           eps_t: jax.Array, *, per_flow_wc: bool, with_dynamics: bool,
           with_ablations: bool, with_sampling: bool = False,
           active_gate: Optional[jax.Array] = None):
    """One tick's coordinator view of the slab: activation, per-(coflow,
    port) live counts, Eq. 1 m_c, and (when compiled in) the §4.3
    finished-flow-median inputs — shared by the scanned `_tick` and the
    single-shot session `plan_tick`.

    `active_gate` (sessions) is `tick < n_end`: a lane at or past its
    horizon has its whole step DISCARDED anyway (`_tick`'s no-op
    select), so deactivating it up front is free — and it zeroes the
    admission/work-conservation while_loop trip counts, making the
    trailing no-op ticks of a chunk cost almost nothing. That surplus
    is what lets one pooled dispatch amortize its fixed cost across
    many session lanes (DESIGN.md §8).
    """
    # activation (reference: arrival <= now + eps, eps << δ)
    active = tb.coflow_valid & ~state.finished & (tb.arrival <= now + eps_t)
    if active_gate is not None:
        active = active & active_gate
    live = active[tb.cid] & ~state.done & tb.flow_valid
    livef = live.astype(jnp.float32)

    # coordinator view of the fabric: m_c (Eq. 1) over ALL flows,
    # live-flow counts per (coflow, port) — scatter-free: 1-D cumsums
    # over the host-precomputed (cid, port)-sorted flow orders
    m = _segment_max(state.sent * tb.flow_valid, tb)
    cnt_s = _segment_sum(livef[tb.perm_src], tb.lo_src, tb.hi_src)
    cnt_r = _segment_sum(livef[tb.perm_dst], tb.lo_dst, tb.hi_dst)
    total = _segment_sum(state.sent * tb.flow_valid, tb.flow_lo,
                         tb.flow_hi) if with_ablations else None

    # leaf-spine fabric (DESIGN.md §11): per-(coflow, link) live counts
    # via the same host-precomputed sorted segment layout as the ports,
    # compiled out entirely (None) on a big-switch slab (Lf == 0)
    cnt_x = bw_x = link_up = link_dn = None
    Lf = tb.bw_up.shape[-1]
    if Lf:
        cnt_up = _segment_sum(livef[tb.perm_up], tb.lo_up, tb.hi_up)
        cnt_dn = _segment_sum(livef[tb.perm_dn], tb.lo_dn, tb.hi_dn)
        cnt_x = jnp.concatenate([cnt_up, cnt_dn], axis=1)  # (C, 2Lf)
        bw_x = jnp.concatenate([tb.bw_up, tb.bw_dn])       # (2Lf,)
        if per_flow_wc:
            link_up, link_dn = tb.link_up, tb.link_dn

    mixed = m_dyn = None
    if with_dynamics:
        # §4.3 remaining-length estimate: the EXACT median of finished-
        # flow sizes per coflow, as order statistics over the host-
        # precomputed (cid, size)-sorted segment layout (tb.perm_size) —
        # one cumsum of the done mask gives each done flow's rank inside
        # its segment, the two middle ranks select the median, no
        # per-tick sort or scatter.
        done_real = (state.done & tb.flow_valid).astype(jnp.float32)
        d_s = done_real[tb.perm_size]
        size_s = tb.size[tb.perm_size]
        cid_s = tb.cid[tb.perm_size]
        S = jnp.concatenate([jnp.zeros((1,), jnp.float32),
                             jnp.cumsum(d_s)])
        n_done = (S[tb.flow_hi] - S[tb.flow_lo]).astype(jnp.int32)  # (C,)
        drank = (S[:-1] - S[tb.flow_lo][cid_s]).astype(jnp.int32)   # (F,)
        k1 = jnp.maximum(n_done - 1, 0) // 2
        k2 = n_done // 2
        hit1 = (d_s > 0.5) & (drank == k1[cid_s])
        hit2 = (d_s > 0.5) & (drank == k2[cid_s])

        # each hit mask selects AT MOST ONE flow per segment, so the
        # pick is a segmented MAX (exact for any padding/layout — a
        # cumsum-difference would round by ulp(prefix), making the
        # median depend on what else shares the slab row, which breaks
        # the session-vs-offline bitwise contract). perm_size permutes
        # flows only WITHIN coflow segments, so [flow_lo, flow_hi)
        # spans are valid in this order too.
        def pick(data):
            def comb(a, b):
                va, ia = a
                vb, ib = b
                return jnp.where(ia == ib, jnp.maximum(va, vb), vb), ib
            v, _ = jax.lax.associative_scan(comb, (data, cid_s))
            return jnp.where(tb.coflow_valid, v[tb.flow_hi - 1], 0.0)

        v1 = pick(size_s * hit1)
        v2 = pick(size_s * hit2)
        f_e = 0.5 * (v1 + v2)        # median (0 when nothing finished)
        rem_dyn = jnp.maximum(f_e[tb.cid] - state.sent, 0.0) * livef
        m_dyn = _segment_max(rem_dyn, tb)
        n_live_c = _segment_sum(livef, tb.flow_lo, tb.flow_hi)
        mixed = active & (n_done > 0) & (n_live_c > 0.5)

    s_mixed = s_m = None
    if with_sampling:
        # non-clairvoyant §4.3 inputs: the size estimate is the MEAN of
        # finished-PILOT sizes (a finished flow's size equals its
        # delivered bytes, so the estimate only ever reads observable
        # quantities); coflows whose pilots are all in flight are not
        # re-queue candidates and keep the bytes-sent Eq. 1 placement.
        if tb.pilot is None:
            raise ValueError("with_sampling needs a TraceBatch packed "
                             "with sampling=True (pilot layout missing)")
        pdone = (tb.pilot & tb.flow_valid & state.done).astype(jnp.float32)
        n_p = _segment_sum(pdone, tb.flow_lo, tb.flow_hi)       # (C,)
        p_sum = _segment_sum(pdone * tb.size, tb.flow_lo, tb.flow_hi)
        f_hat = p_sum / jnp.maximum(n_p, 1.0)
        rem_s = jnp.maximum(f_hat[tb.cid] - state.sent, 0.0) * livef
        s_m = _segment_max(rem_s, tb)
        n_live_s = _segment_sum(livef, tb.flow_lo, tb.flow_hi)
        s_mixed = active & (n_p > 0.5) & (n_live_s > 0.5)

    batch = jc.CoflowBatch(active=active, arrival=tb.arrival_rank, m=m,
                           width=tb.width, cnt_s=cnt_s, cnt_r=cnt_r,
                           bw_s=tb.bw_send, bw_r=tb.bw_recv,
                           total=total, mixed=mixed, m_dyn=m_dyn,
                           cnt_x=cnt_x, bw_x=bw_x,
                           s_mixed=s_mixed, s_m=s_m)
    flows = jc.FlowView(cid=tb.cid, src=tb.src, dst=tb.dst, live=live,
                        up=link_up, dn=link_dn) \
        if per_flow_wc else None
    return batch, flows, active, live, livef


def _tick(state: EngineState, tb: TraceBatch, ep: EngineParams,
          kernel: Optional[str], *, per_flow_wc: bool = True,
          with_dynamics: bool = True,
          with_ablations: bool = False,
          wc_maxmin: bool = False,
          with_sampling: bool = False,
          n_end: Optional[jax.Array] = None) -> EngineState:
    """Advance one *event step*: schedule at the current δ tick, find the
    next instant the schedule could change (arrival, flow completion,
    queue-threshold crossing, starvation deadline — the reference
    simulator's event list), quantize it UP to the δ grid, and integrate
    the constant rates across the jumped interval. Between those events
    the Fig. 7 schedule is a fixed point of unchanged state, so skipping
    the intermediate ticks reproduces the per-tick trajectory exactly.

    The three keyword flags are STATIC structure switches (resolved
    host-side, not traced): `per_flow_wc` selects the exact per-flow
    work-conservation fill vs the cheaper coflow-granular one,
    `with_dynamics` builds the §4.3 finished-flow-median machinery, and
    `with_ablations` builds the total-bytes queue inputs/events for the
    Fig. 10 per_flow_threshold=0 path. Turning one off removes its cost
    from the compiled step entirely.

    `n_end` (traced, sessions only) caps the replay at tick index
    `n_end`: the jump never passes it, and once `tick >= n_end` the step
    is an exact no-op (the whole new state is discarded), so an online
    `SaathSession` can advance to a wall-clock horizon, accept new
    arrivals, and re-enter the scan without ever having scheduled a tick
    that couldn't yet see them. When the cap truncates a schedule
    interval, the pending event horizon (rates + anchor) is carried in
    the state, and the next step RESUMES the stored schedule — stopping
    early only at a since-submitted arrival's tick, exactly like the
    numpy session oracle — instead of re-evaluating the boundary tick,
    so incremental replay is bitwise the offline scan. `None` (offline
    replay) compiles both the cap and the pending machinery out.
    """
    session = n_end is not None
    delta = ep.delta
    tickf = state.tick.astype(jnp.float32)
    now = state.t0 + tickf * delta
    eps_t = 1e-3 * delta
    can = tickf < n_end if session else None
    batch, flows, active, live, livef = _views(
        state, tb, now, eps_t, per_flow_wc=per_flow_wc,
        with_dynamics=with_dynamics, with_ablations=with_ablations,
        with_sampling=with_sampling, active_gate=can)
    total = batch.total
    coord, out = jc.tick_core(state.coord, batch, now, ep.dp,
                              kernel=kernel, flows=flows,
                              wc_fill="maxmin" if wc_maxmin else "greedy")
    # per-flow rates: MADD equal rate for admitted coflows + the work-
    # conservation fill (flow-granular when per_flow_wc, else the
    # coflow-granular equal rate; both already gated by dp.wc)
    r_f = out["rate"][tb.cid] * livef
    if per_flow_wc:
        r_f = r_f + out["wc_flow"]
    else:
        r_f = r_f + out["wc_rate"][tb.cid] * livef
    served = live & (r_f > 0)
    rem = tb.size - state.sent

    # ---- event horizon (mirrors Simulator._next_event + Saath
    # progress_events, vectorized) -------------------------------------
    inf = jnp.float32(jnp.inf)
    t_fin = jnp.min(jnp.where(served, now + rem / jnp.maximum(r_f, 1e-30),
                              inf))
    # queue-threshold crossing, per the active threshold rule: flow f of
    # coflow c crosses when sent_f reaches Q_q^hi / N_c (Eq. 1), or —
    # for the per_flow=0 Aalo-queue ablation — when the coflow's TOTAL
    # bytes reach Q_q^hi (q = the post-assignment queue)
    q = jnp.maximum(coord.queue, 0)
    thq = ep.dp.thresholds[q]
    lim = (thq / jnp.maximum(tb.width, 1).astype(jnp.float32))[tb.cid]
    dt_th = jnp.where(served & jnp.isfinite(lim) & (lim > state.sent),
                      (lim - state.sent) / jnp.maximum(r_f, 1e-30), inf)
    t_th = now + jnp.min(dt_th)
    if with_ablations:
        R_c = _segment_sum(r_f, tb.flow_lo, tb.flow_hi)
        dt_tot = jnp.where(active & (R_c > 0) & jnp.isfinite(thq)
                           & (thq > total),
                           (thq - total) / jnp.maximum(R_c, 1e-30), inf)
        t_th = now + jnp.where(ep.dp.per_flow > 0, jnp.min(dt_th),
                               jnp.min(dt_tot))
    t_dl = jnp.min(jnp.where(active & (coord.deadline > now + eps_t),
                             coord.deadline, inf))
    t_arr = jnp.min(jnp.where(tb.coflow_valid & (tb.arrival > now + eps_t),
                              tb.arrival, inf))
    t_ev = jnp.minimum(jnp.minimum(t_fin, t_th), jnp.minimum(t_dl, t_arr))
    # the pilot-sampling estimate drifts continuously too (rem = f_hat -
    # sent), so learned mode needs the same bounded re-evaluation
    # cadence as the §4.3 exact-median machinery
    jump = DYNAMICS_JUMP_TICKS if (with_dynamics or with_sampling) \
        else MAX_JUMP_TICKS
    n_ev = jnp.where(jnp.isfinite(t_ev),
                     jnp.ceil((t_ev - state.t0) / delta - 1e-4),
                     tickf + jump)
    # the jump cap bounds RE-EVALUATION cadence on live state (§4.3
    # drift; pathological-lane guard). With nothing live there is
    # nothing to re-evaluate — an idle gap (e.g. the run-up from the
    # t=0 grid origin to a late first arrival) is jumped in ONE step,
    # bounded only by the f32-exact tick range.
    idle_jump = jnp.float32(IDLE_JUMP_TICKS)
    hi = tickf + jnp.where(jnp.any(live), jnp.float32(jump), idle_jump)
    n_un = jnp.clip(n_ev, tickf + 1.0, hi)  # uncapped horizon

    if not session:
        n_next = n_un
        r_use, anchor_t, anchor_tick = r_f, now, tickf
        anchor_sent, coord_new = state.sent, coord
    else:
        cap = jnp.maximum(n_end, tickf + 1.0)
        # pending-horizon resume: if the previous advance capped a
        # schedule interval, keep integrating the STORED rates from the
        # STORED anchor to the stored horizon — or to the δ-quantized
        # tick of an arrival submitted since the anchor (a discrete
        # event the offline loop would have stopped at) — instead of
        # re-evaluating the boundary tick.
        pend_t = state.t0 + state.pend_tick * delta
        late = jnp.min(jnp.where(
            tb.coflow_valid & (tb.arrival > pend_t + eps_t),
            tb.arrival, inf))
        late_n = jnp.maximum(jnp.ceil((late - state.t0) / delta - 1e-4),
                             state.pend_tick + 1.0)
        stop = jnp.minimum(state.pend_next, late_n)
        resuming = (state.pend_next > tickf) & (stop > tickf)
        n_next = jnp.where(resuming, jnp.minimum(stop, cap),
                           jnp.minimum(n_un, cap))
        r_use = jnp.where(resuming, state.rate, r_f)
        anchor_t = jnp.where(resuming, pend_t, now)
        anchor_tick = jnp.where(resuming, state.pend_tick, tickf)
        anchor_sent = jnp.where(resuming, state.pend_sent, state.sent)
        # a resumed interval does NOT re-invoke the coordinator: queue
        # moves / deadline refreshes happen only at evaluation instants,
        # exactly as in the offline loop
        coord_new = jax.tree_util.tree_map(
            lambda a, b: jnp.where(resuming, a, b), state.coord, coord)
        served = live & (r_use > 0)

    # ---- integrate the constant rates across the interval, ANCHORED
    # at the evaluation instant: sent/fct are recomputed from the
    # anchor, so an interval split by n_end caps integrates to exactly
    # the same f32 values as the offline single-shot step -------------
    dt = (n_next - anchor_tick) * delta
    rem_a = tb.size - anchor_sent
    adv = r_use * dt
    fin = served & (adv >= rem_a - REL_EPS * tb.size)
    fct = jnp.where(fin, anchor_t + rem_a / jnp.maximum(r_use, 1e-30),
                    state.fct)
    sent = jnp.where(fin, tb.size,
                     jnp.minimum(tb.size, anchor_sent + adv))
    done = state.done | fin

    # coflow completions: CCT = last FCT - arrival (fct is 0 until a
    # flow completes, so the masked row-max sees only completed flows)
    undone = _segment_sum((tb.flow_valid & ~done).astype(jnp.float32),
                          tb.flow_lo, tb.flow_hi)
    newly = active & (undone < 0.5)
    last_fct = _segment_max(fct * tb.flow_valid, tb)
    cct = jnp.where(newly, last_fct - tb.arrival, state.cct)

    if not session:
        return EngineState(coord=coord, sent=sent, done=done, fct=fct,
                           finished=state.finished | newly, cct=cct,
                           t0=state.t0,
                           tick=state.tick + (n_next - tickf)
                           .astype(jnp.int32))
    # pending bookkeeping: cleared once the interval's horizon (or the
    # arrival stop) is reached; (re)armed when this step's interval was
    # truncated by the n_end cap. The anchor leaves (rate/pend_sent/
    # pend_tick) always reflect the interval just integrated, so a
    # re-armed pending resumes from the original evaluation instant.
    hit = n_next >= jnp.where(resuming, stop, n_un)
    pend_next = jnp.where(hit, jnp.float32(0.0),
                          jnp.where(resuming, state.pend_next, n_un))
    new = EngineState(coord=coord_new, sent=sent, done=done, fct=fct,
                      finished=state.finished | newly, cct=cct,
                      t0=state.t0, tick=state.tick + (n_next - tickf)
                      .astype(jnp.int32),
                      rate=r_use, pend_sent=anchor_sent,
                      pend_tick=anchor_tick, pend_next=pend_next)
    # at/past the horizon the step must be a PURE no-op: the schedule at
    # tick n_end is evaluated on the NEXT advance, when every arrival
    # submitted at <= n_end*δ is already in the slab — evaluating it now
    # would bake deadlines/queues that ignore those arrivals. (`can`
    # also pre-gated activation above, so this discarded step computed
    # with zero admission/WC loop trips.)
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(can, a, b), new, state)


# ---- batched chunk runner ------------------------------------------------

def _norm_features(features: tuple) -> tuple:
    """Pad a legacy short features tuple to the full 5-slot form
    `(per_flow_wc, with_dynamics, with_ablations, wc_maxmin,
    with_sampling)` — later slots default off, so pre-existing 4-tuple
    (and pool-padded 3-tuple) callers keep their exact structure."""
    f = tuple(features)
    if not 1 <= len(f) <= 5:
        raise ValueError(f"features tuple of length {len(f)}")
    return f + (False,) * (5 - len(f))


@functools.partial(jax.jit, static_argnames=(
    "chunk", "kernel", "sweep", "features"))
def _run_chunk(state: EngineState, tb: TraceBatch, ep: EngineParams,
               *, chunk: int, kernel: Optional[str], sweep: bool,
               features: tuple) -> EngineState:
    """Scan `chunk` ticks for every trace in the batch (one executable,
    reused across chunks so the host completion loop never recompiles).
    sweep=True maps the EngineParams' leading axis alongside the traces.
    `features` = (per_flow_wc, with_dynamics, with_ablations,
    wc_maxmin, with_sampling), the static structure switches threaded to
    `_tick`. Offline replays
    only: sessions go through `_run_session_block`, whose device-side
    while_loop carries the per-row horizon caps.
    """
    (per_flow_wc, with_dynamics, with_ablations, wc_maxmin,
     with_sampling) = _norm_features(features)
    ep_ax = 0 if sweep else None

    def scan_ticks(s, tb_row, ep_row):
        def body(c, _):
            return _tick(c, tb_row, ep_row, kernel,
                         per_flow_wc=per_flow_wc,
                         with_dynamics=with_dynamics,
                         with_ablations=with_ablations,
                         wc_maxmin=wc_maxmin,
                         with_sampling=with_sampling), None
        s, _ = jax.lax.scan(body, s, None, length=chunk)
        return s

    return jax.vmap(scan_ticks, in_axes=(0, 0, ep_ax))(state, tb, ep)


@functools.partial(jax.jit, static_argnames=("sweep",))
def _init_batch(tb: TraceBatch, ep: EngineParams, *,
                sweep: bool) -> EngineState:
    return jax.vmap(_init_state, in_axes=(0, 0 if sweep else None))(tb, ep)


def default_max_ticks(tb: TraceBatch, delta: float, slack: float = 4.0,
                      ) -> int:
    """Sound-ish horizon bound: at every tick at least the head-of-line
    coflow progresses at its bottleneck rate, so the makespan is at most
    last_arrival + sum of per-coflow bottleneck times (x slack for
    deadline/WC interleavings and idle arrival gaps)."""
    bw = np.where(tb.bw_send > 0, tb.bw_send, np.inf).min()
    per_port = np.zeros((tb.num_traces, 2, tb.num_ports))
    np.add.at(per_port, (np.arange(tb.num_traces)[:, None], 0, tb.src),
              tb.size * tb.flow_valid)
    np.add.at(per_port, (np.arange(tb.num_traces)[:, None], 1, tb.dst),
              tb.size * tb.flow_valid)
    serial = per_port.max(axis=(1, 2)) / bw  # per-trace, coarse
    Lf = tb.bw_up.shape[-1]
    if Lf:
        # oversubscribed uplinks/downlinks can be the bottleneck: fold
        # in each link's bytes over its capacity (sentinel Lf = no link)
        per_link = np.zeros((tb.num_traces, 2, Lf + 1))
        rows = np.arange(tb.num_traces)[:, None]
        np.add.at(per_link, (rows, 0, tb.link_up), tb.size * tb.flow_valid)
        np.add.at(per_link, (rows, 1, tb.link_dn), tb.size * tb.flow_valid)
        cap = np.stack([tb.bw_up, tb.bw_dn], axis=1)  # (B, 2, Lf)
        t_link = np.where(cap > 0, per_link[:, :, :Lf] / np.maximum(
            cap, 1e-30), 0.0).max(axis=(1, 2))
        serial = np.maximum(serial, t_link)
    last = np.where(tb.coflow_valid, tb.arrival, 0.0).max(axis=1)
    # bottleneck-sum bound per trace: sum of each coflow's own bottleneck
    tot = np.einsum("bf->b", tb.size * tb.flow_valid) / bw
    horizon = float((last + slack * np.maximum(serial, tot)).max())
    return max(int(np.ceil(horizon / delta)) + 2, 8)


def resolve_kernel(kernel: Optional[str],
                   use_pallas: bool) -> Optional[str]:
    """`use_pallas=True` opts the tick's inner ops (LCoF contention, the
    max-min water-filling fill) into the Pallas kernels: the compiled
    kernels on TPU, `interpret` mode elsewhere (the kernel BODY executed
    on CPU — slow, parity-testing only). An explicit `kernel` force
    always wins; default (False) keeps backend auto-dispatch."""
    if kernel is not None or not use_pallas:
        return kernel
    from repro.kernels.ops import _on_tpu

    return "pallas" if _on_tpu() else "interpret"


def simulate_batch(traces: "Sequence | TraceBatch",
                   params: Optional[SchedulerParams] = None, *,
                   max_ticks: Optional[int] = None, chunk: int = 128,
                   kernel: Optional[str] = None,
                   work_conservation: "bool | None" = None,
                   dynamics_requeue: "bool | None" = None,
                   lcof: bool = True,
                   per_flow_threshold: bool = True,
                   clairvoyant: "bool | None" = None,
                   fidelity: str = "flow",
                   topology=None,
                   use_pallas: bool = False) -> EngineResult:
    """Replay a fleet of traces under one parameter setting.

    Internal engine entry point: the public front door is
    `repro.api.run(Scenario(..., engine="jax"))`, which owns result
    normalization and the engine-equivalence contract. Only
    `repro.api` and the engine's own tests call this directly.

    The mechanism switches default to the SchedulerParams fields
    (work_conservation / dynamics_requeue) or full SAATH (lcof /
    per_flow_threshold); pass explicit values for Fig. 10 ablations.
    `fidelity` picks the work-conservation granularity: "flow" (default)
    is the paper-exact per-flow greedy fill; "coflow" hands leftover
    bandwidth to a missed coflow as ONE equal rate — the faithful
    mapping for collective coflows (a partial issue is meaningless) and
    the throughput mode for large parameter sweeps (~3x cheaper steps).
    Runs jitted `chunk`-tick scans until every coflow of every trace
    has finished (or `max_ticks` is exhausted, which raises — mirroring
    the reference simulator's max_steps guard).
    """
    params = params or SchedulerParams()
    kernel = resolve_kernel(kernel, use_pallas)
    features = features_for(
        params, fidelity=fidelity, work_conservation=work_conservation,
        dynamics_requeue=dynamics_requeue, lcof=lcof,
        per_flow_threshold=per_flow_threshold, topology=topology,
        clairvoyant=clairvoyant)
    with_sampling = features[4]
    tb = traces if isinstance(traces, TraceBatch) else \
        pack(traces, port_bw=params.port_bw, topology=topology,
             sampling=with_sampling, pilot_frac=params.pilot_frac)
    if with_sampling and tb.pilot is None:
        raise ValueError("non-clairvoyant replay needs a TraceBatch "
                         "packed with sampling=True")
    ep = EngineParams.from_scheduler(
        params, work_conservation=work_conservation,
        dynamics_requeue=dynamics_requeue, lcof=lcof,
        per_flow_threshold=per_flow_threshold, clairvoyant=clairvoyant)
    return _drive(tb, ep, params.delta, max_ticks, chunk, kernel,
                  sweep=False, features=features)


def simulate_sweep(trace, params_list: Sequence[SchedulerParams], *,
                   max_ticks: Optional[int] = None, chunk: int = 128,
                   kernel: Optional[str] = None,
                   fidelity: str = "flow",
                   topology=None,
                   use_pallas: bool = False) -> EngineResult:
    """Replay ONE trace under M parameter settings as one computation.

    Internal engine entry point: the public front door is
    `repro.api.run(Scenario(..., sweep=...))`.

    All settings must share num_queues (K is a static shape) and delta
    is taken per-setting — the scan length covers the smallest δ. The
    work-conservation / §4.3-re-queue switches are traced leaves, so
    settings may mix them freely (the dynamics machinery is compiled in
    when ANY setting re-queues). Returns an EngineResult whose leading
    axis is the setting axis.
    """
    if fidelity not in ("flow", "coflow"):
        raise ValueError(f"unknown fidelity {fidelity!r}")
    k = {len(p.thresholds()) for p in params_list}
    if len(k) != 1:
        raise ValueError("sweep settings must share num_queues")
    if len({p.port_bw for p in params_list}) != 1:
        # port bandwidths are baked into the packed TraceBatch, so a
        # per-setting bw would silently run every lane on settings[0]'s
        raise ValueError("sweep settings must share port_bw")
    kernel = resolve_kernel(kernel, use_pallas)
    sampling_any = any(not p.clairvoyant for p in params_list)
    if sampling_any and len({p.pilot_frac for p in params_list}) > 1:
        # the pilot layout is baked into the packed row, which the
        # sweep repeats — per-setting pilot fractions would need
        # per-row re-packing
        raise ValueError("sweep settings must share pilot_frac")
    tb1 = pack([trace], port_bw=params_list[0].port_bw,
               topology=topology, sampling=sampling_any,
               pilot_frac=params_list[0].pilot_frac)
    B = len(params_list)
    tb = TraceBatch(*(None if a is None else np.repeat(a, B, axis=0)
                      for a in tb1))
    eps = [EngineParams.from_scheduler(p) for p in params_list]
    if sampling_any:
        # dp.clairvoyant must be an ARRAY leaf on every row for the
        # stack below (1.0 = clairvoyant row inside the mixed sweep)
        eps = [e if e.dp.clairvoyant is not None else
               e._replace(dp=e.dp._replace(clairvoyant=jnp.float32(1.0)))
               for e in eps]
    ep = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *eps)
    min_delta = min(p.delta for p in params_list)
    features = (fidelity == "flow",
                any(p.dynamics_requeue and p.clairvoyant
                    for p in params_list), False,
                getattr(topology, "wc_fill", "greedy") == "maxmin",
                any(p.dynamics_requeue and not p.clairvoyant
                    for p in params_list))
    return _drive(tb, ep, min_delta, max_ticks, chunk, kernel, sweep=True,
                  features=features)


def _drive(tb: TraceBatch, ep: EngineParams, delta: float,
           max_ticks: Optional[int], chunk: int, kernel: Optional[str],
           *, sweep: bool, features: tuple) -> EngineResult:
    if max_ticks is None:
        max_ticks = default_max_ticks(tb, delta)
    state = _init_batch(tb, ep, sweep=sweep)
    events = 0
    # every event step advances >= 1 grid tick, so max_ticks also bounds
    # the number of event steps a terminating replay can need
    while events < max_ticks:
        state = _run_chunk(state, tb, ep, chunk=chunk, kernel=kernel,
                           sweep=sweep, features=features)
        events += chunk
        if bool(jnp.all(state.finished)):
            break
    else:
        raise RuntimeError(
            f"jax_engine: {int((~np.asarray(state.finished)).sum())} "
            f"coflows unfinished after {events} event steps "
            f"(raise max_ticks or check the trace)")
    fct = np.asarray(state.fct, np.float64)
    fct[~np.asarray(state.done)] = np.nan
    fct[~tb.flow_valid] = np.nan
    return EngineResult(cct=np.asarray(state.cct, np.float64),
                        fct=fct,
                        sent=np.asarray(state.sent, np.float64),
                        finished=np.asarray(state.finished),
                        ticks=int(np.asarray(state.tick).max()),
                        events=events)


# ---- online session support (repro.api.SaathSession) ---------------------

@functools.partial(jax.jit, donate_argnums=(0,))
def scatter_rows(tree, idx, rows):
    """Write stacked row updates into a device-resident slab pytree.

    `tree` is any leading-axis-batched pytree (a `TraceBatch` or a
    session `EngineState`), `idx` a (k,) int array of row indices and
    `rows` a structurally-identical pytree whose leaves carry the k
    updated rows stacked on axis 0. Passing a PLAIN tuple of trees
    with matching tuples of idx/rows scatters them all in ONE fused
    dispatch (the `SessionPool` updates its TraceBatch and EngineState
    together this way). This is the pool's dirty-row upload path: only
    the rows whose membership/state changed cross the host-device
    boundary; clean rows never re-upload (DESIGN.md §8). The input
    tree is DONATED — XLA updates the slab buffers in place, so a
    scatter costs O(dirty rows), not O(slab); callers must rebind."""
    if type(tree) is tuple:       # NamedTuple slabs are leaves-bearing
        return tuple(
            jax.tree_util.tree_map(
                lambda a, u, i=i: a.at[i].set(u), t, r)
            for t, i, r in zip(tree, idx, rows))
    return jax.tree_util.tree_map(lambda a, u: a.at[idx].set(u),
                                  tree, rows)


@jax.jit
def gather_rows(tree, idx: jax.Array):
    """Slice rows `idx` out of a device-resident slab pytree (stacked on
    axis 0) — the download half of the `SessionPool` row contract: the
    host mirrors only the rows a caller actually inspects."""
    return jax.tree_util.tree_map(lambda a: a[idx], tree)


def features_for(params: SchedulerParams, *, fidelity: str = "flow",
                 work_conservation: "bool | None" = None,
                 dynamics_requeue: "bool | None" = None,
                 lcof: bool = True,
                 per_flow_threshold: bool = True,
                 topology=None,
                 clairvoyant: "bool | None" = None) -> tuple:
    """The static `(per_flow_wc, with_dynamics, with_ablations,
    wc_maxmin, with_sampling)` structure switches `_tick` compiles
    against, derived
    exactly as `simulate_batch` derives them — shared with the online
    session so an incremental replay runs the same compiled step
    structure. `wc_maxmin` comes from the topology's `wc_fill` knob
    (LeafSpine only); the big switch always greedy-fills. The §4.3
    re-queue splits by clairvoyance: `with_dynamics` builds the exact
    finished-flow-median machinery (known sizes), `with_sampling` the
    pilot-estimate machinery (learned sizes)."""
    if fidelity not in ("flow", "coflow"):
        raise ValueError(f"unknown fidelity {fidelity!r}")
    dyn = (params.dynamics_requeue if dynamics_requeue is None
           else dynamics_requeue)
    cl = params.clairvoyant if clairvoyant is None else clairvoyant
    return (fidelity == "flow",
            dyn and cl,
            not (lcof and per_flow_threshold),
            getattr(topology, "wc_fill", "greedy") == "maxmin",
            dyn and not cl)


def _session_while(state: EngineState, tb: TraceBatch, ep: EngineParams,
                   n_end: jax.Array, max_steps: jax.Array, *,
                   kernel: Optional[str], features: tuple):
    """The session while_loop body shared by the single-slab and the
    pmap (sharded) dispatch paths: vmapped `_tick` steps until every
    lane of THIS slab (or shard) has reached its horizon or finished
    all its real coflows. The loop condition is local to the rows it
    sees, so under `pmap` each device terminates independently — a
    shard whose lanes drain early stops stepping without waiting on
    its neighbors."""
    (per_flow_wc, with_dynamics, with_ablations, wc_maxmin,
     with_sampling) = _norm_features(features)

    def lanes_open(s):
        tickf = s.tick.astype(jnp.float32)
        done = (tickf >= n_end) | jnp.all(s.finished, axis=-1)
        return ~jnp.all(done)

    def cond(carry):
        s, steps = carry
        return lanes_open(s) & (steps < max_steps)

    def body(carry):
        s, steps = carry
        s = jax.vmap(
            lambda srow, tbrow, nerow, eprow: _tick(
                srow, tbrow, eprow, kernel, per_flow_wc=per_flow_wc,
                with_dynamics=with_dynamics,
                with_ablations=with_ablations, wc_maxmin=wc_maxmin,
                with_sampling=with_sampling, n_end=nerow))(
                    s, tb, n_end, ep)
        return s, steps + 1

    return jax.lax.while_loop(cond, body, (state, jnp.int32(0)))


@functools.partial(jax.jit, static_argnames=("kernel", "features"))
def _run_session_block(state: EngineState, tb: TraceBatch,
                       ep: EngineParams, n_end: jax.Array,
                       max_steps: jax.Array, *,
                       kernel: Optional[str], features: tuple):
    """Advance every session lane to its own `n_end` horizon (or until
    its real coflows finish) in ONE dispatch: a device-side while_loop
    over vmapped `_tick` steps runs EXACTLY the event steps the fleet
    needs — no fixed-chunk padding, no host round-trip per chunk. This
    is what makes a pooled advance cost one dispatch's fixed overhead
    for the whole fleet instead of per session (DESIGN.md §8).

    `ep` carries a leading ROW axis on every leaf (the `SessionPool`
    stacks one `EngineParams` per slab row), so a heterogeneous
    multi-tenant fleet — per-row thresholds, δ, deadline factors,
    traced mechanism switches — still rides one while_loop dispatch."""
    return _session_while(state, tb, ep, n_end, max_steps,
                          kernel=kernel, features=features)


def row_mesh(shards: int):
    """A 1-D `Mesh` over the first `shards` devices, axis name "rows" —
    the row-axis partitioning the sharded `SessionPool` slab lives on.
    CPU runs get multiple host devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before
    jax initializes; see `make pool-sharded` / the CI sharded step)."""
    devs = jax.devices()
    if shards < 1:
        raise ValueError("shards must be >= 1")
    if shards > len(devs):
        raise ValueError(
            f"shards={shards} needs {shards} devices but jax sees "
            f"{len(devs)}; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={shards} (or more) "
            f"before the first jax import")
    return jax.sharding.Mesh(np.array(devs[:shards]), ("rows",))


@functools.lru_cache(maxsize=None)
def _pmapped_session_block(kernel: Optional[str], features: tuple,
                           mesh) -> "object":
    """The multi-device dispatch path, one compiled program per
    (kernel, features, mesh): `pmap` maps the SHARD axis of a folded
    ``(shards, rows_per_shard, ...)`` slab onto the mesh's devices, and
    every device runs its OWN `_session_while` loop over its rows.
    Rows are independent sessions — there is no cross-shard
    communication — so `pmap` is the right tool: each device's program
    is EXACTLY the single-slab while_loop (no GSPMD partitioner, hence
    no partitioner-inserted collectives; a collective inside loops
    with per-shard trip counts would deadlock the CPU backend), shards
    advance concurrently, and each terminates independently. The
    per-row arithmetic is the same vmapped `_tick` as the single-slab
    path, which is what keeps an N-shard pool bitwise-identical to a
    1-shard pool (tests/test_pool_sharded.py)."""
    devices = list(np.asarray(mesh.devices).flat)

    def block(state, tb, ep, n_end, max_steps):
        return _session_while(state, tb, ep, n_end, max_steps,
                              kernel=kernel, features=features)

    return jax.pmap(block, axis_name="rows",
                    in_axes=(0, 0, 0, 0, None), devices=devices)


def session_advance(state: EngineState, tb: TraceBatch, ep: EngineParams,
                    *, n_end, chunk: int = 32,
                    kernel: Optional[str] = None,
                    features: tuple = (True, True, False, False, False),
                    max_steps: int = 10_000_000, mesh=None,
                    block: bool = True):
    """Re-enter the jitted tick loop on a live session slab until every
    lane has reached its δ-grid tick target or finished all its real
    coflows. `n_end` is a scalar or a (B,) per-row array — a
    `SessionPool` advances a whole fleet of sessions, each to its own
    horizon, with ONE dispatch; lanes already at their horizon are
    exact no-ops. `ep` must carry a leading (B,) row axis on every
    leaf (stack identical rows for a homogeneous fleet): each tenant
    row schedules under its OWN thresholds/δ/mechanism switches inside
    the one dispatch. The caps are traced, so one compiled executable
    serves every advance of every session. `chunk` is accepted for API
    compatibility but unused: the device-side while_loop runs exactly
    the event steps needed.

    `mesh` (a `row_mesh`) routes the dispatch through the pmap path:
    the caller hands the slab in FOLDED layout — every leaf reshaped
    ``(B, ...) -> (shards, B // shards, ...)`` with shard i resident
    on mesh device i — and each device runs its own while_loop over
    its rows. `block=False` (the async dispatch mode) skips the
    host-side step-count readback entirely — the dispatch is enqueued
    and the DEVICE step counter is returned for the caller to fold
    into its lazy control mirror — so the caller can chain the next
    advance without waiting for this one's results.
    Returns (state, event_steps): an int when blocking, the device
    counter otherwise."""
    del chunk
    ne = np.asarray(n_end, np.float32)
    if ne.shape != state.tick.shape:
        ne = np.broadcast_to(
            ne.reshape(-1) if ne.ndim else ne,
            (int(np.prod(state.tick.shape)),)).reshape(state.tick.shape)
    ne = jnp.asarray(ne.copy(), jnp.float32)
    if mesh is not None:
        fn = _pmapped_session_block(kernel, tuple(features), mesh)
        state, steps = fn(state, tb, ep, ne, jnp.int32(max_steps))
    else:
        state, steps = _run_session_block(
            state, tb, ep, ne, jnp.int32(max_steps),
            kernel=kernel, features=features)
    if not block:
        return state, steps
    steps = int(np.asarray(steps).max())  # saath: lint-ok(host-pull-unaccounted): blocking mode's sanctioned sync; pool accounts the ctl read
    if steps >= max_steps:
        raise RuntimeError(
            f"session_advance exceeded {max_steps} event steps before "
            f"reaching its tick horizon (check the slab)")
    return state, steps


@functools.partial(jax.jit, static_argnames=("kernel", "features"))
def session_plan_tick(state: EngineState, tb: TraceBatch,
                      ep: EngineParams, *, kernel: Optional[str] = None,
                      features: tuple = (True, False, False, False, False),
                      row_mask: Optional[jax.Array] = None):
    """One coordinator tick on the slab WITHOUT integrating rates: the
    wave-planning mode `runtime.coflow_bridge.plan_waves` uses (a wave =
    the admitted set of one tick; the caller completes admitted coflows
    instantly). `row_mask` (B,) selects which sessions of a pooled slab
    plan this tick — unselected rows are exact no-ops (their state is
    untouched and they admit nothing). Any pending capped interval of a
    planning row is discarded: planning re-evaluates every tick.
    `ep` carries a leading (B,) row axis (per-tenant parameters, like
    `session_advance`). Returns (state with post-tick coordinator
    carry and tick+1, admitted (B, C) bool)."""
    (per_flow_wc, with_dynamics, with_ablations, wc_maxmin,
     with_sampling) = _norm_features(features)

    def one(s, tb_row, m, ep_row):
        tickf = s.tick.astype(jnp.float32)
        now = s.t0 + tickf * ep_row.delta
        eps_t = 1e-3 * ep_row.delta
        batch, flows, _, _, _ = _views(
            s, tb_row, now, eps_t, per_flow_wc=per_flow_wc,
            with_dynamics=with_dynamics, with_ablations=with_ablations,
            with_sampling=with_sampling)
        coord, out = jc.tick_core(
            s.coord, batch, now, ep_row.dp, kernel=kernel, flows=flows,
            wc_fill="maxmin" if wc_maxmin else "greedy")
        new = s._replace(coord=coord, tick=s.tick + 1)
        if s.pend_next is not None:
            new = new._replace(pend_next=jnp.zeros_like(s.pend_next))
        new = jax.tree_util.tree_map(
            lambda a, b: jnp.where(m, a, b), new, s)
        return new, out["admitted"] & m

    mask = row_mask if row_mask is not None else \
        jnp.ones(state.tick.shape, bool)
    return jax.vmap(one)(state, tb, mask, ep)


__all__ = ["EngineParams", "EngineState", "EngineResult",
           "default_max_ticks", "features_for", "resolve_kernel",
           "session_advance", "session_plan_tick", "scatter_rows",
           "gather_rows"]
