"""Event-driven fabric simulator (the paper's §6 simulation plane).

Faithful to the paper's coordinator model: the schedule is recomputed on
the δ grid; between recomputations ports follow the current rates.  For
speed the simulator is *event-driven*: it jumps directly to the next
time the schedule could change — a coflow arrival, a flow completion, a
queue-threshold crossing, a starvation deadline — then quantizes that
instant UP to the δ grid (a new schedule only takes effect at the next
coordinator tick, exactly like the prototype's pipelined coordinator).
A flow finishing mid-interval leaves its ports idle until the next tick,
reproducing the δ-sensitivity of Fig. 14(c).

Flow completion times are recorded exactly (not grid-quantized): rates
are constant inside an interval so the completion instant is algebraic.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import TYPE_CHECKING, Optional

import numpy as np

from repro.core.params import SchedulerParams
from repro.fabric.state import FlowTable

if TYPE_CHECKING:  # avoid circular import (policies import fabric.state)
    from repro.core.policies.base import Policy


@dataclasses.dataclass
class SimResult:
    table: FlowTable
    steps: int            # scheduler invocations
    wall_seconds: float   # host time spent simulating
    sched_seconds: float  # host time spent inside policy.schedule
    makespan: float       # last ABSOLUTE flow completion time (not a
    #                       CCT — CCTs are arrival-relative durations);
    #                       NaN when no flow finished — the same
    #                       "nothing completed" value the jax plane and
    #                       the repro.api.Result normalizer report, so
    #                       an empty replay can't masquerade as a
    #                       zero-second one

    @property
    def cct(self) -> np.ndarray:
        return self.table.cct

    @property
    def avg_cct(self) -> float:
        """Mean CCT over finished coflows; NaN when none finished (no
        all-NaN RuntimeWarning), matching the jax plane's semantics."""
        from repro.fabric.metrics import nan_row_mean

        return float(nan_row_mean(self.table.cct[None, :])[0])


def _quantize_up(t: float, delta: float) -> float:
    k = math.ceil(t / delta - 1e-9)
    return k * delta


def integrate_interval(table: FlowTable, rates: np.ndarray,
                       live: np.ndarray, now: float,
                       t_next: float) -> None:
    """Advance `table` at constant `rates` across [now, t_next): record
    exact (algebraic) flow completion instants, first-schedule times,
    and coflow completions (CCT = last FCT - arrival). Shared by
    `Simulator.run` and the online `repro.api.SaathSession` numpy
    backend so the two replay loops cannot drift."""
    served = live & (rates > 0)
    table.first_sched[served & np.isnan(table.first_sched)] = now

    adv = rates * (t_next - now)
    rem = table.size - table.sent
    fin = live & (adv >= rem - 1e-9) & (rates > 0)
    if fin.any():
        table.fct[fin] = now + rem[fin] / rates[fin]
        table.done[fin] = True
        table.sent[fin] = table.size[fin]
    grow = live & ~fin
    table.sent[grow] = np.minimum(table.size[grow],
                                  table.sent[grow] + adv[grow])
    table.rate[:] = rates

    if fin.any():
        for c in np.unique(table.cid[fin]):
            lo, hi = table.flow_lo[c], table.flow_hi[c]
            if table.done[lo:hi].all() and not table.finished[c]:
                table.finished[c] = True
                table.active[c] = False
                last = float(np.nanmax(table.fct[lo:hi]))
                table.cct[c] = last - table.arrival[c]


class Simulator:
    """Replays a FlowTable under a Policy.

    max_jump bounds the event horizon so policies whose priorities drift
    continuously (e.g. SRTF remaining-bytes swaps) are re-evaluated at
    least every `max_jump` seconds even with no discrete event.
    """

    def __init__(self, params: SchedulerParams, *,
                 max_jump: Optional[float] = None,
                 max_steps: int = 50_000_000,
                 topology=None):
        self.params = params
        self.max_jump = max_jump if max_jump is not None else 200 * params.delta
        self.max_steps = max_steps
        # fabric model (fabric.topology); None keeps the policy's own
        # (default BigSwitch — the pre-refactor per-port arithmetic)
        self.topology = topology

    # ---- event horizon ---------------------------------------------------
    def _next_event(self, table: FlowTable, policy: Policy, now: float,
                    rates: np.ndarray, next_arrival: float) -> float:
        live = table.flow_live()
        t = next_arrival
        # flow completions at current rates
        srv = live & (rates > 0)
        if srv.any():
            t_fin = now + (table.size[srv] - table.sent[srv]) / rates[srv]
            t = min(t, float(t_fin.min()))
        # policy-internal events (queue-threshold crossings, deadlines)
        t = min(t, policy.progress_events(table, now, rates))
        t = min(t, now + self.max_jump)
        return t

    def _activate(self, table: FlowTable, now: float) -> None:
        dep_ok = np.ones(table.num_coflows, bool)
        if table.deps is not None:
            dep_ok = table.deps_satisfied()
        table.active[:] = ((table.arrival <= now + 1e-12) & ~table.finished
                           & dep_ok)

    def run(self, table: FlowTable, policy: Policy) -> SimResult:
        p = self.params
        t0 = time.perf_counter()
        sched_s = 0.0
        if self.topology is not None:
            policy.topology = self.topology
        policy.reset(table)

        arrivals = np.sort(np.unique(table.arrival))
        if arrivals.size == 0:
            return SimResult(table, 0, 0.0, 0.0, float("nan"))
        now = _quantize_up(float(arrivals[0]), p.delta)
        steps = 0

        while steps < self.max_steps:
            self._activate(table, now)
            if table.finished.all():
                break
            live = table.flow_live()
            future = arrivals[arrivals > now + 1e-12]
            next_arrival = float(future[0]) if future.size else math.inf
            if not live.any():
                if math.isinf(next_arrival):
                    # DAG deps may unlock coflows without new arrivals
                    if not table.finished.all():
                        raise RuntimeError("simulator stalled: unfinished "
                                           "coflows with no live flows")
                    break
                now = _quantize_up(next_arrival, p.delta)
                continue

            s0 = time.perf_counter()
            rates = policy.schedule(table, now)
            sched_s += time.perf_counter() - s0
            steps += 1

            t_ev = self._next_event(table, policy, now, rates, next_arrival)
            if math.isinf(t_ev):
                raise RuntimeError(
                    f"simulator deadlock at t={now:.3f}: no rates, no events "
                    f"({int(live.sum())} live flows)")
            t_next = max(_quantize_up(t_ev, p.delta), now + p.delta)
            integrate_interval(table, rates, live, now, t_next)
            now = t_next
        else:
            raise RuntimeError("simulator exceeded max_steps")

        # last absolute FCT; guard the all-NaN case (nothing finished)
        # instead of letting np.nanmax emit a RuntimeWarning
        fin_fct = table.fct[np.isfinite(table.fct)]
        makespan = float(fin_fct.max()) if fin_fct.size else float("nan")
        return SimResult(table, steps, time.perf_counter() - t0, sched_s,
                         makespan)
