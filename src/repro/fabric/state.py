"""Struct-of-arrays fabric state used by the simulator and all policies."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.coflow import Trace


@dataclasses.dataclass
class FlowTable:
    """All flows of a trace, flattened. Policies read, simulator writes."""

    num_ports: int
    num_coflows: int
    # per-flow
    cid: np.ndarray        # (F,) int32 owning coflow
    src: np.ndarray        # (F,) int32
    dst: np.ndarray        # (F,) int32
    size: np.ndarray       # (F,) float64 bytes
    sent: np.ndarray       # (F,) float64 bytes
    rate: np.ndarray       # (F,) float64 bytes/s (current schedule)
    done: np.ndarray       # (F,) bool
    fct: np.ndarray        # (F,) float64 completion time (nan until done)
    first_sched: np.ndarray  # (F,) float64 first time rate>0 (nan before)
    # per-coflow
    arrival: np.ndarray    # (C,) float64
    width: np.ndarray      # (C,) int32
    active: np.ndarray     # (C,) bool  (arrived and unfinished)
    finished: np.ndarray   # (C,) bool
    cct: np.ndarray        # (C,) float64 (nan until finished)
    # flow index ranges per coflow (flows are stored contiguous per coflow)
    flow_lo: np.ndarray    # (C,) int32
    flow_hi: np.ndarray    # (C,) int32
    # port capacities, bytes/s
    bw_send: np.ndarray    # (P,)
    bw_recv: np.ndarray    # (P,)
    # optional DAG stage dependencies (§4.3): deps[c] = list of cids that
    # must finish before coflow c becomes schedulable
    deps: "list | None" = None

    def deps_satisfied(self) -> np.ndarray:
        ok = np.ones(self.num_coflows, bool)
        if self.deps is None:
            return ok
        for c, dd in enumerate(self.deps):
            if dd:
                ok[c] = all(self.finished[d] for d in dd)
        return ok

    @staticmethod
    def from_trace(trace: Trace, port_bw: float) -> "FlowTable":
        C = len(trace.coflows)
        F = trace.num_flows
        P = trace.num_ports
        t = FlowTable(
            num_ports=P, num_coflows=C,
            cid=np.zeros(F, np.int32), src=np.zeros(F, np.int32),
            dst=np.zeros(F, np.int32), size=np.zeros(F), sent=np.zeros(F),
            rate=np.zeros(F), done=np.zeros(F, bool), fct=np.full(F, np.nan),
            first_sched=np.full(F, np.nan),
            arrival=np.zeros(C), width=np.zeros(C, np.int32),
            active=np.zeros(C, bool), finished=np.zeros(C, bool),
            cct=np.full(C, np.nan),
            flow_lo=np.zeros(C, np.int32), flow_hi=np.zeros(C, np.int32),
            bw_send=np.full(P, port_bw), bw_recv=np.full(P, port_bw),
        )
        i = 0
        ordered = sorted(trace.coflows, key=lambda c: c.cid)
        cid2idx = {c.cid: j for j, c in enumerate(ordered)}
        deps = []
        for c_idx, c in enumerate(ordered):
            t.arrival[c_idx] = c.arrival
            t.width[c_idx] = c.width
            t.flow_lo[c_idx] = i
            for f in c.flows:
                t.cid[i] = c_idx
                t.src[i] = f.src
                t.dst[i] = f.dst
                t.size[i] = f.size
                i += 1
            t.flow_hi[c_idx] = i
            deps.append([cid2idx[d] for d in (c.stage_deps or [])])
        if any(deps):
            t.deps = deps
        return t

    # ---- live views -----------------------------------------------------
    def flow_live(self) -> np.ndarray:
        """(F,) bool — flow belongs to an active coflow and is unfinished."""
        return self.active[self.cid] & ~self.done

    def coflow_sent_total(self) -> np.ndarray:
        return np.bincount(self.cid, weights=self.sent,
                           minlength=self.num_coflows)

    def coflow_max_flow_sent(self) -> np.ndarray:
        """m_c = max bytes sent by any flow of each coflow (Saath Eq.1)."""
        out = np.zeros(self.num_coflows)
        np.maximum.at(out, self.cid, self.sent)
        return out

    def incidence(self, live=None):
        """Boolean (C,P) sender & receiver incidence over live flows."""
        if live is None:
            live = self.flow_live()
        A_s = np.zeros((self.num_coflows, self.num_ports), bool)
        A_r = np.zeros((self.num_coflows, self.num_ports), bool)
        A_s[self.cid[live], self.src[live]] = True
        A_r[self.cid[live], self.dst[live]] = True
        return A_s, A_r

    def flow_counts(self, live=None):
        """Integer (C,P) live-flow counts at sender / receiver ports."""
        if live is None:
            live = self.flow_live()
        cnt_s = np.zeros((self.num_coflows, self.num_ports), np.int32)
        cnt_r = np.zeros((self.num_coflows, self.num_ports), np.int32)
        np.add.at(cnt_s, (self.cid[live], self.src[live]), 1)
        np.add.at(cnt_r, (self.cid[live], self.dst[live]), 1)
        return cnt_s, cnt_r
