import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import: jax locks the device count at first
# initialization. The dry-run (and only the dry-run) builds the 512-chip
# production mesh out of host placeholder devices.

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (ARCH_IDS, SHAPES, cell_is_runnable,  # noqa: E402
                           get_config)
from repro.launch import steps as ST                            # noqa: E402
from repro.launch.mesh import make_production_mesh              # noqa: E402
from repro.optim import make_optimizer                          # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this prints/records:
  * compiled.memory_analysis()  — proves the cell fits per-chip HBM;
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline;
  * the collective mix parsed from the compiled HLO (bytes per device
    per collective kind) — the §Roofline collective term.

Usage:
  python -m repro.launch.dryrun --arch starcoder2-3b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out experiments/dryrun
"""

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_TYPE_RE = re.compile(r"(bf16|f32|f16|f64|s32|s8|u8|u32|s64|pred|f8\w*)"
                      r"\[([0-9,]*)\]")
_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
          "s8": 1, "u8": 1, "s64": 8, "pred": 1}


def _result_bytes(line: str) -> int:
    """Sum result-tuple array bytes on an HLO op line (lhs of '=')."""
    lhs = line.split("=")[0] if "=" in line else line
    total = 0
    for m in _TYPE_RE.finditer(lhs):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES.get(dt, 2)
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [ngroups,group_size]
        return int(m.group(2))
    return default


def collective_bytes(hlo: str, num_devices: int) -> dict:
    """Per-device link-bytes estimate by collective kind.

    Ring estimates: AG/A2A move result*(g-1)/g; AR moves 2x that
    (reduce-scatter + all-gather phases); RS moves operand*(g-1)/g =
    result*(g-1); permute moves the full result.
    """
    out = {k: 0.0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo.splitlines():
        ls = line.strip()
        m = re.search(r"= .*? ([a-z\-]+)\(", ls)
        kind = None
        for k in COLLECTIVES:
            if m and m.group(1) == k or f" {k}(" in ls:
                kind = k
                break
        if kind is None or ls.startswith("ROOT tuple"):
            continue
        if "-start(" in ls or "-done(" in ls:
            # async pairs: count only the -start
            if "-done(" in ls:
                continue
        rb = _result_bytes(ls)
        g = _group_size(ls, num_devices)
        if kind == "all-gather":
            b = rb * (g - 1) / max(g, 1)
        elif kind == "all-reduce":
            b = 2.0 * rb * (g - 1) / max(g, 1)
        elif kind == "reduce-scatter":
            b = rb * (g - 1)
        elif kind == "all-to-all":
            b = rb * (g - 1) / max(g, 1)
        else:
            b = float(rb)
        out[kind] += b
        counts[kind] += 1
    out["total"] = sum(out[k] for k in COLLECTIVES)
    out["counts"] = counts
    return out


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False):
    """Build + lower one cell. Returns (lowered, info dict)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    par = ST.build_parallelism(mesh)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return None, {"skipped": True, "reason": why}

    with mesh:
        params_sds, axes, meta, specs = ST.abstract_model(cfg, par)
        if shape.kind == "train":
            opt = make_optimizer(cfg)
            opt_sds = jax.eval_shape(opt.init, params_sds)
            ospecs = ST.opt_state_specs(cfg, opt_sds, specs, par)
            if ospecs is not None:
                opt_sds = ST.shard_sds(opt_sds, ospecs, par)
            step_fn = ST.jit_train_step(cfg, meta, par, opt, specs)
            batch = ST.input_specs(cfg, shape, par)
            step_sds = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = step_fn.lower(params_sds, opt_sds, step_sds, batch)
        elif shape.kind == "prefill":
            cache_sds, cspecs = ST.abstract_cache(cfg, meta, shape, par)
            fn = jax.jit(ST.make_prefill_step(cfg, meta, par),
                         donate_argnums=(2,))
            batch = ST.input_specs(cfg, shape, par)
            lowered = fn.lower(params_sds, batch, cache_sds)
        else:
            cache_sds, cspecs = ST.abstract_cache(cfg, meta, shape, par)
            fn = jax.jit(ST.make_decode_step(cfg, meta, par),
                         donate_argnums=(2,))
            batch = ST.input_specs(cfg, shape, par)
            kv_len = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = fn.lower(params_sds, batch["tokens"], cache_sds,
                               kv_len)
    return lowered, {"mesh": list(mesh.devices.shape),
                     "axes": list(mesh.axis_names)}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             save_hlo: str | None = None) -> dict:
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name,
           "multi_pod": multi_pod}
    try:
        lowered, info = lower_cell(arch, shape_name, multi_pod=multi_pod)
        rec.update(info)
        if lowered is None:
            return rec
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        if mem is not None:
            for f in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                v = getattr(mem, f, None)
                if v is not None:
                    rec[f] = int(v)
        print("memory_analysis:", mem)
        cost = compiled.cost_analysis()
        if cost:
            rec["flops"] = float(cost.get("flops", -1))
            rec["bytes_accessed"] = float(cost.get("bytes accessed", -1))
            rec["transcendentals"] = float(cost.get("transcendentals", 0))
        print("cost_analysis: flops=%.4g bytes=%.4g" % (
            rec.get("flops", -1), rec.get("bytes_accessed", -1)))
        ndev = 512 if multi_pod else 256
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes(hlo, ndev)
        rec["hlo_lines"] = hlo.count("\n")
        print("collectives:", json.dumps(rec["collectives"]))
        if save_hlo:
            with open(save_hlo, "w") as fh:
                fh.write(hlo)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="arch id, e.g. starcoder2-3b (see configs)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    for arch, shape in cells:
        tag = f"{ARCH_IDS.get(arch, arch)}.{shape}" + (
            ".multipod" if args.multi_pod else ".pod")
        print(f"=== dryrun {tag} ===", flush=True)
        rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                       save_hlo=args.save_hlo)
        path = os.path.join(args.out, tag + ".json")
        with open(path, "w") as fh:
            json.dump(rec, fh, indent=1)
        status = ("SKIP" if rec.get("skipped")
                  else "OK" if rec.get("ok") else "FAIL")
        print(f"=== {tag}: {status} ({rec.get('total_s', 0)}s) ===",
              flush=True)
        if status == "FAIL":
            print(rec.get("error"))
            print(rec.get("traceback", "")[-2000:])


if __name__ == "__main__":
    main()
