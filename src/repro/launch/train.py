"""Training driver: data pipeline -> jitted train step -> checkpoints.

Fault tolerance (designed for 1000+ nodes, exercised at CPU scale):
  * periodic + async checkpoints, atomic commit (checkpoint/ckpt.py);
  * resume from the latest step on restart — the data pipeline is
    stateless in `step`, so a killed-and-restarted run reproduces the
    uninterrupted loss trajectory bit-for-bit (tests/test_train_loop.py);
  * SIGTERM/SIGINT (preemption) triggers a final save before exit;
  * straggler watchdog: step times > k x running median are logged and
    exported — on a real pod the Saath coordinator additionally
    re-queues the straggler's coflows per §4.3 (runtime.coflow_bridge);
  * elastic restart: checkpoints restore under a different mesh via
    `restore(..., mesh=, specs=)` (global shapes; reshard = device_put).

Usage (CPU smoke scale):
  python -m repro.launch.train --arch starcoder2-3b --steps 50 \
      --smoke --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, restore
from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticLMData
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.optim import make_optimizer


class StragglerWatchdog:
    """Flags steps slower than `factor` x the running median (§4.3)."""

    def __init__(self, factor: float = 3.0, window: int = 32):
        self.factor = factor
        self.times = []
        self.window = window
        self.events = []

    def observe(self, step: int, dt: float):
        med = float(np.median(self.times[-self.window:])) \
            if self.times else dt
        self.times.append(dt)
        if len(self.times) > 8 and dt > self.factor * med:
            self.events.append({"step": step, "dt": dt, "median": med})
            return True
        return False


def train(arch: str, *, steps: int = 50, smoke: bool = True,
          ckpt_dir: str | None = None, ckpt_every: int = 20,
          batch: int = 8, seq: int = 64, seed: int = 0,
          mesh=None, log_every: int = 10, coflow_plan: bool = True):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    par = ST.build_parallelism(mesh)
    params, axes, meta, specs = ST.materialize_model(cfg, par, seed=seed)
    opt = make_optimizer(cfg, total_steps=steps)
    opt_state = opt.init(params)
    step_fn = (jax.jit(ST.make_train_step(cfg, meta, par, opt),
                       donate_argnums=(0, 1)))

    data = SyntheticLMData(cfg.vocab_size, seq, batch, seed=seed, par=par,
                           src_len=32 if cfg.enc_dec else 0,
                           d_model=cfg.d_model)

    # the Saath plan for this step's collective coflows (gradient buckets
    # + any registered background tenants) — static per step shape
    plan = None
    if coflow_plan:
        from repro.runtime.buckets import bucketize
        from repro.runtime.coflow_bridge import (grad_bucket_coflows,
                                                 plan_waves)
        bks = bucketize(params, bucket_bytes=8 * 1024 * 1024)
        plan = plan_waves(grad_bucket_coflows(bks), num_chips=8)

    start = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, every=ckpt_every, keep=3)
        last = latest_step(ckpt_dir)
        if last is not None:
            state = restore(ckpt_dir, last,
                            {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = last
            print(f"[train] resumed from step {start}")

    stop = {"now": False}

    def _sig(_s, _f):
        stop["now"] = True

    old = []
    for s in (signal.SIGTERM, signal.SIGINT):
        old.append(signal.signal(s, _sig))

    dog = StragglerWatchdog()
    losses = []
    try:
        for step in range(start, steps):
            t0 = time.perf_counter()
            b = data.batch(step)
            b = {"tokens": b["tokens"][:, :-1],
                 "labels": b["tokens"][:, 1:],
                 **({"src_embeds": b["src_embeds"]}
                    if "src_embeds" in b else {})}
            params, opt_state, m = step_fn(params, opt_state,
                                           jnp.int32(step), b)
            loss = float(m["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            dog.observe(step, dt)
            if step % log_every == 0 or step == steps - 1:
                print(f"[train] step={step} loss={loss:.4f} "
                      f"gnorm={float(m['grad_norm']):.3f} dt={dt * 1e3:.0f}ms")
            if mgr:
                mgr.maybe_save(step + 1,
                               {"params": params, "opt": opt_state},
                               metadata={"arch": arch, "loss": loss})
            if stop["now"]:
                print("[train] preemption signal — saving and exiting")
                if mgr:
                    mgr.maybe_save(step + 1,
                                   {"params": params, "opt": opt_state},
                                   metadata={"arch": arch, "loss": loss,
                                             "preempted": True},
                                   force=True)
                break
    finally:
        if mgr:
            mgr.wait()
        for s, h in zip((signal.SIGTERM, signal.SIGINT), old):
            signal.signal(s, h)

    return {"losses": losses, "straggler_events": dog.events,
            "plan": plan, "final_step": start + len(losses)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", action="store_true",
                    help="use a host-device mesh")
    args = ap.parse_args()
    mesh = make_host_mesh() if args.mesh else None
    out = train(args.arch, steps=args.steps, smoke=args.smoke,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                batch=args.batch, seq=args.seq, mesh=mesh)
    print(json.dumps({"final_loss": out["losses"][-1],
                      "steps": out["final_step"],
                      "stragglers": len(out["straggler_events"])}))


if __name__ == "__main__":
    main()
