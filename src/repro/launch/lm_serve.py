"""Serving driver: batched prefill + decode with a KV/state cache.

The batcher accumulates requests into fixed-shape slots (continuous
batching simplified to fixed batch + per-slot lengths); prefill fills
the cache, then greedy decode steps run until max tokens. Multi-tenant
traffic (the decode steps' collectives + checkpoint uploads + cache
migrations) is ordered by the Saath planner — see
examples/multi_tenant_fabric.py.

Usage (CPU smoke):
  python -m repro.launch.lm_serve --arch mamba2-1.3b --requests 4 --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch import steps as ST
from repro.models import lm


class ServeSession:
    def __init__(self, arch: str, *, smoke: bool = True, mesh=None,
                 max_len: int = 128, batch: int = 4, src_len: int = 16):
        self.cfg = get_smoke_config(arch) if smoke else get_config(arch)
        self.par = ST.build_parallelism(mesh)
        self.params, _, self.meta, _ = ST.materialize_model(
            self.cfg, self.par)
        self.max_len = max_len
        self.batch = batch
        self.src_len = src_len if self.cfg.enc_dec else 0
        self.prefill_fn = jax.jit(ST.make_prefill_step(self.cfg, self.meta,
                                                       self.par))
        self.decode_fn = jax.jit(ST.make_decode_step(self.cfg, self.meta,
                                                     self.par),
                                 donate_argnums=(2,))

    def generate(self, prompts: np.ndarray, n_tokens: int,
                 src_embeds: np.ndarray | None = None):
        """prompts: (B, P) int32. Greedy decode n_tokens continuations."""
        B, P = prompts.shape
        assert B == self.batch
        cache = lm.init_cache(self.cfg, self.meta, B, self.max_len,
                              self.par, src_len=self.src_len)
        batch = {"tokens": jnp.asarray(prompts)}
        if self.cfg.enc_dec:
            batch["src_embeds"] = jnp.asarray(src_embeds)
        logits, cache = self.prefill_fn(self.params, batch, cache)
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        kv_len = P
        for _ in range(n_tokens):
            out.append(np.asarray(tok))
            logits, cache = self.decode_fn(self.params, tok, cache,
                                           jnp.int32(kv_len))
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(
                jnp.int32)
            kv_len += 1
        return np.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()
    sess = ServeSession(args.arch, batch=args.requests)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, sess.cfg.vocab_size,
                           (args.requests, args.prompt_len)).astype(np.int32)
    src = rng.normal(size=(args.requests, sess.src_len or 1,
                           sess.cfg.d_model)).astype(np.float32) \
        if sess.cfg.enc_dec else None
    t0 = time.perf_counter()
    toks = sess.generate(prompts, args.tokens, src_embeds=src)
    dt = time.perf_counter() - t0
    print(f"generated {toks.shape} tokens in {dt:.2f}s "
          f"({args.requests * args.tokens / dt:.1f} tok/s)")
    print(toks[:, :12])


if __name__ == "__main__":
    main()
