"""The multi-tenant coflow serving front door (DESIGN.md §8).

`CoflowServer` is the admission-controlled service surface of the
scheduling plane: tenants register by name, submit coflows, and poll
completions, while ONE `repro.api.SessionPool` hosts every tenant as a
row of a single batched device slab — `advance(dt)` moves the whole
fleet's coordinators with one vmapped dispatch chain, which is what
keeps the per-decision cost flat as tenant count grows (the property
PAPER.md §5 / Table 2 measures on the testbed coordinator).

Admission model: `max_tenants` fixes the slab's row count up front
(the compiled executables are shaped by it); `register` raises
`AdmissionError` once the cap is reached, and `evict` frees a row —
dropping the tenant's unfinished coflows — for the next registrant.
Per-tenant outcomes are extracted as the SAME normalized
`repro.api.Result` the offline engines produce
(`api.scenario.result_from_completions`), so `avg_cct`, `makespan`,
`summary()` and `benchmarks.common.record` work unchanged on live
serving data.

CLI demo (CPU smoke):
  PYTHONPATH=src python -m repro.launch.serve --tenants 6 --seconds 0.4

(The LM prefill/decode serving driver formerly here lives in
`repro.launch.lm_serve`.)
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.api import Result, SessionPool, result_from_completions
from repro.api.session import CompletedCoflow
from repro.core.coflow import Coflow
from repro.core.params import SchedulerParams


class AdmissionError(RuntimeError):
    """The server is at its tenant admission cap."""


class CoflowServer:
    """Admission-controlled multi-tenant coflow scheduling service.

    All tenants share one fabric (`num_ports` ports at
    `params.port_bw`) and one scheduler configuration; each tenant owns
    an isolated `SaathSession` row of the server's `SessionPool` (its
    coflows never contend with another tenant's row — the pool batches
    the COMPUTATION, not the fabric).

    Completion history is retained per tenant for the lifetime of its
    registration (`result()` reports over all of it); eviction drops
    it. Bounded retention for very long-lived tenants is a ROADMAP
    item.
    """

    def __init__(self, params: Optional[SchedulerParams] = None, *,
                 num_ports: int, max_tenants: int = 16,
                 mechanisms: Optional[dict] = None,
                 kernel: Optional[str] = None, chunk: int = 32):
        self.pool = SessionPool(params, num_ports=num_ports,
                                max_sessions=max_tenants,
                                mechanisms=mechanisms, kernel=kernel,
                                chunk=chunk)
        self._tenants: Dict[str, object] = {}
        self._done: Dict[str, List[CompletedCoflow]] = {}
        self._polled: Dict[str, int] = {}
        self.rejected = 0

    # ---- admission -------------------------------------------------------

    @property
    def tenants(self) -> List[str]:
        return list(self._tenants)

    @property
    def occupancy(self) -> tuple:
        return (len(self._tenants), self.pool.max_sessions)

    def register(self, tenant: str) -> None:
        """Admit a tenant (raises `AdmissionError` at the cap,
        `ValueError` on a duplicate name)."""
        if tenant in self._tenants:
            raise ValueError(f"tenant {tenant!r} is already registered")
        try:
            sess = self.pool.session()   # the ONE admission authority
        except RuntimeError as e:
            self.rejected += 1
            used, cap = self.occupancy
            raise AdmissionError(
                f"admission cap reached ({used}/{cap} tenants); evict "
                f"one or raise max_tenants") from e
        self._tenants[tenant] = sess
        self._done[tenant] = []
        self._polled[tenant] = 0

    def evict(self, tenant: str) -> None:
        """Release a tenant's row (unfinished coflows are dropped)."""
        sess = self._session(tenant)
        self.pool.release(sess)
        del self._tenants[tenant]
        del self._done[tenant]
        del self._polled[tenant]

    def _session(self, tenant: str):
        try:
            return self._tenants[tenant]
        except KeyError:
            raise KeyError(
                f"unknown tenant {tenant!r}; registered: "
                f"{sorted(self._tenants)}") from None

    # ---- the tenant-keyed session surface --------------------------------

    def submit(self, tenant: str, coflows: Sequence[Coflow]) -> List[int]:
        return self._session(tenant).submit(coflows)

    def advance(self, dt: float) -> float:
        """Advance EVERY tenant's clock by `dt` with one pooled
        dispatch, harvesting completions into the per-tenant buffers."""
        self.pool.advance(dt)
        for tenant, sess in self._tenants.items():
            self._done[tenant].extend(sess.poll())
        return dt

    def poll(self, tenant: str) -> List[CompletedCoflow]:
        """Completions for `tenant` not yet returned by a poll."""
        sess = self._session(tenant)
        self._done[tenant].extend(sess.poll())
        new = self._done[tenant][self._polled[tenant]:]
        self._polled[tenant] = len(self._done[tenant])
        return list(new)

    def num_live(self, tenant: str) -> int:
        return self._session(tenant).num_live

    def result(self, tenant: str) -> Result:
        """The tenant's completions so far as a normalized
        `repro.api.Result` (the offline engines' NaN/padding contract:
        an idle tenant reports NaN aggregates, never 0.0). A pure
        accessor: it does NOT advance the `poll` cursor."""
        sess = self._session(tenant)
        self._done[tenant].extend(sess.poll())
        return result_from_completions(self._done[tenant],
                                       engine="jax", policy="saath")

    def stats(self) -> dict:
        used, cap = self.occupancy
        return {
            "tenants": used, "max_tenants": cap,
            "rejected": self.rejected,
            "live_coflows": sum(s.num_live
                                for s in self._tenants.values()),
            "completed": sum(len(d) for d in self._done.values()),
            "slab": (self.pool._C_cap, self.pool._F_cap),
        }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        description="multi-tenant coflow serving demo")
    ap.add_argument("--tenants", type=int, default=6)
    ap.add_argument("--max-tenants", type=int, default=4,
                    help="admission cap (< --tenants demonstrates "
                    "rejection + eviction)")
    ap.add_argument("--seconds", type=float, default=0.4,
                    help="virtual horizon per tenant")
    ap.add_argument("--ports", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.traces.synth import tiny_trace

    params = SchedulerParams(port_bw=1e9, delta=1e-3,
                             start_threshold=1e6)
    srv = CoflowServer(params, num_ports=args.ports,
                       max_tenants=args.max_tenants)
    t0 = time.perf_counter()
    waiting = [f"tenant/{i}" for i in range(args.tenants)]
    admitted: List[str] = []
    pending: Dict[str, list] = {}
    for i, name in enumerate(list(waiting)):
        try:
            srv.register(name)
        except AdmissionError:
            continue
        waiting.remove(name)
        admitted.append(name)
        tr = tiny_trace(16, args.ports, seed=args.seed + i, load=0.5)
        pending[name] = sorted(tr.coflows, key=lambda c: c.arrival)

    steps = 0
    next_seed = args.seed + args.tenants
    while admitted or waiting:
        srv.advance(args.seconds / 8)
        steps += 1
        for name in list(admitted):
            sess = srv._tenants[name]
            while pending[name] and pending[name][0].arrival <= sess.now:
                srv.submit(name, [pending[name].pop(0)])
            if not pending[name] and srv.num_live(name) == 0:
                res = srv.result(name)
                print(f"  {name}: {int(res.num_coflows[0])} coflows, "
                      f"avg_cct={res.avg_cct[0] * 1e3:.2f}ms, "
                      f"makespan={res.makespan[0] * 1e3:.1f}ms")
                srv.evict(name)       # frees the row for a waiter
                admitted.remove(name)
                if waiting:
                    nxt = waiting.pop(0)
                    srv.register(nxt)
                    admitted.append(nxt)
                    tr = tiny_trace(16, args.ports, seed=next_seed,
                                    load=0.5)
                    next_seed += 1
                    pending[nxt] = sorted(tr.coflows,
                                          key=lambda c: c.arrival)
        if steps > 10000:
            raise RuntimeError("demo failed to drain")
    wall = time.perf_counter() - t0
    out = dict(srv.stats(), wall_seconds=wall, steps=steps)
    print(f"== served {args.tenants} tenants through a "
          f"{args.max_tenants}-row slab in {wall:.2f}s "
          f"({steps} fleet steps; slab {out['slab']}) ==")
    return out


if __name__ == "__main__":
    main()
