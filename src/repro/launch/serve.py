"""The multi-tenant coflow serving front door (DESIGN.md §8).

`CoflowServer` is the admission-controlled service surface of the
scheduling plane: tenants register by name, submit coflows, and poll
completions, while ONE `repro.api.SessionPool` hosts every tenant as a
row of a single batched device-resident slab — `advance(dt)` moves the
whole fleet's coordinators with one vmapped dispatch chain, which is
what keeps the per-decision cost flat as tenant count grows (the
property PAPER.md §5 / Table 2 measures on the testbed coordinator).

Admission model: `max_tenants` fixes the slab's row count up front
(the compiled executables are shaped by it); `register` raises
`AdmissionError` once the cap is reached, and `evict` frees a row —
dropping the tenant's unfinished coflows — for the next registrant.
Tenants may register with their OWN `SchedulerParams`/mechanism
switches (`register(name, params=..., mechanisms=...)`): the pool
stacks one parameter row per tenant, so a heterogeneous fleet still
rides one dispatch. Per-tenant outcomes are extracted as the SAME
normalized `repro.api.Result` the offline engines produce
(`api.scenario.result_from_completions`), so `avg_cct`, `makespan`,
`summary()` and `benchmarks.common.record` work unchanged on live
serving data.

Completion retention is BOUNDED: every harvested completion is folded
into the tenant's incremental `TenantAggregates` (exact lifetime
count / mean CCT / makespan, O(1) memory), and the raw
`CompletedCoflow` records are TRIMMED once `poll` returns them (plus a
`history_limit` backstop for tenants that never poll). `result()`
therefore reports exact lifetime aggregates forever, while its
per-coflow arrays cover the retained (not-yet-polled) window — a
long-lived tenant no longer grows the server without bound.

Harvesting rides the pool's NEW-COMPLETION BITMAP
(`SessionPool.completed_sessions`): `advance` polls only tenants whose
row finished something since the last harvest, so a clean tenant costs
ZERO host work per fleet step (previously every advance probed every
tenant with a per-session `poll()`).

Overload shedding (ISSUE 6): a tenant may register under a
`TenantQuota` — live-coflow / live-byte budgets plus an SLO. A
`submit` that would blow the budget is SHED under ``policy="reject"``
(the whole batch is refused with `QuotaExceededError` — nothing is
partially admitted) or DEFERRED under ``policy="defer"`` (the
in-budget prefix is admitted; the rest queues server-side and retries
on every `advance` as capacity frees up, arrivals clamping to the
tenant clock). A deferred submission that waits longer than the
quota's `slo` is shed instead of admitted — the DCoflow-style
degradation (PAPERS.md, arxiv 2205.01229): work that can no longer
meet its budget is dropped with a counted decision, not queued into
unbounded latency. Shed/deferral counters live in `TenantAggregates`
(`shed`, `deferred`) and fleet-wide in `stats()`.

The underlying pool's sharded slab and async dispatch pass straight
through: ``CoflowServer(..., shards=N, async_dispatch=...,
features=...)``.

CLI demo (CPU smoke):
  PYTHONPATH=src python -m repro.launch.serve --tenants 6 --seconds 0.4

(The LM prefill/decode serving driver formerly here lives in
`repro.launch.lm_serve`.)
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import os
import sys
import time
from typing import Dict, List, Optional, Sequence

if __name__ == "__main__" and "--shards" in sys.argv \
        and "XLA_FLAGS" not in os.environ:
    # jax locks the device count at first initialization, which the
    # `repro.api` import below triggers — a sharded CLI run must force
    # the host devices BEFORE that (no-op when the caller already set
    # XLA_FLAGS, e.g. `make pool-sharded` / CI)
    _n = int(sys.argv[sys.argv.index("--shards") + 1])
    if _n > 1:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={_n}"

import numpy as np

from repro.api import Result, SessionPool, result_from_completions
from repro.api.pool import PoolFullError
from repro.api.session import CompletedCoflow
from repro.core.coflow import Coflow
from repro.core.params import SchedulerParams


class AdmissionError(RuntimeError):
    """The server is at its tenant admission cap."""


class QuotaExceededError(RuntimeError):
    """A submit was shed: it would blow the tenant's quota and the
    tenant registered under ``policy="reject"``."""


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """A tenant's overload budget: live-load caps plus an SLO.

    `max_live_coflows` / `max_live_bytes` bound the tenant's LIVE load
    (unfinished coflows on its row); a submit that would exceed either
    is shed (``policy="reject"``: the whole batch raises
    `QuotaExceededError`) or deferred (``policy="defer"``: the
    in-budget prefix is admitted, the overflow queues server-side and
    retries each `advance`). `slo` is the deferral deadline in tenant
    seconds: a deferred submission older than it is shed — by then it
    cannot meet its latency target, so admitting it only grows the
    backlog (the DCoflow admission rule shape)."""
    max_live_coflows: Optional[int] = None
    max_live_bytes: Optional[float] = None
    slo: Optional[float] = None
    policy: str = "reject"

    def __post_init__(self):
        if self.policy not in ("reject", "defer"):
            raise ValueError(
                f"quota policy must be 'reject' or 'defer', "
                f"got {self.policy!r}")
        for name in ("max_live_coflows", "max_live_bytes", "slo"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be positive, got {v}")


@dataclasses.dataclass
class TenantAggregates:
    """Exact lifetime completion statistics, folded incrementally as
    completions are harvested (O(1) memory however long the tenant
    lives — the fix for the unbounded per-tenant history)."""
    coflows: int = 0
    flows: int = 0
    bytes: float = 0.0
    cct_sum: float = 0.0
    last_fct: float = -math.inf     # max absolute flow completion time
    trimmed: int = 0                # records dropped by history_limit
    shed: int = 0                   # coflows refused over quota/SLO
    deferred: int = 0               # coflows queued by policy="defer"

    def fold(self, comps: Sequence[CompletedCoflow]) -> None:
        for d in comps:
            self.coflows += 1
            self.flows += int(d.fct.size)
            if d.size is not None:
                self.bytes += float(np.sum(d.size))
            self.cct_sum += float(d.cct)
            if d.fct.size:
                self.last_fct = max(self.last_fct,
                                    float(np.max(d.fct)))

    @property
    def avg_cct(self) -> float:
        return self.cct_sum / self.coflows if self.coflows \
            else float("nan")

    @property
    def makespan(self) -> float:
        # guard on `last_fct` being FINITE, not on `coflows`: a fold of
        # completions that all carry zero flows (fct.size == 0) bumps
        # `coflows` without ever touching `last_fct`, and the bare
        # coflows-gate then reported the -inf initializer as a makespan
        if not math.isfinite(self.last_fct):
            return float("nan")
        return self.last_fct


@dataclasses.dataclass
class TenantResult(Result):
    """A tenant's normalized `Result` whose summary statistics come
    from the EXACT lifetime aggregates while the per-coflow arrays
    cover only the retained (not-yet-polled) completion window —
    `row_cct()`/percentiles see the window, `avg_cct`/`makespan`/
    `num_coflows`/`total_bytes` the whole registration."""
    agg: Optional[TenantAggregates] = None
    total_bytes: Optional[np.ndarray] = None   # (1,) lifetime bytes

    @property
    def avg_cct(self) -> np.ndarray:
        if self.agg is None:
            return Result.avg_cct.fget(self)
        return np.array([self.agg.avg_cct])

    @property
    def makespan(self) -> np.ndarray:
        if self.agg is None:
            return Result.makespan.fget(self)
        return np.array([self.agg.makespan])

    @staticmethod
    def from_window(window: Sequence[CompletedCoflow],
                    agg: TenantAggregates) -> "TenantResult":
        """Build from the retained completion window + the lifetime
        aggregates (counts lifted to the lifetime totals; the arrays
        may be shorter after trimming)."""
        base = result_from_completions(window, engine="jax",
                                       policy="saath")
        out = TenantResult(
            **{f.name: getattr(base, f.name)
               for f in dataclasses.fields(Result)},
            agg=agg if agg.coflows else None)
        if agg.coflows:
            out.num_coflows = np.array([agg.coflows])
            out.num_flows = np.array([agg.flows])
            out.total_bytes = np.array([agg.bytes])
        else:
            out.total_bytes = np.array([float(np.nansum(out.sent))])
        return out


class CoflowServer:
    """Admission-controlled multi-tenant coflow scheduling service.

    All tenants share one fabric (`num_ports` ports) and one compiled
    tick structure; each tenant owns an isolated `SaathSession` row of
    the server's `SessionPool` — optionally under its own scheduler
    parameters — and its coflows never contend with another tenant's
    row (the pool batches the COMPUTATION, not the fabric).

    `history_limit` bounds the raw completions retained per tenant
    between polls (aggregates stay exact past it; overflow is counted
    in `aggregates(tenant).trimmed`).
    """

    def __init__(self, params: Optional[SchedulerParams] = None, *,
                 num_ports: int, max_tenants: int = 16,
                 mechanisms: Optional[dict] = None,
                 kernel: Optional[str] = None, chunk: int = 32,
                 history_limit: int = 4096, shards: int = 1,
                 async_dispatch: bool = True,
                 features: Optional[tuple] = None):
        self.pool = SessionPool(params, num_ports=num_ports,
                                max_sessions=max_tenants,
                                mechanisms=mechanisms, kernel=kernel,
                                chunk=chunk, shards=shards,
                                async_dispatch=async_dispatch,
                                features=features)
        self.history_limit = int(history_limit)
        self._tenants: Dict[str, object] = {}
        self._pending: Dict[str, List[CompletedCoflow]] = {}
        self._agg: Dict[str, TenantAggregates] = {}
        self._quota: Dict[str, Optional[TenantQuota]] = {}
        # policy="defer" overflow: (coflow, tenant clock at deferral)
        self._deferred: Dict[str, List[tuple]] = {}
        self._live_bytes: Dict[str, float] = {}
        self.rejected = 0

    # ---- admission -------------------------------------------------------

    @property
    def tenants(self) -> List[str]:
        return list(self._tenants)

    @property
    def occupancy(self) -> tuple:
        return (len(self._tenants), self.pool.max_sessions)

    def register(self, tenant: str,
                 params: Optional[SchedulerParams] = None,
                 mechanisms: Optional[dict] = None,
                 quota: Optional[TenantQuota] = None) -> None:
        """Admit a tenant (raises `AdmissionError` at the cap,
        `ValueError` on a duplicate name), optionally under its own
        `SchedulerParams`/mechanism switches — the tenant's slab row
        then schedules with those thresholds/δ/switches inside the
        same fleet dispatch — and/or a `TenantQuota` overload budget."""
        if tenant in self._tenants:
            raise ValueError(f"tenant {tenant!r} is already registered")
        try:
            # the ONE admission authority. ONLY the pool-full signal is
            # an admission decision; any other fault (bad params raise
            # ValueError, engine faults raise their own RuntimeError)
            # propagates untouched — translating it here misreported
            # real bugs as "admission cap reached" and corrupted the
            # `rejected` counter
            sess = self.pool.session(params=params,
                                     mechanisms=mechanisms)
        except PoolFullError as e:
            self.rejected += 1
            used, cap = self.occupancy
            raise AdmissionError(
                f"admission cap reached ({used}/{cap} tenants); evict "
                f"one or raise max_tenants") from e
        self._tenants[tenant] = sess
        self._pending[tenant] = []
        self._agg[tenant] = TenantAggregates()
        self._quota[tenant] = quota
        self._deferred[tenant] = []
        self._live_bytes[tenant] = 0.0

    def evict(self, tenant: str) -> None:
        """Release a tenant's row (unfinished coflows are dropped)."""
        sess = self._session(tenant)
        self.pool.release(sess)
        del self._tenants[tenant]
        del self._pending[tenant]
        del self._agg[tenant]
        del self._quota[tenant]
        del self._deferred[tenant]
        del self._live_bytes[tenant]

    def _session(self, tenant: str):
        try:
            return self._tenants[tenant]
        except KeyError:
            raise KeyError(
                f"unknown tenant {tenant!r}; registered: "
                f"{sorted(self._tenants)}") from None

    # ---- the tenant-keyed session surface --------------------------------

    def submit(self, tenant: str, coflows: Sequence[Coflow]) -> List[int]:
        """Submit coflows to a tenant's row, under its quota when one
        was registered: an over-budget batch is refused whole with
        `QuotaExceededError` (``policy="reject"``) or split — in-budget
        prefix admitted now, overflow deferred server-side
        (``policy="defer"``). Returns the handles admitted NOW (a
        deferred coflow gets its handle when a later `advance` admits
        it)."""
        sess = self._session(tenant)
        quota = self._quota[tenant]
        coflows = list(coflows)
        if quota is None:
            handles = sess.submit(coflows)
            self._live_bytes[tenant] += sum(c.total_bytes for c in coflows)
            return handles
        agg = self._agg[tenant]
        fits = self._budget_room(tenant, coflows)
        if fits < len(coflows) and quota.policy == "reject":
            agg.shed += len(coflows)
            raise QuotaExceededError(
                f"tenant {tenant!r} over quota ({sess.num_live} live "
                f"coflows, {self._live_bytes[tenant]:.3g} live bytes); "
                f"batch of {len(coflows)} shed")
        admit, overflow = coflows[:fits], coflows[fits:]
        handles = sess.submit(admit) if admit else []
        self._live_bytes[tenant] += sum(c.total_bytes for c in admit)
        if overflow:
            now = sess.now
            self._deferred[tenant].extend((c, now) for c in overflow)
            agg.deferred += len(overflow)
        return handles

    def _budget_room(self, tenant: str,
                     coflows: Sequence[Coflow]) -> int:
        """How many of `coflows` (in order) fit the tenant's quota
        right now — greedy prefix against the live-coflow and
        live-byte budgets."""
        quota = self._quota[tenant]
        live = self._tenants[tenant].num_live
        live_b = self._live_bytes[tenant]
        n = 0
        for c in coflows:
            if quota.max_live_coflows is not None and \
                    live + 1 > quota.max_live_coflows:
                break
            if quota.max_live_bytes is not None and \
                    live_b + c.total_bytes > quota.max_live_bytes:
                break
            live += 1
            live_b += c.total_bytes
            n += 1
        return n

    def _harvest(self, tenant: str) -> None:
        """Drain the session's fresh completions into the tenant's
        bounded pending buffer, folding the exact aggregates first."""
        done = self._tenants[tenant].poll()
        if not done:
            return
        agg = self._agg[tenant]
        before = agg.bytes
        agg.fold(done)
        self._live_bytes[tenant] = max(
            0.0, self._live_bytes[tenant] - (agg.bytes - before))
        pend = self._pending[tenant]
        pend.extend(done)
        if len(pend) > self.history_limit:
            drop = len(pend) - self.history_limit
            del pend[:drop]
            agg.trimmed += drop

    def advance(self, dt: float) -> float:
        """Advance EVERY tenant's clock by `dt` with one pooled
        dispatch, harvesting completions into the per-tenant buffers.
        Harvesting walks the pool's NEW-COMPLETION BITMAP
        (`completed_sessions`), not the tenant roster: a tenant whose
        row finished nothing since the last harvest is never polled —
        zero host work per clean tenant per step. Deferred submissions
        are then retried against the freed budget."""
        self.pool.advance(dt)
        fresh = {id(s) for s in self.pool.completed_sessions()}
        if fresh:
            for tenant, sess in self._tenants.items():
                if id(sess) in fresh:
                    self._harvest(tenant)
        self._admit_deferred()
        return dt

    def _admit_deferred(self) -> None:
        """Retry each tenant's deferred queue (in deferral order):
        entries older than the quota's SLO are shed — they can no
        longer meet their target, so admitting them only grows the
        backlog — and the rest are admitted while the freed budget
        lasts (arrivals clamp to the tenant clock on submit)."""
        for tenant, queue in self._deferred.items():
            if not queue:
                continue
            sess = self._tenants[tenant]
            quota = self._quota[tenant]
            agg = self._agg[tenant]
            now = sess.now
            keep: List[tuple] = []
            blocked = False
            for c, t_defer in queue:
                if quota.slo is not None and now - t_defer > quota.slo:
                    agg.shed += 1
                    continue
                if not blocked and self._budget_room(tenant, [c]):
                    sess.submit([c])
                    self._live_bytes[tenant] += c.total_bytes
                else:
                    blocked = True    # keep the queue order: nothing
                    keep.append((c, t_defer))  # younger jumps ahead
            self._deferred[tenant] = keep

    def poll(self, tenant: str) -> List[CompletedCoflow]:
        """Completions for `tenant` not yet returned by a poll. This is
        the TRIM point: returned records leave the server (their
        statistics live on in `aggregates(tenant)`)."""
        self._session(tenant)
        self._harvest(tenant)
        out = self._pending[tenant]
        self._pending[tenant] = []
        return out

    def num_live(self, tenant: str) -> int:
        return self._session(tenant).num_live

    def aggregates(self, tenant: str) -> TenantAggregates:
        """The tenant's exact lifetime completion statistics (stable
        across polls/trimming; O(1) memory)."""
        self._session(tenant)
        self._harvest(tenant)
        return self._agg[tenant]

    def result(self, tenant: str) -> Result:
        """The tenant's completions as a normalized `repro.api.Result`
        (the offline engines' NaN/padding contract: an idle tenant
        reports NaN aggregates, never 0.0). A pure accessor: it does
        NOT advance the `poll` cursor. `avg_cct`/`makespan`/
        `num_coflows` are exact over the tenant's WHOLE registration
        (incremental aggregates); the per-coflow arrays cover the
        retained not-yet-polled window."""
        self._session(tenant)
        self._harvest(tenant)
        return TenantResult.from_window(self._pending[tenant],
                                        self._agg[tenant])

    def stats(self) -> dict:
        used, cap = self.occupancy
        return {
            "tenants": used, "max_tenants": cap,
            "rejected": self.rejected,
            "live_coflows": sum(s.num_live
                                for s in self._tenants.values()),
            "completed": sum(a.coflows for a in self._agg.values()),
            "retained": sum(len(p) for p in self._pending.values()),
            "shed": sum(a.shed for a in self._agg.values()),
            "deferred": sum(a.deferred for a in self._agg.values()),
            "deferred_pending": sum(len(q)
                                    for q in self._deferred.values()),
            "shards": self.pool.shards,
            "slab": (self.pool._C_cap, self.pool._F_cap),
        }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        description="multi-tenant coflow serving demo")
    ap.add_argument("--tenants", type=int, default=6)
    ap.add_argument("--max-tenants", type=int, default=4,
                    help="admission cap (< --tenants demonstrates "
                    "rejection + eviction)")
    ap.add_argument("--seconds", type=float, default=0.4,
                    help="virtual horizon per tenant")
    ap.add_argument("--ports", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=1,
                    help="partition the slab row axis across this many "
                    "devices (CPU: forced host devices)")
    args = ap.parse_args(argv)

    from repro.traces.synth import tiny_trace

    params = SchedulerParams(port_bw=1e9, delta=1e-3,
                             start_threshold=1e6)
    if args.max_tenants % args.shards:
        ap.error("--max-tenants must be a multiple of --shards")
    srv = CoflowServer(params, num_ports=args.ports,
                       max_tenants=args.max_tenants,
                       shards=args.shards)
    t0 = time.perf_counter()
    waiting = [f"tenant/{i}" for i in range(args.tenants)]
    admitted: List[str] = []
    pending: Dict[str, list] = {}
    for i, name in enumerate(list(waiting)):
        try:
            srv.register(name)
        except AdmissionError:
            continue
        waiting.remove(name)
        admitted.append(name)
        tr = tiny_trace(16, args.ports, seed=args.seed + i, load=0.5)
        pending[name] = sorted(tr.coflows, key=lambda c: c.arrival)

    steps = 0
    next_seed = args.seed + args.tenants
    while admitted or waiting:
        srv.advance(args.seconds / 8)
        steps += 1
        for name in list(admitted):
            sess = srv._tenants[name]
            while pending[name] and pending[name][0].arrival <= sess.now:
                srv.submit(name, [pending[name].pop(0)])
            if not pending[name] and srv.num_live(name) == 0:
                res = srv.result(name)
                print(f"  {name}: {int(res.num_coflows[0])} coflows, "
                      f"avg_cct={res.avg_cct[0] * 1e3:.2f}ms, "
                      f"makespan={res.makespan[0] * 1e3:.1f}ms")
                srv.evict(name)       # frees the row for a waiter
                admitted.remove(name)
                if waiting:
                    nxt = waiting.pop(0)
                    srv.register(nxt)
                    admitted.append(nxt)
                    tr = tiny_trace(16, args.ports, seed=next_seed,
                                    load=0.5)
                    next_seed += 1
                    pending[nxt] = sorted(tr.coflows,
                                          key=lambda c: c.arrival)
        if steps > 10000:
            raise RuntimeError("demo failed to drain")
    wall = time.perf_counter() - t0
    out = dict(srv.stats(), wall_seconds=wall, steps=steps)
    print(f"== served {args.tenants} tenants through a "
          f"{args.max_tenants}-row slab in {wall:.2f}s "
          f"({steps} fleet steps; slab {out['slab']}) ==")
    return out


if __name__ == "__main__":
    main()
