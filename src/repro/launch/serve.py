"""The multi-tenant coflow serving front door (DESIGN.md §8).

`CoflowServer` is the admission-controlled service surface of the
scheduling plane: tenants register by name, submit coflows, and poll
completions, while ONE `repro.api.SessionPool` hosts every tenant as a
row of a single batched device-resident slab — `advance(dt)` moves the
whole fleet's coordinators with one vmapped dispatch chain, which is
what keeps the per-decision cost flat as tenant count grows (the
property PAPER.md §5 / Table 2 measures on the testbed coordinator).

Admission model: `max_tenants` fixes the slab's row count up front
(the compiled executables are shaped by it); `register` raises
`AdmissionError` once the cap is reached, and `evict` frees a row —
dropping the tenant's unfinished coflows — for the next registrant.
Tenants may register with their OWN `SchedulerParams`/mechanism
switches (`register(name, params=..., mechanisms=...)`): the pool
stacks one parameter row per tenant, so a heterogeneous fleet still
rides one dispatch. Per-tenant outcomes are extracted as the SAME
normalized `repro.api.Result` the offline engines produce
(`api.scenario.result_from_completions`), so `avg_cct`, `makespan`,
`summary()` and `benchmarks.common.record` work unchanged on live
serving data.

Completion retention is BOUNDED: every harvested completion is folded
into the tenant's incremental `TenantAggregates` (exact lifetime
count / mean CCT / makespan, O(1) memory), and the raw
`CompletedCoflow` records are TRIMMED once `poll` returns them (plus a
`history_limit` backstop for tenants that never poll). `result()`
therefore reports exact lifetime aggregates forever, while its
per-coflow arrays cover the retained (not-yet-polled) window — a
long-lived tenant no longer grows the server without bound.

CLI demo (CPU smoke):
  PYTHONPATH=src python -m repro.launch.serve --tenants 6 --seconds 0.4

(The LM prefill/decode serving driver formerly here lives in
`repro.launch.lm_serve`.)
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.api import Result, SessionPool, result_from_completions
from repro.api.session import CompletedCoflow
from repro.core.coflow import Coflow
from repro.core.params import SchedulerParams


class AdmissionError(RuntimeError):
    """The server is at its tenant admission cap."""


@dataclasses.dataclass
class TenantAggregates:
    """Exact lifetime completion statistics, folded incrementally as
    completions are harvested (O(1) memory however long the tenant
    lives — the fix for the unbounded per-tenant history)."""
    coflows: int = 0
    flows: int = 0
    bytes: float = 0.0
    cct_sum: float = 0.0
    last_fct: float = -math.inf     # max absolute flow completion time
    trimmed: int = 0                # records dropped by history_limit

    def fold(self, comps: Sequence[CompletedCoflow]) -> None:
        for d in comps:
            self.coflows += 1
            self.flows += int(d.fct.size)
            if d.size is not None:
                self.bytes += float(np.sum(d.size))
            self.cct_sum += float(d.cct)
            if d.fct.size:
                self.last_fct = max(self.last_fct,
                                    float(np.max(d.fct)))

    @property
    def avg_cct(self) -> float:
        return self.cct_sum / self.coflows if self.coflows \
            else float("nan")

    @property
    def makespan(self) -> float:
        return self.last_fct if self.coflows else float("nan")


@dataclasses.dataclass
class TenantResult(Result):
    """A tenant's normalized `Result` whose summary statistics come
    from the EXACT lifetime aggregates while the per-coflow arrays
    cover only the retained (not-yet-polled) completion window —
    `row_cct()`/percentiles see the window, `avg_cct`/`makespan`/
    `num_coflows` the whole registration."""
    agg: Optional[TenantAggregates] = None

    @property
    def avg_cct(self) -> np.ndarray:
        if self.agg is None:
            return Result.avg_cct.fget(self)
        return np.array([self.agg.avg_cct])

    @property
    def makespan(self) -> np.ndarray:
        if self.agg is None:
            return Result.makespan.fget(self)
        return np.array([self.agg.makespan])

    @staticmethod
    def from_window(window: Sequence[CompletedCoflow],
                    agg: TenantAggregates) -> "TenantResult":
        """Build from the retained completion window + the lifetime
        aggregates (counts lifted to the lifetime totals; the arrays
        may be shorter after trimming)."""
        base = result_from_completions(window, engine="jax",
                                       policy="saath")
        out = TenantResult(
            **{f.name: getattr(base, f.name)
               for f in dataclasses.fields(Result)},
            agg=agg if agg.coflows else None)
        if agg.coflows:
            out.num_coflows = np.array([agg.coflows])
            out.num_flows = np.array([agg.flows])
        return out


class CoflowServer:
    """Admission-controlled multi-tenant coflow scheduling service.

    All tenants share one fabric (`num_ports` ports) and one compiled
    tick structure; each tenant owns an isolated `SaathSession` row of
    the server's `SessionPool` — optionally under its own scheduler
    parameters — and its coflows never contend with another tenant's
    row (the pool batches the COMPUTATION, not the fabric).

    `history_limit` bounds the raw completions retained per tenant
    between polls (aggregates stay exact past it; overflow is counted
    in `aggregates(tenant).trimmed`).
    """

    def __init__(self, params: Optional[SchedulerParams] = None, *,
                 num_ports: int, max_tenants: int = 16,
                 mechanisms: Optional[dict] = None,
                 kernel: Optional[str] = None, chunk: int = 32,
                 history_limit: int = 4096):
        self.pool = SessionPool(params, num_ports=num_ports,
                                max_sessions=max_tenants,
                                mechanisms=mechanisms, kernel=kernel,
                                chunk=chunk)
        self.history_limit = int(history_limit)
        self._tenants: Dict[str, object] = {}
        self._pending: Dict[str, List[CompletedCoflow]] = {}
        self._agg: Dict[str, TenantAggregates] = {}
        self.rejected = 0

    # ---- admission -------------------------------------------------------

    @property
    def tenants(self) -> List[str]:
        return list(self._tenants)

    @property
    def occupancy(self) -> tuple:
        return (len(self._tenants), self.pool.max_sessions)

    def register(self, tenant: str,
                 params: Optional[SchedulerParams] = None,
                 mechanisms: Optional[dict] = None) -> None:
        """Admit a tenant (raises `AdmissionError` at the cap,
        `ValueError` on a duplicate name), optionally under its own
        `SchedulerParams`/mechanism switches — the tenant's slab row
        then schedules with those thresholds/δ/switches inside the
        same fleet dispatch."""
        if tenant in self._tenants:
            raise ValueError(f"tenant {tenant!r} is already registered")
        try:
            # the ONE admission authority (a full pool raises before
            # per-tenant params are even looked at; bad params raise
            # ValueError, which propagates untouched)
            sess = self.pool.session(params=params,
                                     mechanisms=mechanisms)
        except RuntimeError as e:
            self.rejected += 1
            used, cap = self.occupancy
            raise AdmissionError(
                f"admission cap reached ({used}/{cap} tenants); evict "
                f"one or raise max_tenants") from e
        self._tenants[tenant] = sess
        self._pending[tenant] = []
        self._agg[tenant] = TenantAggregates()

    def evict(self, tenant: str) -> None:
        """Release a tenant's row (unfinished coflows are dropped)."""
        sess = self._session(tenant)
        self.pool.release(sess)
        del self._tenants[tenant]
        del self._pending[tenant]
        del self._agg[tenant]

    def _session(self, tenant: str):
        try:
            return self._tenants[tenant]
        except KeyError:
            raise KeyError(
                f"unknown tenant {tenant!r}; registered: "
                f"{sorted(self._tenants)}") from None

    # ---- the tenant-keyed session surface --------------------------------

    def submit(self, tenant: str, coflows: Sequence[Coflow]) -> List[int]:
        return self._session(tenant).submit(coflows)

    def _harvest(self, tenant: str) -> None:
        """Drain the session's fresh completions into the tenant's
        bounded pending buffer, folding the exact aggregates first."""
        done = self._tenants[tenant].poll()
        if not done:
            return
        agg = self._agg[tenant]
        agg.fold(done)
        pend = self._pending[tenant]
        pend.extend(done)
        if len(pend) > self.history_limit:
            drop = len(pend) - self.history_limit
            del pend[:drop]
            agg.trimmed += drop

    def advance(self, dt: float) -> float:
        """Advance EVERY tenant's clock by `dt` with one pooled
        dispatch, harvesting completions into the per-tenant buffers."""
        self.pool.advance(dt)
        for tenant in self._tenants:
            self._harvest(tenant)
        return dt

    def poll(self, tenant: str) -> List[CompletedCoflow]:
        """Completions for `tenant` not yet returned by a poll. This is
        the TRIM point: returned records leave the server (their
        statistics live on in `aggregates(tenant)`)."""
        self._session(tenant)
        self._harvest(tenant)
        out = self._pending[tenant]
        self._pending[tenant] = []
        return out

    def num_live(self, tenant: str) -> int:
        return self._session(tenant).num_live

    def aggregates(self, tenant: str) -> TenantAggregates:
        """The tenant's exact lifetime completion statistics (stable
        across polls/trimming; O(1) memory)."""
        self._session(tenant)
        self._harvest(tenant)
        return self._agg[tenant]

    def result(self, tenant: str) -> Result:
        """The tenant's completions as a normalized `repro.api.Result`
        (the offline engines' NaN/padding contract: an idle tenant
        reports NaN aggregates, never 0.0). A pure accessor: it does
        NOT advance the `poll` cursor. `avg_cct`/`makespan`/
        `num_coflows` are exact over the tenant's WHOLE registration
        (incremental aggregates); the per-coflow arrays cover the
        retained not-yet-polled window."""
        self._session(tenant)
        self._harvest(tenant)
        return TenantResult.from_window(self._pending[tenant],
                                        self._agg[tenant])

    def stats(self) -> dict:
        used, cap = self.occupancy
        return {
            "tenants": used, "max_tenants": cap,
            "rejected": self.rejected,
            "live_coflows": sum(s.num_live
                                for s in self._tenants.values()),
            "completed": sum(a.coflows for a in self._agg.values()),
            "retained": sum(len(p) for p in self._pending.values()),
            "slab": (self.pool._C_cap, self.pool._F_cap),
        }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        description="multi-tenant coflow serving demo")
    ap.add_argument("--tenants", type=int, default=6)
    ap.add_argument("--max-tenants", type=int, default=4,
                    help="admission cap (< --tenants demonstrates "
                    "rejection + eviction)")
    ap.add_argument("--seconds", type=float, default=0.4,
                    help="virtual horizon per tenant")
    ap.add_argument("--ports", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.traces.synth import tiny_trace

    params = SchedulerParams(port_bw=1e9, delta=1e-3,
                             start_threshold=1e6)
    srv = CoflowServer(params, num_ports=args.ports,
                       max_tenants=args.max_tenants)
    t0 = time.perf_counter()
    waiting = [f"tenant/{i}" for i in range(args.tenants)]
    admitted: List[str] = []
    pending: Dict[str, list] = {}
    for i, name in enumerate(list(waiting)):
        try:
            srv.register(name)
        except AdmissionError:
            continue
        waiting.remove(name)
        admitted.append(name)
        tr = tiny_trace(16, args.ports, seed=args.seed + i, load=0.5)
        pending[name] = sorted(tr.coflows, key=lambda c: c.arrival)

    steps = 0
    next_seed = args.seed + args.tenants
    while admitted or waiting:
        srv.advance(args.seconds / 8)
        steps += 1
        for name in list(admitted):
            sess = srv._tenants[name]
            while pending[name] and pending[name][0].arrival <= sess.now:
                srv.submit(name, [pending[name].pop(0)])
            if not pending[name] and srv.num_live(name) == 0:
                res = srv.result(name)
                print(f"  {name}: {int(res.num_coflows[0])} coflows, "
                      f"avg_cct={res.avg_cct[0] * 1e3:.2f}ms, "
                      f"makespan={res.makespan[0] * 1e3:.1f}ms")
                srv.evict(name)       # frees the row for a waiter
                admitted.remove(name)
                if waiting:
                    nxt = waiting.pop(0)
                    srv.register(nxt)
                    admitted.append(nxt)
                    tr = tiny_trace(16, args.ports, seed=next_seed,
                                    load=0.5)
                    next_seed += 1
                    pending[nxt] = sorted(tr.coflows,
                                          key=lambda c: c.arrival)
        if steps > 10000:
            raise RuntimeError("demo failed to drain")
    wall = time.perf_counter() - t0
    out = dict(srv.stats(), wall_seconds=wall, steps=steps)
    print(f"== served {args.tenants} tenants through a "
          f"{args.max_tenants}-row slab in {wall:.2f}s "
          f"({steps} fleet steps; slab {out['slab']}) ==")
    return out


if __name__ == "__main__":
    main()
