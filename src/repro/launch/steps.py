"""Jittable train / prefill / decode steps with production shardings.

Everything here works on either real arrays or ShapeDtypeStructs — the
dry-run lowers the very same step functions the trainer executes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm
from repro.models.common import Parallelism, logical_to_spec, param_specs


# ------------------------------------------------------------- parallelism
def build_parallelism(mesh) -> Parallelism:
    if mesh is None:
        return Parallelism(None)
    names = mesh.axis_names
    data_axes = tuple(n for n in names if n in ("pod", "data"))
    model_axis = "model" if "model" in names else None
    return Parallelism(mesh=mesh, data_axes=data_axes,
                       model_axis=model_axis)


# --------------------------------------------------------- abstract state
def abstract_model(cfg: ModelConfig, par: Parallelism):
    """(params_sds_with_shardings, axes, meta, specs) without allocating."""
    holder = {}

    def _init(key):
        params, axes, meta = lm.init_model(cfg, key)
        holder["axes"] = axes
        holder["meta"] = meta
        return params

    params_sds = jax.eval_shape(_init, jax.random.key(0))
    axes, meta = holder["axes"], holder["meta"]
    specs = param_specs(params_sds, axes, par)
    if par.mesh is not None:
        params_sds = jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype,
                sharding=NamedSharding(par.mesh, sp)),
            params_sds, specs)
    return params_sds, axes, meta, specs


def materialize_model(cfg: ModelConfig, par: Parallelism, seed: int = 0):
    """Really init params (smoke/examples scale), sharded if on a mesh."""
    holder = {}

    def _init(key):
        params, axes, meta = lm.init_model(cfg, key)
        holder["axes"] = axes
        holder["meta"] = meta
        return params

    if par.mesh is None:
        params = _init(jax.random.key(seed))
        return params, holder["axes"], holder["meta"], None
    sds = jax.eval_shape(_init, jax.random.key(seed))
    specs = param_specs(sds, holder["axes"], par)
    shardings = jax.tree.map(
        lambda sp: NamedSharding(par.mesh, sp), specs,
        is_leaf=lambda x: isinstance(x, P))
    params = jax.jit(_init, out_shardings=shardings)(jax.random.key(seed))
    return params, holder["axes"], holder["meta"], specs


# ------------------------------------------------------------ input specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig, par: Parallelism,
                *, src_len: int = 4096):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len

    def sds(shp, dtype, axes):
        if par.mesh is None:
            return jax.ShapeDtypeStruct(shp, dtype)
        spec = logical_to_spec(axes, shp, par)
        return jax.ShapeDtypeStruct(
            shp, dtype, sharding=NamedSharding(par.mesh, spec))

    out = {}
    if shape.kind == "train":
        out["tokens"] = sds((B, S), jnp.int32, ("batch", None))
        out["labels"] = sds((B, S), jnp.int32, ("batch", None))
        if cfg.enc_dec:
            out["src_embeds"] = sds((B, src_len, cfg.d_model), jnp.bfloat16,
                                    ("batch", None, None))
    elif shape.kind == "prefill":
        out["tokens"] = sds((B, S), jnp.int32, ("batch", None))
        if cfg.enc_dec:
            out["src_embeds"] = sds((B, src_len, cfg.d_model), jnp.bfloat16,
                                    ("batch", None, None))
    else:  # decode: one new token against a seq_len KV cache
        out["tokens"] = sds((B, 1), jnp.int32, ("batch", None))
    return out


# ------------------------------------------------------------- cache specs
_CACHE_SPEC_BY_KEY = {
    # key -> logical axes AFTER the leading (groups,) dim. Decode shards
    # the cache on the SEQUENCE dim (flash-decoding style): heads stay
    # replicated, every chip scans its slice of the context.
    "k": (None, "batch", "kv_seq", None, None),
    "v": (None, "batch", "kv_seq", None, None),
    "ckv": (None, "batch", "kv_seq", None),
    "krope": (None, "batch", "kv_seq", None),
    "conv": (None, "batch", None, "ssm_heads"),
    "ssd": (None, "batch", "ssm_heads", None, None),
    "cross_k": (None, "batch", None, "kv_heads", None),
    "cross_v": (None, "batch", None, "kv_heads", None),
}


def cache_specs(cache_sds, par: Parallelism):
    """PartitionSpecs for an init_cache()-shaped pytree."""
    def spec_for(path, leaf):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        axes = _CACHE_SPEC_BY_KEY.get(key)
        if axes is None:
            return P()
        ndim = leaf.ndim
        ax = axes[-ndim:] if len(axes) >= ndim else \
            (None,) * (ndim - len(axes)) + axes
        return logical_to_spec(ax, leaf.shape, par)

    flat = jax.tree_util.tree_flatten_with_path(cache_sds)
    specs = [spec_for(kp, leaf) for kp, leaf in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], specs)


def abstract_cache(cfg, meta, shape: ShapeConfig, par: Parallelism,
                   *, src_len: int = 4096, max_extra: int = 0):
    """Cache sized exactly seq_len (keeps the sequence dim divisible by
    the model axis); decode writes position kv_len = seq_len - 1."""
    B = shape.global_batch
    max_len = shape.seq_len + max_extra

    def _mk():
        return lm.init_cache(cfg, meta, B, max_len, par,
                             src_len=src_len if cfg.enc_dec else 0)

    sds = jax.eval_shape(_mk)
    specs = cache_specs(sds, par)
    if par.mesh is not None:
        sds = jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(par.mesh, sp)),
            sds, specs)
    return sds, specs


# -------------------------------------------------------------- train step
def opt_state_specs(cfg: ModelConfig, opt_sds, params_specs, par):
    """PartitionSpecs for the optimizer state: AdamW moments inherit the
    param specs; Adafactor's factored stats are left to the compiler
    (None = auto) — they are O(n+m) small."""
    if cfg.optimizer == "adafactor":
        return None
    return {"m": params_specs, "v": params_specs}


def shard_sds(sds_tree, specs, par):
    if par.mesh is None:
        return sds_tree
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(par.mesh, sp)),
        sds_tree, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def make_train_step(cfg: ModelConfig, meta, par: Parallelism, optimizer):
    """fwd/bwd (+ optional microbatched gradient accumulation: divides
    the activation working set by `cfg.train_microbatches` — the
    standard memory lever for the >30B train cells) + optimizer update."""
    k = max(cfg.train_microbatches, 1)

    def train_step(params, opt_state, step, batch):
        def loss_fn(p, mb):
            return lm.forward_train_loss(cfg, p, meta, mb, par)

        if k == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = jax.tree.map(
                lambda t: t.reshape((k, t.shape[0] // k) + t.shape[1:]),
                batch)

            def body(carry, mb):
                c_loss, c_grads = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (c_loss + l,
                        jax.tree.map(jnp.add, c_grads, g)), None

            zeros = jax.tree.map(jnp.zeros_like, params)
            (loss, grads), _ = jax.lax.scan(body, (0.0, zeros), mbs)
            loss = loss / k
            grads = jax.tree.map(lambda g: g / k, grads)

        params2, opt_state2, info = optimizer.update(grads, opt_state,
                                                     params, step)
        metrics = {"loss": loss, "grad_norm": info["grad_norm"],
                   "step": step + 1}
        return params2, opt_state2, metrics

    return train_step


def jit_train_step(cfg, meta, par, optimizer, specs):
    step_fn = make_train_step(cfg, meta, par, optimizer)
    if par.mesh is None:
        return jax.jit(step_fn, donate_argnums=(0, 1))
    shardings = jax.tree.map(lambda sp: NamedSharding(par.mesh, sp), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return jax.jit(step_fn, donate_argnums=(0, 1),
                   in_shardings=(shardings, None, None, None))


# ------------------------------------------------------------- serve steps
def make_prefill_step(cfg, meta, par):
    def prefill_step(params, batch, cache):
        return lm.forward_prefill(cfg, params, meta, batch, cache, par)
    return prefill_step


def make_decode_step(cfg, meta, par):
    def decode_step(params, tokens, cache, kv_len):
        return lm.forward_decode(cfg, params, meta, tokens, cache, kv_len,
                                 par)
    return decode_step
