PY := python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-slow test-all api-smoke pool-smoke bench-smoke bench

test:            ## fast tier-1 suite (slow integration tests excluded)
	$(PY) -m pytest -q

test-slow:       ## only the @pytest.mark.slow integration tests
	$(PY) -m pytest -q -m slow

test-all:        ## everything
	$(PY) -m pytest -q -m ""

api-smoke:       ## tiny Scenario on both engines + 3-step SaathSession
	$(PY) -m benchmarks.api_smoke

pool-smoke:      ## 16-session SessionPool fleet vs 16 sequential sessions
	$(PY) -m benchmarks.pool_throughput

bench-smoke:     ## the quick batched-engine benchmark paths
	$(PY) -m benchmarks.api_smoke
	$(PY) -m benchmarks.fig9_speedup --engine=jax
	$(PY) -m benchmarks.fig10_breakdown --engine=jax
	$(PY) -m benchmarks.fig13_fct_deviation --engine=jax
	$(PY) -m benchmarks.fig14_sensitivity --engine=jax
	$(PY) -m benchmarks.table2_coordinator_latency --engine=jax
	SAATH_POOL_MIN_SPEEDUP=2.0 $(PY) -m benchmarks.pool_throughput --sessions 8 --coflows 12

bench:           ## full quick benchmark suite (numpy reference engine)
	$(PY) -m benchmarks.run
