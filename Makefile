PY := python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-slow test-all coverage lint audit audit-update coherence coherence-update topology topology-full sampling pool-fuzz api-smoke pool-smoke pool-sharded bench-smoke bench

test:            ## fast tier-1 suite (slow integration tests excluded)
	$(PY) -m pytest -q

lint:            ## trace-safety lint (+ ruff style pass when installed)
	@command -v ruff >/dev/null 2>&1 && ruff check src tests \
	  || echo "ruff not installed; skipping style pass"
	$(PY) -m repro.analysis.lint src tests

audit:           ## jaxpr dispatch audit vs analysis/dispatch_manifest.json
	$(PY) -m repro.analysis.audit

audit-update:    ## re-trace the hot entrypoints and rewrite the manifest
	$(PY) -m repro.analysis.audit --update

coherence:       ## slab coherence gate: typestate checker vs analysis/coherence_manifest.json + seeded-mutation selftest + interleaving explorer vs the blocking oracle
	$(PY) -m repro.analysis.coherence
	$(PY) -m repro.analysis.coherence --selftest
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	  $(PY) -m repro.analysis.explore --schedules 3 --ops 24

coherence-update: ## re-extract serving-plane effects and rewrite the coherence manifest (rule findings still block)
	$(PY) -m repro.analysis.coherence --update

topology:        ## fabric-model gates: bitwise big-switch guard + leaf-spine suites + oversub sweep (quick)
	$(PY) -m pytest -q tests/test_fabric_regression.py tests/test_topology.py
	$(PY) -m benchmarks.fig_oversub --engine=jax

topology-full:   ## nightly fabric-model tier: slow fleet/Pallas parity + full oversub sweep
	$(PY) -m pytest -q -m slow tests/test_topology.py
	$(PY) -m benchmarks.fig_oversub --engine=jax --full

sampling:        ## non-clairvoyant gates: estimator/bitwise/pool suites + known-vs-learned-vs-Aalo sweep (quick)
	$(PY) -m pytest -q tests/test_sampling.py
	$(PY) -m benchmarks.fig_sampling --engine=jax

test-slow:       ## only the @pytest.mark.slow integration tests
	$(PY) -m pytest -q -m slow

test-all:        ## everything
	$(PY) -m pytest -q -m ""

coverage:        ## fast suite + coverage gate on the serving/engine modules (needs pytest-cov)
	$(PY) -m pytest -q --cov=repro.api --cov=repro.fabric \
	  --cov-report=term-missing --cov-fail-under=75

pool-fuzz:       ## deeper pool/serve property fuzz (more interleaving examples)
	SAATH_FUZZ_EXAMPLES=20 $(PY) -m pytest -q tests/test_pool_fuzz.py tests/test_serve.py tests/test_pool.py

api-smoke:       ## tiny Scenario on both engines + 3-step SaathSession
	$(PY) -m benchmarks.api_smoke

pool-smoke:      ## 16-session SessionPool fleet vs 16 sequential sessions
	$(PY) -m benchmarks.pool_throughput

pool-sharded:    ## sharded slab + serving suites and benchmark on 8 forced host devices
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	  $(PY) -m pytest -q tests/test_pool_sharded.py tests/test_pool.py \
	    tests/test_serve.py tests/test_pool_fuzz.py
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	  SAATH_POOL_MIN_SPEEDUP=2.0 \
	  $(PY) -m benchmarks.pool_throughput --sessions 32 --shards 4

bench-smoke:     ## the quick batched-engine benchmark paths
	$(PY) -m benchmarks.api_smoke
	$(PY) -m benchmarks.fig9_speedup --engine=jax
	$(PY) -m benchmarks.fig10_breakdown --engine=jax
	$(PY) -m benchmarks.fig13_fct_deviation --engine=jax
	$(PY) -m benchmarks.fig14_sensitivity --engine=jax
	$(PY) -m benchmarks.table2_coordinator_latency --engine=jax
	SAATH_POOL_MIN_SPEEDUP=2.0 $(PY) -m benchmarks.pool_throughput --sessions 8 --coflows 12

bench:           ## full quick benchmark suite (numpy reference engine)
	$(PY) -m benchmarks.run
