"""Batched serving example: prefill + greedy decode on two families —
a KV-cache transformer and an O(1)-state Mamba2 — via the same API.

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

from repro.launch.lm_serve import ServeSession

for arch in ("starcoder2-3b", "mamba2-1.3b"):
    sess = ServeSession(arch, smoke=True, batch=2, max_len=64)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, sess.cfg.vocab_size, (2, 8)).astype(np.int32)
    toks = sess.generate(prompts, 12)
    print(f"{arch}: generated {toks.shape}; sample: {toks[0][:8]}")
