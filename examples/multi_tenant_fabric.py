"""Multi-tenant fabric scheduling: the paper's scheduler arbitrating a
pod's concurrent collective traffic.

Tenants: (a) a training job's per-step gradient buckets (reverse-layer
arrival order), (b) a MoE job's all-to-all waves, (c) a checkpoint
upload over DCN, (d) a serving fleet's KV-cache migration, (e) an
elastic-rescale parameter resharding burst.

The Saath coordinator orders them with all-or-none + LCoF and
starvation deadlines; compare against naive FIFO issue.

    PYTHONPATH=src python examples/multi_tenant_fabric.py
"""
import numpy as np

from repro.core.coflow import Coflow, Flow, Trace
from repro.api import Scenario, run
from repro.core.params import SchedulerParams
from repro.fabric.metrics import percentile_speedup
from repro.runtime.coflow_bridge import CollectiveCoflow, plan_waves

# ---- wave planning view ---------------------------------------------------
coflows = []
for b in range(6):  # gradient buckets, deepest layer ready first
    coflows.append(CollectiveCoflow(f"grad/{b}", (48 - 4 * b) << 20,
                                    ("ici:data",), b))
for l in (0, 1, 2):  # MoE a2a per MoE layer
    coflows.append(CollectiveCoflow(f"moe_a2a/{l}", 160 << 20,
                                    ("ici:model",), 10 + l))
coflows += [
    CollectiveCoflow("ckpt/upload", 4 << 30, ("dcn", "host"), 20),
    CollectiveCoflow("kv/migrate", 512 << 20, ("dcn",), 21),
    CollectiveCoflow("reshard/params", 1 << 30,
                     ("ici:data", "ici:model"), 22),
]
waves = plan_waves(coflows, num_chips=16)
print("== Saath wave plan (all-or-none + LCoF) ==")
for i, w in enumerate(waves):
    print(f"wave {i}: {w}")

# ---- full fabric simulation: Saath vs FIFO issue --------------------------
# Model each chip's ICI as a port; tenants contend for overlapping chip
# sets; replicate the steady state over 40 steps with Poisson jitter.
rng = np.random.default_rng(0)
P = 64
cfs = []
fid = 0
t = 0.0
for step in range(40):
    t += float(rng.exponential(0.05))
    for b in range(4):
        chips = range(0, 32)
        flows = [Flow(fid + i, c, c, float((32 - 6 * b) << 19))
                 for i, c in enumerate(chips)]
        fid += len(flows)
        cfs.append(Coflow(len(cfs), t + 0.001 * b, flows))
    if step % 4 == 0:  # periodic checkpoint upload on other chips
        flows = [Flow(fid + i, 32 + i, 32 + i, float(1 << 26))
                 for i in range(16)]
        fid += 16
        cfs.append(Coflow(len(cfs), t, flows))
trace = Trace(num_ports=P, coflows=cfs)
params = SchedulerParams(port_bw=50e9 / 8, delta=1e-3,
                         start_threshold=8 << 20)
fifo = run(Scenario(policy="fifo", trace=trace, params=params))
saath = run(Scenario(policy="saath", trace=trace, params=params))
s = percentile_speedup(fifo.row_cct(), saath.row_cct())
print("\n== steady-state fabric: Saath vs FIFO issue order ==")
print(f"collective-coflow completion speedup: p50={s['p50']:.2f}x "
      f"p90={s['p90']:.2f}x overall={s['overall']:.2f}x")
