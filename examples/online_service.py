"""Online coflow service: a Poisson open-loop tenant mix through one
long-running `SaathSession` (the ISSUE-3 tentpole demo) — and, with
``--tenants N``, N such mixes through one `SessionPool` slab (the
ISSUE-4 multi-tenant serving plane).

Three traffic sources share a pod's fabric, arrivals NOT known up
front:

* a training job: every step, a burst of gradient buckets (ici:data)
  and MoE all-to-all waves (ici:model), staggered by backward-pass
  readiness;
* checkpoint shard uploads over (dcn, host), Poisson;
* serving KV-cache migrations over dcn, Poisson.

Each session keeps its padded slab row alive across the whole run —
submissions land in recycled rows, `advance` re-enters the jitted tick
scan up to each wall-clock horizon, `poll` retires completions — i.e.
the coordinator runs as a *service*, not a trace replay. With N > 1
tenants the pool advances every tenant's coordinator with ONE vmapped
dispatch chain per horizon.

    PYTHONPATH=src python examples/online_service.py [--seconds 0.2]
        [--backend jax|numpy] [--seed 0] [--tenants 1] [--shards 1]

``--shards N`` partitions the pool's row axis across N devices (the
ISSUE-6 pmap dispatch path); on CPU the forced host devices are set
up automatically when XLA_FLAGS isn't already pinned by the caller.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __name__ == "__main__" and "--shards" in sys.argv \
        and "XLA_FLAGS" not in os.environ:
    # jax locks the device count at first initialization (triggered
    # by the repro.api import below) — a sharded run must force the
    # host devices BEFORE that
    _n = int(sys.argv[sys.argv.index("--shards") + 1])
    if _n > 1:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={_n}"

import numpy as np

from repro.api import SaathSession, SessionPool
from repro.runtime.coflow_bridge import (RESOURCES, CollectiveCoflow,
                                         bridge_params,
                                         collective_to_coflow)

NUM_CHIPS = 16
STEP = 0.02          # training step period (s)
MB = 1 << 20


def _workload(seconds: float, seed: int):
    """(time, name, CollectiveCoflow) arrivals over the horizon."""
    rng = np.random.default_rng(seed)
    events = []
    # training steps: 4 gradient buckets + 2 MoE a2a per step
    t = 0.0
    while t < seconds:
        for b in range(4):
            events.append((t + 1e-3 * b, CollectiveCoflow(
                f"grad/{b}", int(32 * MB), ("ici:data",), b)))
        for l in range(2):
            events.append((t + 5e-4 + 2e-3 * l, CollectiveCoflow(
                f"moe/{l}", int(64 * MB), ("ici:model",), 10 + l)))
        t += STEP
    # background tenants: Poisson
    t = float(rng.exponential(1 / 50))
    while t < seconds:
        events.append((t, CollectiveCoflow(
            "ckpt", int(256 * MB), ("dcn", "host"), 50)))
        t += float(rng.exponential(1 / 50))
    t = float(rng.exponential(1 / 100))
    while t < seconds:
        events.append((t, CollectiveCoflow(
            "kv", int(64 * MB), ("dcn",), 60)))
        t += float(rng.exponential(1 / 100))
    events.sort(key=lambda e: e[0])
    return events


def main(seconds: float = 0.2, seed: int = 0,
         backend: str = "jax", tenants: int = 1,
         shards: int = 1) -> dict:
    params = bridge_params()
    P = len(RESOURCES) * NUM_CHIPS
    if tenants > 1 and backend != "jax":
        raise ValueError("multi-tenant pooling is the jax slab's "
                         "feature; --tenants needs --backend jax")
    if shards > 1 and tenants <= 1:
        raise ValueError("--shards partitions the pooled slab; it "
                         "needs --tenants > 1")
    if tenants > 1:
        pool = SessionPool(params, num_ports=P, max_sessions=tenants,
                           shards=shards)
        sessions = [pool.session() for _ in range(tenants)]
        advance_all = pool.advance
    else:
        sessions = [SaathSession(params, num_ports=P, backend=backend)]
        advance_all = lambda dt: sessions[0].advance(dt)  # noqa: E731

    # merge every tenant's open-loop arrivals onto one fleet timeline
    merged = sorted(
        (at, ti, c)
        for ti in range(tenants)
        for at, c in _workload(seconds, seed + ti))

    t0 = time.perf_counter()
    kinds = {}
    done = []
    now = 0.0
    for at, ti, c in merged:
        if at > now:
            advance_all(at - now)
            now = at
        h = sessions[ti].submit(
            [collective_to_coflow(c, num_chips=NUM_CHIPS, arrival=at)])[0]
        kinds[(ti, h)] = c.name.split("/")[0]
        for s_i, s in enumerate(sessions):
            done += [(s_i, d) for d in s.poll()]
    spent = 0.0
    while any(s.num_live for s in sessions) and spent < 60.0:
        advance_all(5 * STEP)
        spent += 5 * STEP
        for s_i, s in enumerate(sessions):
            done += [(s_i, d) for d in s.poll()]
    wall = time.perf_counter() - t0

    by_kind = {}
    for s_i, d in done:
        by_kind.setdefault(kinds[(s_i, d.handle)], []).append(d.cct * 1e3)
    print(f"== online service ({backend}, {tenants} tenant(s)): "
          f"{len(merged)} collectives over {seconds * 1e3:.0f}ms "
          f"virtual, wall {wall:.2f}s ==")
    for kind, ccts in sorted(by_kind.items()):
        a = np.asarray(ccts)
        print(f"  {kind:6s} n={a.size:4d} avg={a.mean():7.3f}ms "
              f"p90={np.percentile(a, 90):7.3f}ms")
    if backend == "jax":
        print(f"  slab: {len(sessions)} row(s) x {sessions[0]._C_cap} "
              f"coflow x {sessions[0]._F_cap} flow slots (grown once, "
              f"recycled across {len(merged)} submissions)")
    all_cct = np.asarray([d.cct for _, d in done])
    unfinished = sum(s.num_live for s in sessions)
    return {"completed": len(done), "unfinished": unfinished,
            "avg_cct": float(all_cct.mean()) if all_cct.size else
            float("nan"), "wall_seconds": wall}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=float, default=0.2,
                    help="virtual horizon of the open-loop arrivals")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", choices=("jax", "numpy"), default="jax")
    ap.add_argument("--tenants", type=int, default=1,
                    help="sessions sharing one SessionPool slab")
    ap.add_argument("--shards", type=int, default=1,
                    help="partition the pooled slab's row axis across "
                    "this many devices (needs --tenants > 1, a "
                    "multiple of --shards)")
    args = ap.parse_args()
    main(seconds=args.seconds, seed=args.seed, backend=args.backend,
         tenants=args.tenants, shards=args.shards)
