"""End-to-end training driver: a ~100M-param dense LM for a few hundred
steps on the host, with checkpointing and the Saath coflow plan active.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(Architectures are selectable; the default builds a reduced starcoder2
family config at ~100M params. Loss should drop well below the ~5.55
unigram entropy of the synthetic mixture.)
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--cpu-budget", action="store_true",
                    help="~20M params / short sequences (single-core CPU)")
    args = ap.parse_args()

    # ~100M-param member of the chosen family (--cpu-budget: ~20M so a
    # laptop core makes progress; same code path either way)
    import repro.launch.train as T
    cfg = get_config(args.arch)
    if args.cpu_budget:
        dims = dict(num_layers=2, d_model=256, vocab_size=8192, ff=1024,
                    heads=4, seq=128, batch=8)
    else:
        dims = dict(num_layers=4, d_model=512, vocab_size=32768, ff=2048,
                    heads=8, seq=256, batch=16)
    small = dataclasses.replace(
        cfg, num_layers=dims["num_layers"], d_model=dims["d_model"],
        num_heads=dims["heads"] if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, dims["heads"])
        if cfg.num_kv_heads else 0,
        head_dim=dims["d_model"] // dims["heads"] if cfg.num_heads else 0,
        d_ff=dims["ff"] if cfg.d_ff else 0,
        vocab_size=dims["vocab_size"])

    orig = T.get_smoke_config
    T.get_smoke_config = lambda a: small
    try:
        out = train(args.arch, steps=args.steps, smoke=True,
                    batch=dims["batch"], seq=dims["seq"],
                    ckpt_dir=args.ckpt, ckpt_every=100)
    finally:
        T.get_smoke_config = orig
    print(f"first losses: {[round(x, 3) for x in out['losses'][:3]]}")
    print(f"last  losses: {[round(x, 3) for x in out['losses'][-3:]]}")
    print(f"saath plan for grad coflows: {out['plan']}")


if __name__ == "__main__":
    main()
