"""Quickstart: the paper in five minutes on a laptop.

1. Replay an FB-like trace under Aalo and Saath; print the speedup.
2. Show the three design ideas (all-or-none, per-flow thresholds,
   LCoF) switching on one by one.
3. Plan a multi-tenant collective schedule with the same coordinator.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import Scenario, run
from repro.core.params import SchedulerParams
from repro.fabric.metrics import percentile_speedup
from repro.runtime.buckets import Bucket
from repro.runtime.coflow_bridge import (CollectiveCoflow,
                                         grad_bucket_coflows, plan_waves)
from repro.traces import fb_like_trace

trace = fb_like_trace(num_coflows=200, num_ports=80, seed=1)
params = SchedulerParams()

print("== 1. Saath vs Aalo on an FB-like trace ==")
aalo = run(Scenario(policy="aalo", trace=trace, params=params))
saath = run(Scenario(policy="saath", trace=trace, params=params))
s = percentile_speedup(aalo.row_cct(), saath.row_cct())
print(f"CCT speedup vs Aalo: p50={s['p50']:.2f}x p90={s['p90']:.2f}x "
      f"(overall {s['overall']:.2f}x)\n")

print("== 2. design ideas one by one ==")
for name, kw in [("A/N only", dict(lcof=False, per_flow_threshold=False)),
                 ("A/N + P/F", dict(lcof=False, per_flow_threshold=True)),
                 ("full SAATH", {})]:
    r = run(Scenario(policy="saath", trace=trace, params=params,
                     policy_kwargs=kw))
    s = percentile_speedup(aalo.row_cct(), r.row_cct())
    print(f"{name:12s} p50={s['p50']:.2f}x p90={s['p90']:.2f}x")

print("\n== 3. the same scheduler planning collectives ==")
buckets = [Bucket(0, ("layer2",), (0,), 64 << 20),
           Bucket(1, ("layer1",), (1,), 64 << 20),
           Bucket(2, ("layer0",), (2,), 96 << 20)]
coflows = grad_bucket_coflows(buckets)
coflows += [
    CollectiveCoflow("moe/a2a", 32 << 20, ("ici:model",), 50),
    CollectiveCoflow("ckpt/upload", 1 << 30, ("dcn", "host"), 60),
    CollectiveCoflow("kv/migrate", 256 << 20, ("dcn",), 70),
]
waves = plan_waves(coflows, num_chips=16)
for i, w in enumerate(waves):
    print(f"wave {i}: {w}")
print("\n(grad buckets serialize on ici:data; the MoE a2a, checkpoint "
      "upload and KV migration ride disjoint resources in wave 0 — "
      "all-or-none + LCoF in action)")
